//! Quickstart: simulate PageRank on a disaggregated system under the
//! baseline page-migration scheme (Remote) and under DaeMon, and compare.
//!
//!     cargo run --release --example quickstart

use daemon_sim::config::SimConfig;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::run_workload;
use daemon_sim::workloads::{by_name, Scale};

fn main() {
    // The paper's default operating point: 100ns switch latency, network
    // bandwidth = 1/4 of the DRAM bus, local memory = 20% of the working
    // set (Table 2 cache hierarchy).
    let cfg = SimConfig::default().with_seed(1);
    let workload = by_name("pr").expect("pr is a Table 3 workload");

    println!("simulating '{}' ({})...", workload.name(), workload.domain());
    let remote = run_workload(&cfg, SchemeKind::Remote, workload.as_ref(), Scale::Paper);
    let daemon = run_workload(&cfg, SchemeKind::Daemon, workload.as_ref(), Scale::Paper);

    let r = &remote.metrics;
    let d = &daemon.metrics;
    println!("\n                      Remote      DaeMon");
    println!("IPC               {:>10.4}  {:>10.4}", r.ipc(), d.ipc());
    println!(
        "access cost (cyc) {:>10.1}  {:>10.1}",
        r.mean_access_cost(),
        d.mean_access_cost()
    );
    println!(
        "local hit ratio   {:>10.3}  {:>10.3}",
        r.local_hit_ratio(),
        d.local_hit_ratio()
    );
    println!("pages moved       {:>10}  {:>10}", r.pages_moved, d.pages_moved);
    println!("lines moved       {:>10}  {:>10}", r.lines_moved, d.lines_moved);
    println!(
        "compression ratio {:>10.2}  {:>10.2}",
        r.compression_ratio, d.compression_ratio
    );
    println!(
        "\nDaeMon speedup over Remote: {:.2}x (access cost {:.2}x lower)",
        d.ipc() / r.ipc(),
        r.mean_access_cost() / d.mean_access_cost()
    );
}
