//! Network robustness sweep: how each data-movement scheme behaves as the
//! interconnect degrades (the scenario the paper's intro motivates —
//! runtime variability in network latency/bandwidth).
//!
//!     cargo run --release --example network_sweep [workload]

use daemon_sim::config::SimConfig;
use daemon_sim::experiments::common::Runner;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::util::table::Table;

fn main() {
    let wl = std::env::args().nth(1).unwrap_or_else(|| "bf".to_string());
    let r = Runner::quick();
    let schemes = [
        SchemeKind::Remote,
        SchemeKind::CacheLine,
        SchemeKind::Lc,
        SchemeKind::Pq,
        SchemeKind::Daemon,
    ];
    let mut table = Table::new(
        &format!("'{wl}' IPC across network operating points"),
        &["network", "Remote", "cache-line", "LC", "PQ", "DaeMon"],
    );
    for (sw, bw) in [
        (100.0, 2.0),
        (100.0, 4.0),
        (100.0, 8.0),
        (400.0, 4.0),
        (400.0, 8.0),
        (1000.0, 8.0),
    ] {
        let cfg = SimConfig::default().with_net(sw, bw);
        let (trace, profile) = r.gen_trace(&wl, cfg.seed);
        let cells: Vec<_> = schemes.iter().map(|&k| (k, cfg.clone())).collect();
        let ms = r.run_cells(&trace, profile, &cells);
        let vals: Vec<f64> = ms.iter().map(|m| m.ipc()).collect();
        table.row_f(&format!("{}ns,1/{}", sw as u32, bw as u32), &vals);
    }
    println!("{}", table.render());
    println!(
        "Note how single-granularity schemes flip order across operating\n\
         points (the paper's 'no one-size-fits-all' observation) while\n\
         DaeMon stays at or near the front everywhere."
    );
}
