//! End-to-end driver: the full system on the full workload suite.
//!
//! Runs all 13 Table 3 workloads at paper scale through the complete
//! stack — rust simulator (L3), with the link-compression oracle either
//! native (`exact`) or the AOT-compiled pallas/JAX model executed through
//! PJRT (`pjrt`, requires `make artifacts`) — under Remote, PQ and DaeMon,
//! and reports the paper's headline metrics:
//!
//!   paper: DaeMon improves performance 2.39x and access cost 3.06x over
//!          page-granularity movement (Remote).
//!
//! Results are appended to EXPERIMENTS.md by the maintainer; the run also
//! writes results/end_to_end.json.
//!
//!     cargo run --release --example end_to_end [-- --estimator pjrt]

use daemon_sim::config::SimConfig;
use daemon_sim::experiments::common::{speedup, Runner};
use daemon_sim::runtime::{ModelRunner, NetParams, PjrtOracle};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::util::json::Json;
use daemon_sim::util::stats::geomean;
use daemon_sim::util::table::Table;
use daemon_sim::workloads::{by_name, ALL};

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "pjrt" || a == "--estimator=pjrt")
        || std::env::args()
            .collect::<Vec<_>>()
            .windows(2)
            .any(|w| w[0] == "--estimator" && w[1] == "pjrt");
    let r = Runner::paper();
    let cfg = SimConfig::default();
    // Wall-time progress reporting only — never feeds simulated time.
    #[allow(clippy::disallowed_methods)]
    let t_start = std::time::Instant::now();

    let mut table = Table::new(
        &format!(
            "End-to-end: all workloads, paper config ({} oracle)",
            if use_pjrt { "PJRT" } else { "exact" }
        ),
        &[
            "workload",
            "Remote-IPC",
            "PQ-x",
            "DaeMon-x",
            "cost-gain-x",
            "hit-Remote",
            "hit-DaeMon",
            "ratio",
        ],
    );
    let mut daemon_speedups = Vec::new();
    let mut pq_speedups = Vec::new();
    let mut cost_gains = Vec::new();
    let mut results = Vec::new();

    for wl in ALL {
        let w = by_name(wl).unwrap();
        let (trace, profile) = r.gen_trace(wl, cfg.seed);
        let mut metrics = Vec::new();
        for kind in [SchemeKind::Remote, SchemeKind::Pq, SchemeKind::Daemon] {
            let oracle: Option<Box<dyn daemon_sim::system::SizeOracle>> = if use_pjrt
                && kind == SchemeKind::Daemon
            {
                let runner = ModelRunner::load_default()
                    .expect("run `make artifacts` for the PJRT estimator");
                Some(Box::new(PjrtOracle::new(
                    runner,
                    NetParams::paper_default(),
                    cfg.seed,
                    vec![w.profile()],
                )))
            } else {
                None
            };
            let mut m = Machine::new(
                cfg.clone(),
                kind,
                trace.footprint_pages,
                vec![profile],
                oracle,
            );
            m.run(std::slice::from_ref(&trace));
            metrics.push(m.metrics.clone());
        }
        let dm = speedup(&metrics[2], &metrics[0]);
        let pq = speedup(&metrics[1], &metrics[0]);
        let cg = metrics[0].mean_access_cost() / metrics[2].mean_access_cost().max(1e-9);
        daemon_speedups.push(dm);
        pq_speedups.push(pq);
        cost_gains.push(cg);
        table.row_f(
            wl,
            &[
                metrics[0].ipc(),
                pq,
                dm,
                cg,
                metrics[0].local_hit_ratio(),
                metrics[2].local_hit_ratio(),
                metrics[2].compression_ratio,
            ],
        );
        results.push(Json::obj(vec![
            ("workload", Json::str(wl)),
            ("daemon_speedup", Json::num(dm)),
            ("pq_speedup", Json::num(pq)),
            ("cost_gain", Json::num(cg)),
        ]));
    }
    let gm_d = geomean(&daemon_speedups);
    let gm_p = geomean(&pq_speedups);
    let gm_c = geomean(&cost_gains);
    table.row_f("geomean", &[0.0, gm_p, gm_d, gm_c, 0.0, 0.0, 0.0]);
    println!("{}", table.render());
    println!(
        "HEADLINE  DaeMon vs Remote: {:.2}x speedup (paper 2.39x), {:.2}x \
         access-cost gain (paper 3.06x)  [{:.0}s wall]",
        gm_d,
        gm_c,
        t_start.elapsed().as_secs_f64()
    );

    let _ = std::fs::create_dir_all("results");
    let out = Json::obj(vec![
        ("estimator", Json::str(if use_pjrt { "pjrt" } else { "exact" })),
        ("daemon_speedup_geomean", Json::num(gm_d)),
        ("pq_speedup_geomean", Json::num(gm_p)),
        ("cost_gain_geomean", Json::num(gm_c)),
        ("per_workload", Json::Arr(results)),
    ]);
    let _ = std::fs::write("results/end_to_end.json", out.to_string());
    eprintln!("wrote results/end_to_end.json");
}
