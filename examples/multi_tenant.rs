//! Multi-tenant scenarios.
//!
//! Part 1 (Fig. 18): four heterogeneous jobs share one 4-core compute
//! component and one memory component; DaeMon's engines adapt the
//! movement granularity per-page across the mixed traffic.
//!
//! Part 2 (cluster fabric): four independent single-core tenants share
//! two memory modules over the switched fabric — each tenant gets a
//! strict bandwidth share of every module port and DRAM bus (the
//! memory-side engines' per-tenant queue controllers).
//!
//!     cargo run --release --example multi_tenant

use daemon_sim::config::{ClusterConfig, SimConfig};
use daemon_sim::experiments::common::Runner;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::run_cluster;
use daemon_sim::util::table::Table;
use daemon_sim::workloads::cache::TraceCache;
use daemon_sim::workloads::Scale;

fn main() {
    let r = Runner::quick();
    let mixes: [(&str, [&str; 4]); 3] = [
        ("graph+bio+sparse+dnn", ["pr", "nw", "sp", "dr"]),
        ("frontier+series+hpc+dnn", ["bf", "ts", "hp", "rs"]),
        ("peel+embed+filter+tri", ["kc", "sl", "pf", "tr"]),
    ];
    let mut table = Table::new(
        "4 concurrent heterogeneous jobs on a 4-core compute component",
        &["mix", "Remote-IPC", "DaeMon-IPC", "speedup"],
    );
    for (label, mix) in &mixes {
        let cfg = SimConfig::default().with_cores(4);
        let remote = r.run_mix(&mix[..], SchemeKind::Remote, &cfg);
        let daemon = r.run_mix(&mix[..], SchemeKind::Daemon, &cfg);
        table.row_f(
            label,
            &[remote.ipc(), daemon.ipc(), daemon.ipc() / remote.ipc()],
        );
    }
    println!("{}", table.render());

    // Part 2: a real cluster — 4 tenants x 2 shared memory modules.
    let tenants = ["pr", "nw", "sp", "hp"];
    let ccfg = ClusterConfig::new(2);
    let base = SimConfig::default();
    let run = |kind: SchemeKind| {
        let specs: Vec<(String, SchemeKind)> =
            tenants.iter().map(|w| (w.to_string(), kind)).collect();
        run_cluster(&ccfg, &base, &specs, |wl| {
            TraceCache::global().get(wl, Scale::Paper, base.seed, r.max_accesses)
        })
    };
    let remote = run(SchemeKind::Remote);
    let daemon = run(SchemeKind::Daemon);
    let mut cl = Table::new(
        "4 tenants x 2 shared memory modules over the switched fabric",
        &["tenant", "Remote-IPC", "DaeMon-IPC", "speedup", "DaeMon-p99-cost"],
    );
    for (i, wl) in tenants.iter().enumerate() {
        cl.row_f(
            wl,
            &[
                remote[i].ipc(),
                daemon[i].ipc(),
                daemon[i].ipc() / remote[i].ipc(),
                daemon[i].p99_access_cost(),
            ],
        );
    }
    println!("{}", cl.render());
}
