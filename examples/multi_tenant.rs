//! Multi-tenant scenario (Fig. 18): four heterogeneous jobs share one
//! 4-core compute component and one memory component; DaeMon's engines
//! adapt the movement granularity per-page across the mixed traffic.
//!
//!     cargo run --release --example multi_tenant

use daemon_sim::config::SimConfig;
use daemon_sim::experiments::common::Runner;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::util::table::Table;

fn main() {
    let r = Runner::quick();
    let mixes: [(&str, [&str; 4]); 3] = [
        ("graph+bio+sparse+dnn", ["pr", "nw", "sp", "dr"]),
        ("frontier+series+hpc+dnn", ["bf", "ts", "hp", "rs"]),
        ("peel+embed+filter+tri", ["kc", "sl", "pf", "tr"]),
    ];
    let mut table = Table::new(
        "4 concurrent heterogeneous jobs on a 4-core compute component",
        &["mix", "Remote-IPC", "DaeMon-IPC", "speedup"],
    );
    for (label, mix) in &mixes {
        let cfg = SimConfig::default().with_cores(4);
        let remote = r.run_mix(&mix[..], SchemeKind::Remote, &cfg);
        let daemon = r.run_mix(&mix[..], SchemeKind::Daemon, &cfg);
        table.row_f(
            label,
            &[remote.ipc(), daemon.ipc(), daemon.ipc() / remote.ipc()],
        );
    }
    println!("{}", table.render());
}
