// Probe: print locality scores of all workloads at Test scale.
use daemon_sim::workloads::{by_name, Scale, ALL};
use daemon_sim::workloads::trace::{locality_score, page_locality, lines_per_episode};
fn main() {
    for name in ALL {
        let w = by_name(name).unwrap();
        let t = w.generate(13, Scale::Test);
        println!("{name}: score={:.3} pl={:.3} lpe={:.2} pages={} accs={}",
            locality_score(&t), page_locality(&t), lines_per_episode(&t),
            t.footprint_pages, t.accesses.len());
    }
}
