use daemon_sim::compress::{est, lz, synth};
use daemon_sim::util::prng::Rng;
fn main() {
    let profiles = [
        ("high", synth::Profile::high()),
        ("med", synth::Profile::medium()),
        ("low", synth::Profile::low()),
    ];
    for (name, p) in profiles {
        let mut rng = Rng::new(9);
        let (mut e_sum, mut r_sum) = (0f64, 0f64);
        let n = 40;
        for _ in 0..n {
            let words = synth::gen_page_words(&mut rng, p);
            let mut bytes = Vec::new();
            for w in &words { bytes.extend_from_slice(&w.to_le_bytes()); }
            e_sum += est::estimate_page(&words)[0] as f64;
            r_sum += lz::compressed_size(&bytes) as f64;
        }
        println!("{name}: est_mean={:.0} real_mean={:.0}", e_sum/n as f64, r_sum/n as f64);
    }
}
