//! # daemon-sim
//!
//! A from-scratch reproduction of **DaeMon: Architectural Support for
//! Efficient Data Movement in Disaggregated Systems** (Giannoula et al.,
//! SIGMETRICS 2022/2023) as a three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — a cycle-approximate simulator of a fully
//!   disaggregated system: compute components (OoO cores, cache hierarchy,
//!   local memory), memory components (DRAM + hardware address
//!   translation), the interconnect, the DaeMon compute/memory engines, all
//!   baseline data-movement schemes, 13 instrumented workloads, and the
//!   experiment harness that regenerates every figure and table of the
//!   paper's evaluation.
//! - **L2/L1 (python, build-time only)** — the hardware link-compression
//!   unit model as a JAX cost model around a Pallas kernel, AOT-lowered to
//!   HLO text and executed from rust through PJRT (`runtime`).
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod compress;
pub mod daemon;
pub mod experiments;
pub mod config;
pub mod lifecycle;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod schemes;
pub mod sim;
pub mod system;
pub mod util;
pub mod workloads;
