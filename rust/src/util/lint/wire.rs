//! R5: shard wire-format hygiene.
//!
//! The sharded sweep merges JSON produced by *other* invocations of the
//! binary, so the `Metrics::to_json` field list and the shard version
//! tag are a cross-build contract.  This rule compares the source
//! against the committed golden manifest (`wire_manifest`): any drift
//! in the field list, the read-back path, or the version constant is a
//! diagnostic until the manifest and the version tag are updated
//! together in the same commit.

use super::wire_manifest::{METRICS_FIELDS, WIRE_FORMAT};
use super::{Diagnostic, Repo, Rule, R5};

const METRICS_PATH: &str = "rust/src/metrics.rs";
const ORCH_PATH: &str = "rust/src/experiments/orchestrator.rs";

pub struct WireDrift;

/// `("name"` occurrences on a raw line: the serialization tuples of
/// `Json::obj(vec![...])` blocks.
fn quoted_field_names(line: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(pos) = rest.find("(\"") {
        let after = &rest[pos + 2..];
        let Some(end) = after.find('"') else { break };
        let name = &after[..end];
        if !name.is_empty() && name.bytes().all(|b| b.is_ascii_lowercase() || b == b'_') {
            out.push(name);
        }
        rest = &after[end + 1..];
    }
    out
}

fn find_line(raw: &[String], pat: &str) -> Option<usize> {
    raw.iter().position(|l| l.contains(pat))
}

impl Rule for WireDrift {
    fn id(&self) -> &'static str {
        R5
    }

    fn summary(&self) -> &'static str {
        "shard wire format matches the committed golden manifest"
    }

    fn explain(&self) -> &'static str {
        "DESIGN.md \"Sharded sweeps\" / EXPERIMENTS.md: shard JSON is merged across\n\
         separate binary invocations, so Metrics::to_json's field list and the\n\
         SHARD_FORMAT version tag are a cross-build contract.  R5 pins both in\n\
         rust/src/util/lint/wire_manifest.rs and flags any drift: a field added,\n\
         removed, renamed, or reordered in to_json; a manifest field from_json stops\n\
         reading back; or a version tag that differs from the manifest.  To change\n\
         the format intentionally, update to_json/from_json, bump SHARD_FORMAT, and\n\
         record both in wire_manifest.rs in the same commit."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        let Some(metrics) = repo.file(METRICS_PATH) else { return };
        let Some(to_line) = find_line(&metrics.raw, "fn to_json") else {
            let msg = "Metrics::to_json not found; R5 cannot pin the wire format".to_string();
            out.push(Diagnostic::new(METRICS_PATH, 1, R5, msg));
            return;
        };
        let from_line = find_line(&metrics.raw, "fn from_json");
        let body_end = from_line.unwrap_or(metrics.raw.len());

        let mut fields: Vec<(String, usize)> = Vec::new();
        for (i, line) in metrics.raw[to_line..body_end].iter().enumerate() {
            for name in quoted_field_names(line) {
                fields.push((name.to_string(), to_line + i + 1));
            }
        }

        // Report only the first divergence: a single reorder would
        // otherwise cascade into a diagnostic per trailing field.
        let n = fields.len().max(METRICS_FIELDS.len());
        for i in 0..n {
            match (fields.get(i), METRICS_FIELDS.get(i)) {
                (Some((got, line)), Some(want)) if got != want => {
                    let msg = format!(
                        "to_json emits `{got}` at index {i} where the manifest pins \
                         `{want}`; update wire_manifest.rs AND bump SHARD_FORMAT"
                    );
                    out.push(Diagnostic::new(METRICS_PATH, *line, R5, msg));
                    break;
                }
                (Some((got, line)), None) => {
                    let msg = format!(
                        "to_json serializes `{got}` which is not in the wire manifest; \
                         add it to wire_manifest.rs AND bump SHARD_FORMAT"
                    );
                    out.push(Diagnostic::new(METRICS_PATH, *line, R5, msg));
                    break;
                }
                (None, Some(want)) => {
                    let msg = format!(
                        "manifest field `{want}` is no longer serialized by to_json; \
                         remove it from wire_manifest.rs AND bump SHARD_FORMAT"
                    );
                    out.push(Diagnostic::new(METRICS_PATH, to_line + 1, R5, msg));
                    break;
                }
                _ => {}
            }
        }

        if let Some(from) = from_line {
            for want in METRICS_FIELDS {
                let quoted = format!("\"{want}\"");
                if !metrics.raw[from..].iter().any(|l| l.contains(&quoted)) {
                    let msg = format!(
                        "manifest field `{want}` is not read back by Metrics::from_json"
                    );
                    out.push(Diagnostic::new(METRICS_PATH, from + 1, R5, msg));
                }
            }
        } else {
            let msg = "Metrics::from_json not found; shards could not be merged".to_string();
            out.push(Diagnostic::new(METRICS_PATH, 1, R5, msg));
        }

        if let Some(orch) = repo.file(ORCH_PATH) {
            match find_line(&orch.raw, "const SHARD_FORMAT") {
                Some(i) => {
                    let line = &orch.raw[i];
                    let tag = line.split('"').nth(1).unwrap_or("");
                    if tag != WIRE_FORMAT {
                        let msg = format!(
                            "SHARD_FORMAT is `{tag}` but the wire manifest pins \
                             `{WIRE_FORMAT}`; the version tag and manifest must move together"
                        );
                        out.push(Diagnostic::new(ORCH_PATH, i + 1, R5, msg));
                    }
                }
                None => {
                    let msg = "const SHARD_FORMAT not found in the orchestrator".to_string();
                    out.push(Diagnostic::new(ORCH_PATH, 1, R5, msg));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics_fixture(to_fields: &[&str], from_fields: &[&str]) -> String {
        let mut s = String::from(
            "impl Metrics {\n    pub fn to_json(&self) -> Json {\n        Json::obj(vec![\n",
        );
        for f in to_fields {
            s.push_str(&format!("            (\"{f}\", Json::num(1.0)),\n"));
        }
        s.push_str("        ])\n    }\n\n");
        s.push_str("    pub fn from_json(j: &Json) -> Result<Metrics, String> {\n");
        for f in from_fields {
            s.push_str(&format!("        let _ = jnum(j, \"{f}\")?;\n"));
        }
        s.push_str("        Ok(Metrics::new())\n    }\n}\n");
        s
    }

    fn orch_fixture(tag: &str) -> String {
        format!("const SHARD_FORMAT: &str = \"{tag}\";\n")
    }

    fn check(files: &[(&str, &str)]) -> Vec<Diagnostic> {
        let repo = Repo::from_fixtures(files, &[]);
        let mut out = Vec::new();
        WireDrift.check(&repo, &mut out);
        out
    }

    #[test]
    fn manifest_matching_fixture_is_clean() {
        let m = metrics_fixture(&METRICS_FIELDS, &METRICS_FIELDS);
        let o = orch_fixture(WIRE_FORMAT);
        let d = check(&[(METRICS_PATH, &m), (ORCH_PATH, &o)]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn reordered_field_is_one_diagnostic() {
        let mut fields: Vec<&str> = METRICS_FIELDS.to_vec();
        fields.swap(0, 1);
        let m = metrics_fixture(&fields, &METRICS_FIELDS);
        let d = check(&[(METRICS_PATH, &m)]);
        assert_eq!(d.len(), 1, "first divergence only: {d:?}");
        assert!(d[0].message.contains("bump SHARD_FORMAT"));
        assert_eq!(d[0].line, 4, "first tuple line");
    }

    #[test]
    fn added_and_removed_fields_are_flagged() {
        let mut extra: Vec<&str> = METRICS_FIELDS.to_vec();
        extra.push("bogus_counter");
        let m = metrics_fixture(&extra, &METRICS_FIELDS);
        let d = check(&[(METRICS_PATH, &m)]);
        assert!(d[0].message.contains("`bogus_counter`"), "{d:?}");

        let fewer = &METRICS_FIELDS[..METRICS_FIELDS.len() - 1];
        let m = metrics_fixture(fewer, &METRICS_FIELDS);
        let d = check(&[(METRICS_PATH, &m)]);
        assert!(d[0].message.contains("no longer serialized"), "{d:?}");
    }

    #[test]
    fn from_json_must_read_every_manifest_field() {
        let from = &METRICS_FIELDS[..METRICS_FIELDS.len() - 1];
        let m = metrics_fixture(&METRICS_FIELDS, from);
        let d = check(&[(METRICS_PATH, &m)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("not read back"));
    }

    #[test]
    fn version_tag_must_match_the_manifest() {
        let m = metrics_fixture(&METRICS_FIELDS, &METRICS_FIELDS);
        let o = orch_fixture("daemon-sim-shard-v3");
        let d = check(&[(METRICS_PATH, &m), (ORCH_PATH, &o)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("daemon-sim-shard-v3"));
    }

    #[test]
    fn fixture_repos_without_metrics_are_skipped() {
        assert!(check(&[("rust/src/x.rs", "fn f() {}\n")]).is_empty());
    }
}
