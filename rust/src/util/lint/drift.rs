//! R4: registry/doc drift.
//!
//! Two sync invariants that rot silently without a gate:
//!
//! * every experiment id registered in `rust/src/experiments/mod.rs`
//!   is documented in EXPERIMENTS.md, and every id-shaped token in
//!   DESIGN.md / EXPERIMENTS.md names a registered experiment;
//! * every lifecycle state enum named in DESIGN.md's "Lifecycles and
//!   state machines" and "Request serving & SLO model" transition
//!   tables exists in the source, and every state or event named in any
//!   column of those tables appears as a source identifier (the
//!   `lifecycle::Lifecycle` enums and their event types);
//! * every event kind named in the first column of DESIGN.md's
//!   "Observability" tables appears as a source identifier (the
//!   `EventKind` taxonomy in `rust/src/obs/trace.rs`).
//!
//! The rule anchors on the registry file: fixture repos without it are
//! skipped entirely (a real tree without it would not build), while a
//! real tree with the registry but without the docs is drift.

use super::{scan, Diagnostic, Repo, Rule, SourceFile, R4};

const REGISTRY_PATH: &str = "rust/src/experiments/mod.rs";
const LIFECYCLE_HEADING: &str = "## Lifecycles and state machines";
const REQUEST_HEADING: &str = "## Request serving & SLO model";
const OBSERVABILITY_HEADING: &str = "## Observability";

pub struct DocDrift;

/// `(id, 1-based line)` for every literal `id: "..."` field in a
/// registry source file (shared with R6's policy-registry scan).
pub(crate) fn registry_ids(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (i, line) in f.raw.iter().enumerate() {
        if let Some(rest) = line.trim_start().strip_prefix("id: \"") {
            if let Some(end) = rest.find('"') {
                out.push((rest[..end].to_string(), i + 1));
            }
        }
    }
    out
}

fn ident_tokens(line: &str) -> impl Iterator<Item = &str> {
    line.split(|c: char| !scan::is_ident_char(c)).filter(|t| !t.is_empty())
}

/// Does `tok` look like an experiment id?  Only the distinctive shapes
/// are claimed (`fig<N>`, `table<N>`, `cluster_*`, `ablation_*`); free
/// ids like `headline` are covered by the forward direction only.
fn id_shaped(tok: &str) -> bool {
    for p in ["fig", "table"] {
        if let Some(rest) = tok.strip_prefix(p) {
            if !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()) {
                return true;
            }
        }
    }
    ["cluster_", "ablation_"].iter().any(|p| tok.strip_prefix(p).is_some_and(|r| !r.is_empty()))
}

fn doc_has_token(text: &str, tok: &str) -> bool {
    text.lines().any(|l| scan::has_token(l, tok))
}

/// Backticked spans of a markdown line: odd-indexed pieces of a split
/// on the backtick character.
pub(crate) fn backtick_spans(line: &str) -> Vec<&str> {
    line.split('`').enumerate().filter(|(i, _)| i % 2 == 1).map(|(_, s)| s).collect()
}

/// Lines of the `heading` section (1-based numbering), up to the next
/// `## ` heading.
pub(crate) fn doc_section<'a>(text: &'a str, heading: &str) -> Vec<(usize, &'a str)> {
    let mut out = Vec::new();
    let mut inside = false;
    for (i, line) in text.lines().enumerate() {
        if line.trim_end() == heading {
            inside = true;
            continue;
        }
        if inside && line.starts_with("## ") {
            break;
        }
        if inside {
            out.push((i + 1, line));
        }
    }
    out
}

/// Check that every backticked uppercase-start identifier in the
/// section's tables appears as a source identifier.  `all_columns`
/// widens the scan from the first column to every cell — the lifecycle
/// transition tables carry states in the `from`/`to` columns and events
/// in the middle one, and all three kinds must exist in source.
fn check_table_idents(
    repo: &Repo,
    section: &[(usize, &str)],
    what: &str,
    all_columns: bool,
    out: &mut Vec<Diagnostic>,
) {
    let mut seen: Vec<&str> = Vec::new();
    for (line_no, line) in section {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        let cells = line.split('|').skip(1);
        let cells: Vec<&str> = if all_columns { cells.collect() } else { cells.take(1).collect() };
        for span in cells.iter().flat_map(|c| backtick_spans(c)) {
            let ok = span.starts_with(|c: char| c.is_ascii_uppercase())
                && span.chars().all(scan::is_ident_char);
            if ok && !seen.contains(&span) {
                seen.push(span);
                if !source_has_token(repo, span) {
                    let msg = format!(
                        "{what} `{span}` is in a DESIGN.md table but never \
                         appears in the scanned source"
                    );
                    out.push(Diagnostic::new("DESIGN.md", *line_no, R4, msg));
                }
            }
        }
    }
}

fn enum_shaped(name: &str) -> bool {
    name.ends_with("State")
        && name.len() > "State".len()
        && name.starts_with(|c: char| c.is_ascii_uppercase())
        && name.chars().all(|c| c.is_ascii_alphanumeric())
}

fn source_has_token(repo: &Repo, tok: &str) -> bool {
    repo.files.iter().any(|f| f.code.iter().any(|l| scan::has_token(l, tok)))
}

impl Rule for DocDrift {
    fn id(&self) -> &'static str {
        R4
    }

    fn summary(&self) -> &'static str {
        "experiment registry and lifecycle docs stay in sync with source"
    }

    fn explain(&self) -> &'static str {
        "DESIGN.md \"Experiment index\" and \"Lifecycles and state machines\": the docs\n\
         are the contract for what the binary can run and how its state machines move.\n\
         R4 checks three things: (a) every id in experiments::REGISTRY is mentioned in\n\
         EXPERIMENTS.md; (b) every id-shaped token (fig<N>, table<N>, cluster_*,\n\
         ablation_*) in DESIGN.md/EXPERIMENTS.md names a registered experiment; (c)\n\
         every `SomethingState` enum named in the lifecycle or request-serving\n\
         sections exists in rust/src, and every state and event in a lifecycle\n\
         transition table (all columns) appears as a source identifier; (d) every event kind in the \"Observability\"\n\
         section's tables (first column)\n\
         appears as a source identifier (the EventKind taxonomy).  Fix by registering\n\
         the experiment, documenting it, or updating the stale doc."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        let Some(reg) = repo.file(REGISTRY_PATH) else { return };
        let ids = registry_ids(reg);

        match repo.doc("EXPERIMENTS.md") {
            Some(exps) => {
                for (id, line) in &ids {
                    if !doc_has_token(exps, id) {
                        let msg = format!("experiment id `{id}` is not documented in \
                                           EXPERIMENTS.md");
                        out.push(Diagnostic::new(REGISTRY_PATH, *line, R4, msg));
                    }
                }
            }
            None => {
                let msg = "EXPERIMENTS.md is missing".to_string();
                out.push(Diagnostic::new(REGISTRY_PATH, 1, R4, msg));
            }
        }

        for (doc, text) in &repo.docs {
            for (i, line) in text.lines().enumerate() {
                for tok in ident_tokens(line) {
                    if id_shaped(tok) && !ids.iter().any(|(id, _)| id == tok) {
                        let msg = format!(
                            "`{tok}` looks like an experiment id but is not in the registry"
                        );
                        out.push(Diagnostic::new(doc, i + 1, R4, msg));
                    }
                }
            }
        }

        let Some(design) = repo.doc("DESIGN.md") else {
            let msg = "DESIGN.md is missing".to_string();
            out.push(Diagnostic::new(REGISTRY_PATH, 1, R4, msg));
            return;
        };
        // The request-serving section carries the `RequestState`
        // transition table outside the main lifecycle section; both are
        // held to the same contract.
        let mut checked: Vec<&str> = Vec::new();
        for heading in [LIFECYCLE_HEADING, REQUEST_HEADING] {
            let section = doc_section(design, heading);
            for (line_no, line) in &section {
                for span in backtick_spans(line) {
                    let name = span.rsplit("::").next().unwrap_or(span);
                    if enum_shaped(name) && !checked.contains(&name) {
                        checked.push(name);
                        let pat = format!("enum {name}");
                        if !source_has_token(repo, &pat) {
                            let msg = format!(
                                "lifecycle enum `{name}` is named in DESIGN.md but `{pat}` \
                                 does not exist in the scanned source"
                            );
                            out.push(Diagnostic::new("DESIGN.md", *line_no, R4, msg));
                        }
                    }
                }
            }
            check_table_idents(repo, &section, "lifecycle state/event", true, out);
        }
        check_table_idents(
            repo,
            &doc_section(design, OBSERVABILITY_HEADING),
            "observability event kind",
            false,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const REGISTRY_FIXTURE: &str = "pub const REGISTRY: &[ExperimentDef] = &[\n\
        ExperimentDef {\n\
        id: \"fig1\",\n\
        },\n\
        ExperimentDef {\n\
        id: \"cluster_a\",\n\
        },\n\
        ];\n";

    const DESIGN_FIXTURE: &str = "# Doc\n\n\
        ## Lifecycles and state machines\n\n\
        ### Thing lifecycle (`foo::BarState`)\n\n\
        | state | meaning |\n\
        |---|---|\n\
        | `Alpha` | first |\n\
        | `Gone` | second |\n\n\
        ## Next section\n\nfig1 again.\n";

    const ENUM_FIXTURE: &str = "pub enum BarState { Alpha }\n";

    fn check(files: &[(&str, &str)], docs: &[(&str, &str)]) -> Vec<Diagnostic> {
        let repo = Repo::from_fixtures(files, docs);
        let mut out = Vec::new();
        DocDrift.check(&repo, &mut out);
        out
    }

    #[test]
    fn clean_fixture_passes() {
        let d = check(
            &[(REGISTRY_PATH, REGISTRY_FIXTURE), ("rust/src/e.rs", ENUM_FIXTURE)],
            &[
                ("DESIGN.md", "## Lifecycles and state machines\n\n| state |\n| `Alpha` |\n"),
                ("EXPERIMENTS.md", "Run fig1 and cluster_a.\n"),
            ],
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn unregistered_doc_id_and_undocumented_registry_id_are_flagged() {
        let d = check(
            &[(REGISTRY_PATH, REGISTRY_FIXTURE)],
            &[("EXPERIMENTS.md", "Only fig1 here, plus unknown fig9 and ablation_x.\n")],
        );
        let msgs: Vec<String> = d.iter().map(|x| x.to_string()).collect();
        assert!(msgs.iter().any(|m| m.contains("`cluster_a`") && m.contains("not documented")));
        assert!(msgs.iter().any(|m| m.contains("`fig9`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`ablation_x`")), "{msgs:?}");
        assert!(d.iter().all(|x| x.rule == R4));
    }

    #[test]
    fn registry_line_numbers_point_at_the_id() {
        let docs = [("EXPERIMENTS.md", "fig1\n"), ("DESIGN.md", "no lifecycle section\n")];
        let d = check(&[(REGISTRY_PATH, REGISTRY_FIXTURE)], &docs);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 6, "cluster_a's `id:` line is line 6");
    }

    #[test]
    fn missing_docs_are_drift_when_the_registry_exists() {
        let d = check(&[(REGISTRY_PATH, REGISTRY_FIXTURE)], &[]);
        assert!(d.iter().any(|x| x.message.contains("EXPERIMENTS.md is missing")));
        assert!(d.iter().any(|x| x.message.contains("DESIGN.md is missing")));
        assert!(check(&[("rust/src/other.rs", "fn f() {}\n")], &[]).is_empty());
    }

    #[test]
    fn lifecycle_enum_and_state_drift_are_flagged() {
        let docs = [
            ("DESIGN.md", DESIGN_FIXTURE),
            ("EXPERIMENTS.md", "fig1 cluster_a\n"),
        ];
        let d = check(&[(REGISTRY_PATH, REGISTRY_FIXTURE), ("rust/src/e.rs", ENUM_FIXTURE)], &docs);
        let msgs: Vec<String> = d.iter().map(|x| x.to_string()).collect();
        assert_eq!(d.len(), 1, "{msgs:?}");
        assert!(msgs[0].contains("`Gone`"), "{msgs:?}");

        let no_enum = check(&[(REGISTRY_PATH, REGISTRY_FIXTURE)], &docs);
        assert!(
            no_enum.iter().any(|x| x.message.contains("`BarState`")),
            "missing enum is drift: {no_enum:?}"
        );
    }

    #[test]
    fn lifecycle_event_columns_are_checked_too() {
        // `from`/`to` states exist; the `Zap` event in the middle column
        // does not — all columns of a transition table are live.
        let design = "# Doc\n\n\
            ## Lifecycles and state machines\n\n\
            | from | event | to |\n\
            |---|---|---|\n\
            | `Alpha` | `Zap` | `Alpha` |\n\n\
            ## Next section\n";
        let d = check(
            &[(REGISTRY_PATH, REGISTRY_FIXTURE), ("rust/src/e.rs", ENUM_FIXTURE)],
            &[("DESIGN.md", design), ("EXPERIMENTS.md", "fig1 cluster_a\n")],
        );
        let msgs: Vec<String> = d.iter().map(|x| x.to_string()).collect();
        assert_eq!(d.len(), 1, "{msgs:?}");
        assert!(msgs[0].contains("`Zap`"), "{msgs:?}");
        assert!(msgs[0].contains("lifecycle state/event"), "{msgs:?}");
    }

    #[test]
    fn request_serving_section_tables_are_checked_too() {
        let design = "# Doc\n\n\
            ## Request serving & SLO model\n\n\
            ### Request lifecycle (`foo::BarState`)\n\n\
            | from | event | to |\n\
            |---|---|---|\n\
            | `Alpha` | `Zap` | `Alpha` |\n\n\
            ## Next section\n";
        let d = check(
            &[(REGISTRY_PATH, REGISTRY_FIXTURE), ("rust/src/e.rs", ENUM_FIXTURE)],
            &[("DESIGN.md", design), ("EXPERIMENTS.md", "fig1 cluster_a\n")],
        );
        let msgs: Vec<String> = d.iter().map(|x| x.to_string()).collect();
        assert_eq!(d.len(), 1, "{msgs:?}");
        assert!(msgs[0].contains("`Zap`"), "{msgs:?}");

        let no_enum = check(
            &[(REGISTRY_PATH, REGISTRY_FIXTURE)],
            &[("DESIGN.md", design), ("EXPERIMENTS.md", "fig1 cluster_a\n")],
        );
        assert!(
            no_enum.iter().any(|x| x.message.contains("`BarState`")),
            "enum named only in the request section is still checked: {no_enum:?}"
        );
    }

    #[test]
    fn observability_event_kinds_must_exist_in_source() {
        let design = "# Doc\n\n\
            ## Observability\n\n\
            | event | meaning |\n\
            |---|---|\n\
            | `PageMove` | migration span |\n\
            | `Vanished` | removed long ago |\n\n\
            ## Next section\n";
        let d = check(
            &[
                (REGISTRY_PATH, REGISTRY_FIXTURE),
                ("rust/src/t.rs", "pub enum EventKind { PageMove }\n"),
            ],
            &[("DESIGN.md", design), ("EXPERIMENTS.md", "fig1 cluster_a\n")],
        );
        let msgs: Vec<String> = d.iter().map(|x| x.to_string()).collect();
        assert_eq!(d.len(), 1, "{msgs:?}");
        assert!(msgs[0].contains("`Vanished`"), "{msgs:?}");
        assert!(msgs[0].contains("observability event kind"), "{msgs:?}");
    }
}
