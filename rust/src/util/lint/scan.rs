//! Line-level Rust source scanner for the lint rules.
//!
//! The rules match *tokens* on *code*, so the scanner's job is to blank
//! out everything that is not code: line comments, (nested) block
//! comments, string literals, raw strings, byte strings, and char
//! literals.  Delimiters are kept so column positions stay meaningful;
//! the blanked regions become spaces.  Comment text is collected
//! separately, per line, because that is where `lint:` attestations
//! live.
//!
//! This is a scanner, not a parser: it tracks just enough state to know
//! whether a byte is code, comment, or literal.  The subtle cases it
//! handles are nested `/* /* */ */` comments, `r#"…"#` raw strings with
//! arbitrary hash counts, `b"…"`/`b'…'` byte literals, escaped quotes,
//! and the `'a'`-char vs `'a`-lifetime ambiguity (a quote starts a char
//! literal only if the next char is a backslash or the char after next
//! is a closing quote).

/// A file split into parallel per-line views: `code` with all comment
/// and literal *contents* blanked to spaces, and `comments` holding the
/// comment text of each line.
pub struct Scanned {
    pub code: Vec<String>,
    pub comments: Vec<String>,
}

enum St {
    Code,
    LineComment,
    /// Block comment with nesting depth.
    Block(u32),
    Str,
    /// Raw string terminated by `"` followed by this many `#`s.
    RawStr(u8),
    Char,
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

pub fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `'` at `i` starts a char literal (vs a lifetime) iff the next char is
/// a backslash or the char after next is the closing quote.
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Parse `r"`, `r#"`, `br"`, … at `i`; returns (hash count, chars consumed).
fn raw_str_open(chars: &[char], i: usize) -> Option<(u8, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u8;
    while chars.get(j) == Some(&'#') && hashes < u8::MAX {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

fn hashes_after(chars: &[char], i: usize, want: u8) -> bool {
    (0..want as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Scan `text` into blanked code lines and comment lines.  The two
/// vectors always have the same length as `text.lines()` would produce.
pub fn strip(text: &str) -> Scanned {
    let chars: Vec<char> = text.chars().collect();
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut cl = String::new();
    let mut cm = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            code.push(std::mem::take(&mut cl));
            comments.push(std::mem::take(&mut cm));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    cl.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == '\'' && is_char_literal(&chars, i) {
                    cl.push('\'');
                    st = St::Char;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((hashes, skip)) = raw_str_open(&chars, i) {
                        cl.push('"');
                        st = St::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && next == Some('"') {
                        cl.push_str("b\"");
                        st = St::Str;
                        i += 2;
                    } else if c == 'b' && next == Some('\'') && is_char_literal(&chars, i + 1) {
                        cl.push_str("b'");
                        st = St::Char;
                        i += 2;
                    } else {
                        cl.push(c);
                        i += 1;
                    }
                } else {
                    cl.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cm.push(c);
                i += 1;
            }
            St::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    i += 2;
                } else {
                    cm.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && chars.get(i + 1).is_some_and(|&n| n != '\n') {
                    i += 2;
                } else if c == '"' {
                    cl.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    cl.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && hashes_after(&chars, i + 1, hashes) {
                    cl.push('"');
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    cl.push(' ');
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' && chars.get(i + 1).is_some_and(|&n| n != '\n') {
                    i += 2;
                } else if c == '\'' {
                    cl.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    cl.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !cl.is_empty() || !cm.is_empty() {
        code.push(cl);
        comments.push(cm);
    }
    Scanned { code, comments }
}

/// First token-boundary occurrence of `pat` in `line`: the characters
/// immediately before and after the match must not be identifier chars,
/// so `HashMap` does not match inside `FxHashMap`.  `pat` must be
/// non-empty ASCII.
pub fn find_token(line: &str, pat: &str) -> Option<usize> {
    debug_assert!(!pat.is_empty() && pat.is_ascii());
    let mut start = 0;
    while let Some(pos) = line[start..].find(pat) {
        let at = start + pos;
        let end = at + pat.len();
        let before_ok = at == 0 || !line[..at].chars().next_back().is_some_and(is_ident_char);
        let after_ok = end >= line.len() || !line[end..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return Some(at);
        }
        start = end;
    }
    None
}

pub fn has_token(line: &str, pat: &str) -> bool {
    find_token(line, pat).is_some()
}

/// All token-boundary occurrences of `pat` in `line`.
pub fn token_positions(line: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut start = 0;
    while start < line.len() {
        match find_token(&line[start..], pat) {
            Some(pos) => {
                out.push(start + pos);
                start += pos + pat.len();
            }
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        strip(text).code
    }

    #[test]
    fn line_comments_are_blanked_and_collected() {
        let s = strip("let x = 1; // HashMap here\nlet y = 2;\n");
        assert_eq!(s.code[0], "let x = 1; ");
        assert_eq!(s.comments[0], " HashMap here");
        assert_eq!(s.code[1], "let y = 2;");
        assert_eq!(s.comments[1], "");
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let s = strip("a /* one /* two */ still */ b\nc /* open\nmore */ d\n");
        assert_eq!(s.code[0], "a  b");
        assert_eq!(s.code[1], "c ");
        assert_eq!(s.code[2], " d");
        assert!(s.comments[1].contains("open"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let s = strip("let s = \"HashMap // not a comment\";\n");
        assert!(!s.code[0].contains("HashMap"));
        assert!(s.code[0].starts_with("let s = \""));
        assert_eq!(s.comments[0], "");
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let c = code_of("let s = \"a\\\"b\"; let t = 1;\n");
        assert!(c[0].contains("let t = 1;"));
        assert!(!c[0].contains('a'));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = code_of("let s = r#\"quote \" inside HashMap\"# + r\"x\";\n");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains('+'));
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let c = code_of("let a = b\"HashMap\"; let b = b'x'; let k = br\"y\";\n");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("let b = b'"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let c = code_of("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'z'; let n = '\\n';\n");
        assert!(c[0].contains("&'a str"));
        assert!(!c[1].contains('z'));
        assert!(c[1].contains("let n = '"));
    }

    #[test]
    fn identifier_ending_in_r_does_not_open_raw_string() {
        let c = code_of("let hasher = mixer(\"k\");\n");
        assert!(c[0].contains("let hasher = mixer(\"") && c[0].contains("\");"));
    }

    #[test]
    fn line_counts_match_lines() {
        for text in ["", "a", "a\n", "a\nb", "a\n\n", "/* x\ny */\n"] {
            let s = strip(text);
            assert_eq!(s.code.len(), text.lines().count(), "text {text:?}");
            assert_eq!(s.comments.len(), text.lines().count(), "text {text:?}");
        }
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("use std::collections::HashMap;", "HashMap"));
        assert!(!has_token("use crate::util::hash::FxHashMap;", "HashMap"));
        assert!(!has_token("let map_x = HashMapLike::new();", "HashMap"));
        assert!(has_token("a.iter()", "a.iter()"));
        assert_eq!(find_token("xx HashMap xx HashMap", "HashMap"), Some(3));
        assert_eq!(token_positions("HashMap + HashMap", "HashMap"), vec![0, 10]);
    }
}
