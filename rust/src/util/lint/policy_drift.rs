//! R6: policy-registry/doc drift.
//!
//! `rust/src/policy/mod.rs` holds the three policy tables (`REGISTRY`,
//! `RECOVERY`, `SHARING`) and `rust/src/policy/adaptive.rs` the
//! closed-loop control-law table (`CONTROL_LAWS`), each entry carrying a
//! literal `id: "..."` field; DESIGN.md's "Policy registry" section
//! documents every id in its tables' first columns.  R6 keeps the two
//! in sync in both directions:
//!
//! * every id registered in the policy file appears backticked in the
//!   first column of a table row under the "Policy registry" heading;
//! * every id-shaped backticked token in those first columns names a
//!   registered policy (stale doc rows are drift too).
//!
//! Like R4, the rule anchors on its registry file: fixture repos
//! without `rust/src/policy/mod.rs` are skipped entirely.

use super::drift::{backtick_spans, doc_section, registry_ids};
use super::{Diagnostic, Repo, Rule, R6};

const POLICY_PATH: &str = "rust/src/policy/mod.rs";
/// Control-law registry; optional (older fixture repos lack it), but
/// scanned with the same both-direction contract when present.
const ADAPTIVE_PATH: &str = "rust/src/policy/adaptive.rs";
const POLICY_HEADING: &str = "## Policy registry";

pub struct PolicyDrift;

/// Does `tok` look like a policy id?  Ids are lowercase CLI spellings
/// (`daemon`, `cache-line+page`, `work-conserving`); anything starting
/// with an ASCII lowercase letter and built from `[a-z0-9+-]` is
/// claimed, which skips flag spellings like `--scheme` and code paths.
fn id_like(tok: &str) -> bool {
    tok.starts_with(|c: char| c.is_ascii_lowercase())
        && tok
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-' || c == '+')
}

/// `(span, 1-based line)` for every id-like backticked token in the
/// first column of the section's table rows.
fn doc_ids<'a>(section: &[(usize, &'a str)]) -> Vec<(&'a str, usize)> {
    let mut out = Vec::new();
    for (line_no, line) in section {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        let Some(first) = line.split('|').nth(1) else { continue };
        for span in backtick_spans(first) {
            if id_like(span) {
                out.push((span, *line_no));
            }
        }
    }
    out
}

impl Rule for PolicyDrift {
    fn id(&self) -> &'static str {
        R6
    }

    fn summary(&self) -> &'static str {
        "policy registry ids and the DESIGN.md policy tables stay in sync"
    }

    fn explain(&self) -> &'static str {
        "rust/src/policy/mod.rs is the single source of movement / recovery / sharing\n\
         policies (and policy/adaptive.rs of the closed-loop control laws), and\n\
         DESIGN.md \"Policy registry\" is their user-facing contract.  R6 checks both\n\
         directions: every `id: \"...\"` literal in the policy files must appear\n\
         backticked in the first column of a table row under the \"Policy registry\"\n\
         heading, and every id-shaped backticked token in those first columns must\n\
         name a registered policy or control law.  Fix by adding the missing doc\n\
         row, registering the policy, or deleting the stale row."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        let Some(reg) = repo.file(POLICY_PATH) else { return };
        // (id, source line, source path) across both registry files.
        let mut ids: Vec<(String, usize, &'static str)> =
            registry_ids(reg).into_iter().map(|(id, l)| (id, l, POLICY_PATH)).collect();
        if let Some(laws) = repo.file(ADAPTIVE_PATH) {
            ids.extend(registry_ids(laws).into_iter().map(|(id, l)| (id, l, ADAPTIVE_PATH)));
        }

        let Some(design) = repo.doc("DESIGN.md") else {
            let msg = "DESIGN.md is missing".to_string();
            out.push(Diagnostic::new(POLICY_PATH, 1, R6, msg));
            return;
        };
        let section = doc_section(design, POLICY_HEADING);
        if section.is_empty() {
            let msg = format!("DESIGN.md has no `{POLICY_HEADING}` section");
            out.push(Diagnostic::new(POLICY_PATH, 1, R6, msg));
            return;
        }
        let documented = doc_ids(&section);
        for (id, line, path) in &ids {
            if !documented.iter().any(|(d, _)| d == id) {
                let msg = format!(
                    "policy id `{id}` is not documented in DESIGN.md's policy tables"
                );
                out.push(Diagnostic::new(path, *line, R6, msg));
            }
        }
        for (doc_id, line) in &documented {
            if !ids.iter().any(|(id, _, _)| id == doc_id) {
                let msg = format!(
                    "`{doc_id}` is in a DESIGN.md policy table but not in the policy \
                     registry"
                );
                out.push(Diagnostic::new("DESIGN.md", *line, R6, msg));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const POLICY_FIXTURE: &str = "pub static REGISTRY: [MovementDef; 2] = [\n\
        MovementDef {\n\
        id: \"local\",\n\
        },\n\
        MovementDef {\n\
        id: \"cache-line+page\",\n\
        },\n\
        ];\n";

    const DESIGN_FIXTURE: &str = "# Doc\n\n\
        ## Policy registry\n\n\
        | id | scheme |\n\
        |---|---|\n\
        | `local` | Local |\n\
        | `cache-line+page` | both granularities, `naive` alias aside |\n\n\
        ## Next section\n";

    fn check(files: &[(&str, &str)], docs: &[(&str, &str)]) -> Vec<Diagnostic> {
        let repo = Repo::from_fixtures(files, docs);
        let mut out = Vec::new();
        PolicyDrift.check(&repo, &mut out);
        out
    }

    #[test]
    fn clean_fixture_passes() {
        let d = check(&[(POLICY_PATH, POLICY_FIXTURE)], &[("DESIGN.md", DESIGN_FIXTURE)]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn repos_without_the_policy_file_are_skipped() {
        let d = check(&[("rust/src/other.rs", "fn f() {}\n")], &[]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn undocumented_registry_id_is_flagged_at_its_source_line() {
        let design = "## Policy registry\n\n| id |\n|---|\n| `local` |\n";
        let d = check(&[(POLICY_PATH, POLICY_FIXTURE)], &[("DESIGN.md", design)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, R6);
        assert_eq!(d[0].path, POLICY_PATH);
        assert_eq!(d[0].line, 6, "`cache-line+page`'s id: line");
        assert!(d[0].message.contains("`cache-line+page`"), "{d:?}");
    }

    #[test]
    fn stale_doc_row_is_flagged_at_the_doc_line() {
        let design = "## Policy registry\n\n\
            | id |\n|---|\n| `local` |\n| `cache-line+page` |\n| `ghost` |\n";
        let d = check(&[(POLICY_PATH, POLICY_FIXTURE)], &[("DESIGN.md", design)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].path, "DESIGN.md");
        assert_eq!(d[0].line, 7);
        assert!(d[0].message.contains("`ghost`"), "{d:?}");
        // Non-id spans in later columns (prose, `naive` alias notes) and
        // uppercase names are never claimed as ids.
        assert!(!DESIGN_FIXTURE.is_empty());
    }

    const LAWS_FIXTURE: &str = "pub static CONTROL_LAWS: [ControlLawDef; 1] = [\n\
        ControlLawDef {\n\
        id: \"ratio-tune\",\n\
        },\n\
        ];\n";

    #[test]
    fn control_law_ids_are_drift_checked_both_directions() {
        // Undocumented law → flagged at its line in the adaptive file.
        let d = check(
            &[(POLICY_PATH, POLICY_FIXTURE), (ADAPTIVE_PATH, LAWS_FIXTURE)],
            &[("DESIGN.md", DESIGN_FIXTURE)],
        );
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].path, ADAPTIVE_PATH);
        assert_eq!(d[0].line, 3, "`ratio-tune`'s id: line");
        assert!(d[0].message.contains("`ratio-tune`"), "{d:?}");
        // A doc row naming the law clears it; a law-only doc row without
        // the registration would be stale drift.
        let design = DESIGN_FIXTURE.replace(
            "\n## Next section",
            "| `ratio-tune` | closed loop |\n\n## Next section",
        );
        let wrong_design = design.replace("ratio-tune", "ratio-tunee");
        let d = check(
            &[(POLICY_PATH, POLICY_FIXTURE), (ADAPTIVE_PATH, LAWS_FIXTURE)],
            &[("DESIGN.md", &design)],
        );
        assert!(d.is_empty(), "{d:?}");
        let d = check(
            &[(POLICY_PATH, POLICY_FIXTURE), (ADAPTIVE_PATH, LAWS_FIXTURE)],
            &[("DESIGN.md", &wrong_design)],
        );
        assert_eq!(d.len(), 2, "stale doc row + undocumented law: {d:?}");
    }

    #[test]
    fn missing_doc_or_section_is_drift_when_the_registry_exists() {
        let d = check(&[(POLICY_PATH, POLICY_FIXTURE)], &[]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("DESIGN.md is missing"), "{d:?}");
        let d = check(&[(POLICY_PATH, POLICY_FIXTURE)], &[("DESIGN.md", "# Doc\nno tables\n")]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("has no `## Policy registry` section"), "{d:?}");
    }
}
