//! `daemon-lint`: the repo's zero-dependency static-analysis pass.
//!
//! The simulator's correctness story rests on determinism rules and
//! lifecycle/wire invariants that used to live only as prose in
//! DESIGN.md.  This module makes them executable: a line/token-level
//! scanner (`scan`) feeds five rules, each a small struct implementing
//! [`Rule`]:
//!
//! * `R1-rand-state` — no `std::collections` hash maps/sets with the
//!   default `RandomState` outside an allowlist (`rules::RandState`);
//! * `R2-wall-clock` — no wall-clock or environment entropy in
//!   simulation code (`rules::WallClock`);
//! * `R3-unordered-iter` — no unattested iteration over unordered maps
//!   in files that feed `Metrics` or JSON (`rules::UnorderedIter`);
//! * `R4-doc-drift` — registry ids, lifecycle enums and transition-table
//!   states/events stay in sync with EXPERIMENTS.md / DESIGN.md
//!   (`drift::DocDrift`);
//! * `R5-wire-drift` — the shard wire format matches the committed
//!   golden manifest (`wire::WireDrift`);
//! * `R6-policy-drift` — every `policy::REGISTRY` id is documented in
//!   DESIGN.md's "Policy registry" tables and vice versa
//!   (`policy_drift::PolicyDrift`).
//!
//! Violations can be waived in place with comment attestations:
//! `// lint: sorted` attests that an iteration on the next (or same)
//! line is order-independent or explicitly sorted before it reaches
//! output, and `// lint: allow(R1): <reason>` waives a named rule with
//! a written justification.  Attestations without a reason, unknown
//! directives, and unknown rule ids are themselves diagnostics, so the
//! waiver surface stays auditable.
//!
//! The `daemon-lint` binary (`rust/src/bin/lint.rs`) drives this over
//! `rust/src`, `rust/tests`, and `benches`, and CI runs it as a
//! required gate.  See DESIGN.md §"Static analysis & invariant
//! enforcement" for the policy discussion.

pub mod drift;
pub mod policy_drift;
pub mod rules;
pub mod scan;
pub mod wire;
pub mod wire_manifest;

use std::fmt;
use std::path::{Path, PathBuf};

/// Rule ids, in report order.  The short form (`R1`) is accepted
/// anywhere a rule id is named (attestations, `--explain`).
pub const R1: &str = "R1-rand-state";
pub const R2: &str = "R2-wall-clock";
pub const R3: &str = "R3-unordered-iter";
pub const R4: &str = "R4-doc-drift";
pub const R5: &str = "R5-wire-drift";
pub const R6: &str = "R6-policy-drift";
/// Pseudo-rule id for malformed attestation directives.
pub const ATTEST: &str = "attest";

const RULE_IDS: [&str; 6] = [R1, R2, R3, R4, R5, R6];

/// Directories scanned for `.rs` files, relative to the repo root.
pub const SCAN_ROOTS: [&str; 3] = ["rust/src", "rust/tests", "benches"];

/// Markdown files the drift rules read, relative to the repo root.
pub const DOC_FILES: [&str; 2] = ["DESIGN.md", "EXPERIMENTS.md"];

/// Resolve a rule name (full id or short `R<n>` form) to its canonical
/// id.
pub fn canonical_rule(name: &str) -> Option<&'static str> {
    RULE_IDS
        .iter()
        .find(|id| **id == name || id.split('-').next() == Some(name))
        .copied()
}

/// One finding, rendered as `path:line: rule-id message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn new(path: &str, line: usize, rule: &'static str, message: String) -> Self {
        Self { path: path.to_string(), line, rule, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} {}", self.path, self.line, self.rule, self.message)
    }
}

/// Attestations attached to one source line.
#[derive(Clone, Debug, Default)]
pub struct Marks {
    /// `// lint: sorted` — iteration here is order-independent or
    /// sorted before it reaches output.
    pub sorted: bool,
    /// Canonical rule ids waived by `// lint: allow(...): reason`.
    pub allow: Vec<&'static str>,
}

impl Marks {
    fn any(&self) -> bool {
        self.sorted || !self.allow.is_empty()
    }

    fn merge(&mut self, other: &Marks) {
        self.sorted |= other.sorted;
        for id in &other.allow {
            if !self.allow.contains(id) {
                self.allow.push(id);
            }
        }
    }
}

/// One scanned source file: raw lines, comment/literal-blanked code
/// lines, and per-line attestation marks.
pub struct SourceFile {
    /// Repo-relative path with `/` separators.
    pub path: String,
    pub raw: Vec<String>,
    pub code: Vec<String>,
    marks: Vec<Marks>,
    /// Diagnostics for malformed attestation directives.
    attest: Vec<Diagnostic>,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let scanned = scan::strip(text);
        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let n = raw.len();
        let mut code = scanned.code;
        let mut comments = scanned.comments;
        code.resize(n, String::new());
        comments.resize(n, String::new());
        let mut marks: Vec<Marks> = vec![Marks::default(); n];
        let mut attest = Vec::new();
        for (i, (cm, mk)) in comments.iter().zip(marks.iter_mut()).enumerate() {
            parse_directive(path, i, cm, mk, &mut attest);
        }
        // A directive on a comment-only line attests the next line, so
        // an attestation can sit above the statement it waives.
        for i in 1..n {
            if code[i - 1].trim().is_empty() && marks[i - 1].any() {
                let prev = marks[i - 1].clone();
                marks[i].merge(&prev);
            }
        }
        SourceFile { path: path.to_string(), raw, code, marks, attest }
    }

    /// Is `rule` waived on 0-based line `line0`?
    pub fn allows(&self, line0: usize, rule: &str) -> bool {
        self.marks.get(line0).is_some_and(|m| m.allow.iter().any(|a| *a == rule))
    }

    /// Does 0-based line `line0` carry a `sorted` attestation?
    pub fn sorted_ok(&self, line0: usize) -> bool {
        self.marks.get(line0).is_some_and(|m| m.sorted)
    }
}

fn parse_directive(
    path: &str,
    line0: usize,
    comment: &str,
    marks: &mut Marks,
    out: &mut Vec<Diagnostic>,
) {
    let t = comment.trim_start_matches(['!', '*', ' ', '\t']).trim_end();
    let Some(rest) = t.strip_prefix("lint:") else { return };
    let rest = rest.trim();
    if let Some(after) = rest.strip_prefix("sorted") {
        if after.starts_with(scan::is_ident_char) {
            let msg = format!("unknown directive `{rest}`");
            out.push(Diagnostic::new(path, line0 + 1, ATTEST, msg));
        } else {
            marks.sorted = true;
        }
        return;
    }
    if let Some(body) = rest.strip_prefix("allow(") {
        let Some(close) = body.find(')') else {
            let msg = "unclosed `allow(` in attestation".to_string();
            out.push(Diagnostic::new(path, line0 + 1, ATTEST, msg));
            return;
        };
        let reason = body[close + 1..].trim_start_matches([':', ' ', '\t']).trim();
        if reason.is_empty() {
            let msg = "allow() needs a written justification after the rule list".to_string();
            out.push(Diagnostic::new(path, line0 + 1, ATTEST, msg));
        }
        for name in body[..close].split(',') {
            let name = name.trim();
            match canonical_rule(name) {
                Some(id) => marks.allow.push(id),
                None => {
                    let msg = format!("unknown rule id `{name}` in allow()");
                    out.push(Diagnostic::new(path, line0 + 1, ATTEST, msg));
                }
            }
        }
        return;
    }
    let msg = format!("unknown directive `{rest}`");
    out.push(Diagnostic::new(path, line0 + 1, ATTEST, msg));
}

/// The scanned tree the rules run over.
pub struct Repo {
    pub files: Vec<SourceFile>,
    /// `(repo-relative path, text)` for each doc file found.
    pub docs: Vec<(String, String)>,
}

impl Repo {
    /// Scan `SCAN_ROOTS` and `DOC_FILES` under `root`.  Missing roots
    /// are skipped (fixture trees); unreadable files are errors.
    pub fn load(root: &Path) -> Result<Repo, String> {
        let mut files = Vec::new();
        for sub in SCAN_ROOTS {
            let dir = root.join(sub);
            if !dir.is_dir() {
                continue;
            }
            let mut paths = Vec::new();
            walk(&dir, &mut paths)?;
            for p in paths {
                let text = std::fs::read_to_string(&p)
                    .map_err(|e| format!("read {}: {e}", p.display()))?;
                let rel = p.strip_prefix(root).unwrap_or(&p).to_string_lossy().replace('\\', "/");
                files.push(SourceFile::parse(&rel, &text));
            }
        }
        let mut docs = Vec::new();
        for name in DOC_FILES {
            if let Ok(text) = std::fs::read_to_string(root.join(name)) {
                docs.push((name.to_string(), text));
            }
        }
        Ok(Repo { files, docs })
    }

    /// Build a repo from in-memory fixtures (rule unit tests).
    pub fn from_fixtures(files: &[(&str, &str)], docs: &[(&str, &str)]) -> Repo {
        Repo {
            files: files.iter().map(|(p, t)| SourceFile::parse(p, t)).collect(),
            docs: docs.iter().map(|(p, t)| (p.to_string(), t.to_string())).collect(),
        }
    }

    pub fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }

    pub fn doc(&self, path: &str) -> Option<&str> {
        self.docs.iter().find(|(p, _)| p == path).map(|(_, t)| t.as_str())
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in rd {
        entries.push(e.map_err(|e| format!("read {}: {e}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// A lint rule: an id, a one-line summary, a DESIGN.md-backed rationale
/// for `--explain`, and the check itself.
pub trait Rule {
    fn id(&self) -> &'static str;
    fn summary(&self) -> &'static str;
    fn explain(&self) -> &'static str;
    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>);
}

/// All rules, in report order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(rules::RandState),
        Box::new(rules::WallClock),
        Box::new(rules::UnorderedIter),
        Box::new(drift::DocDrift),
        Box::new(wire::WireDrift),
        Box::new(policy_drift::PolicyDrift),
    ]
}

/// Run every rule plus the attestation checks; diagnostics are sorted
/// by `(path, line, rule)` so output order is deterministic.
pub fn run(repo: &Repo) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in &repo.files {
        out.extend(f.attest.iter().cloned());
    }
    for rule in all_rules() {
        rule.check(repo, &mut out);
    }
    out.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagnostic_renders_file_line_rule() {
        let d = Diagnostic::new("rust/src/x.rs", 7, R1, "msg".to_string());
        assert_eq!(d.to_string(), "rust/src/x.rs:7: R1-rand-state msg");
    }

    #[test]
    fn canonical_rule_accepts_short_and_full_ids() {
        assert_eq!(canonical_rule("R1"), Some(R1));
        assert_eq!(canonical_rule("R3-unordered-iter"), Some(R3));
        assert_eq!(canonical_rule("R9"), None);
        assert_eq!(canonical_rule("sorted"), None);
    }

    #[test]
    fn sorted_attestation_marks_same_and_next_line() {
        let f = SourceFile::parse(
            "f.rs",
            "// lint: sorted\nfor k in m.keys() {}\nx.iter(); // lint: sorted\n",
        );
        assert!(f.sorted_ok(1), "comment-only directive reaches the next line");
        assert!(f.sorted_ok(2), "trailing directive marks its own line");
        assert!(f.attest.is_empty(), "well-formed directives produce no diagnostics");
    }

    #[test]
    fn allow_requires_reason_and_known_rule() {
        let f = SourceFile::parse("f.rs", "// lint: allow(R1)\nlet x = 1;\n");
        assert_eq!(f.attest.len(), 1);
        assert_eq!(f.attest[0].rule, ATTEST);
        assert!(f.allows(1, R1), "rule is still parsed so the waiver is visible");

        let f = SourceFile::parse("f.rs", "// lint: allow(R7): because\nlet x = 1;\n");
        assert_eq!(f.attest.len(), 1);
        assert!(f.attest[0].message.contains("unknown rule id"));

        let f = SourceFile::parse("f.rs", "// lint: allow(R1, R2): trusted site\nlet x = 1;\n");
        assert!(f.attest.is_empty());
        assert!(f.allows(1, R1) && f.allows(1, R2) && !f.allows(1, R3));
    }

    #[test]
    fn unknown_directives_are_flagged() {
        let f = SourceFile::parse("f.rs", "// lint: sortedish\n// lint: frobnicate\n");
        assert_eq!(f.attest.len(), 2);
        assert!(f.attest.iter().all(|d| d.rule == ATTEST));
    }

    #[test]
    fn directives_inside_strings_are_ignored() {
        let f = SourceFile::parse("f.rs", "let s = \"// lint: frobnicate\";\n");
        assert!(f.attest.is_empty());
    }

    #[test]
    fn prose_comments_mentioning_the_word_lint_are_not_directives() {
        let f = SourceFile::parse("f.rs", "// daemon-lint: the repo's analysis pass\n");
        assert!(f.attest.is_empty());
    }

    #[test]
    fn meta_lint_repo_is_clean_at_head() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let repo = Repo::load(root).expect("scan repo");
        assert!(repo.files.len() > 30, "scanned {} files", repo.files.len());
        let diags = run(&repo);
        let rendered: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
        assert!(diags.is_empty(), "daemon-lint is not clean:\n{}", rendered.join("\n"));
    }
}
