//! Determinism rules R1–R3: hashing, entropy, and iteration order.
//!
//! These are the executable form of DESIGN.md §"Simulator performance
//! model"'s determinism rules: results must be pure functions of
//! `(config, seed)`, which forbids randomized hash seeds, wall-clock or
//! environment reads, and unordered-map iteration on any path that can
//! reach `Metrics` or JSON output.

use super::{scan, Diagnostic, Repo, Rule, SourceFile, R1, R2, R3};

/// Files where default-`RandomState` collections are sanctioned.  Keep
/// this list short: the only legitimate site is the module that wraps
/// `std`'s maps with the deterministic Fx hasher.
const R1_ALLOWED_FILES: [&str; 1] = ["rust/src/util/hash.rs"];

/// Path prefixes where wall-clock / environment reads are sanctioned:
/// CLI timing and bench harness plumbing, never simulation code.
const R2_ALLOWED_PREFIXES: [&str; 3] = ["rust/src/main.rs", "rust/src/bin/", "benches/"];

const R2_TOKENS: [&str; 8] = [
    "Instant::now",
    "SystemTime",
    "env::var",
    "env::var_os",
    "env::vars",
    "env::vars_os",
    "env::args",
    "env::args_os",
];

/// R1: no `std::collections` hash maps/sets with the default
/// (per-process randomized) `RandomState`.
pub struct RandState;

fn r1_match(line: &str) -> Option<String> {
    let std_path = scan::has_token(line, "std::collections");
    for base in ["HashMap", "HashSet"] {
        if std_path && scan::has_token(line, base) {
            return Some(format!("std::collections::{base}"));
        }
        for ctor in ["new", "with_capacity", "from"] {
            let pat = format!("{base}::{ctor}");
            if scan::has_token(line, &pat) {
                return Some(pat);
            }
        }
    }
    None
}

impl Rule for RandState {
    fn id(&self) -> &'static str {
        R1
    }

    fn summary(&self) -> &'static str {
        "no std hash collections with the default RandomState"
    }

    fn explain(&self) -> &'static str {
        "DESIGN.md, determinism rules (\"Simulator performance model\"): results must be\n\
         pure functions of (config, seed).  std::collections::HashMap/HashSet seed\n\
         SipHash with per-process random state, so capacity history, iteration order,\n\
         and anything derived from them varies run to run.  Use util::hash::FxHashMap /\n\
         FxHashSet (deterministic, seedless, and faster on the simulator's small fixed\n\
         keys).  The only sanctioned site is rust/src/util/hash.rs, which defines those\n\
         aliases; anything else needs a `lint: allow(R1): <reason>` attestation."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        for f in &repo.files {
            if R1_ALLOWED_FILES.contains(&f.path.as_str()) {
                continue;
            }
            for (i, line) in f.code.iter().enumerate() {
                if f.allows(i, R1) {
                    continue;
                }
                if let Some(tok) = r1_match(line) {
                    let msg = format!(
                        "`{tok}` uses the nondeterministic default RandomState; \
                         use `util::hash::FxHashMap`/`FxHashSet`"
                    );
                    out.push(Diagnostic::new(&f.path, i + 1, R1, msg));
                }
            }
        }
    }
}

/// R2: no wall-clock or environment entropy in simulation code.
pub struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        R2
    }

    fn summary(&self) -> &'static str {
        "no wall-clock or environment reads in simulation code"
    }

    fn explain(&self) -> &'static str {
        "DESIGN.md, determinism rules (\"Simulator performance model\"): simulated time\n\
         is driven by the event clock, never the host.  Instant::now/SystemTime and\n\
         env reads make results depend on the machine and the moment, which breaks\n\
         byte-identity across runs and across the sharded sweep merge.  Sanctioned\n\
         sites are CLI timing in rust/src/main.rs, the lint binary under rust/src/bin/,\n\
         and the bench harness under benches/ (host metadata in bench JSON is the\n\
         point there); anything else needs a `lint: allow(R2): <reason>` attestation."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        for f in &repo.files {
            if R2_ALLOWED_PREFIXES.iter().any(|p| f.path.starts_with(p)) {
                continue;
            }
            for (i, line) in f.code.iter().enumerate() {
                if f.allows(i, R2) {
                    continue;
                }
                if let Some(tok) = R2_TOKENS.iter().find(|t| scan::has_token(line, t)) {
                    let msg = format!(
                        "`{tok}` injects wall-clock/environment entropy; simulation \
                         results must be pure functions of (config, seed)"
                    );
                    out.push(Diagnostic::new(&f.path, i + 1, R2, msg));
                }
            }
        }
    }
}

/// R3: no unattested iteration over unordered maps in files that feed
/// `Metrics` or JSON output.
pub struct UnorderedIter;

const ITER_CALLS: [&str; 10] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
    ".retain(",
];

fn feeds_output(f: &SourceFile) -> bool {
    f.code.iter().any(|l| scan::has_token(l, "Metrics") || scan::has_token(l, "Json"))
}

/// Extract the declared identifier to the left of a map type token:
/// `let mut stamp: FxHashMap<..>`, `stamp = FxHashMap::default()`, and
/// struct fields / fn params (`cache: FxHashMap<..>`).  Returns `None`
/// for type positions that declare nothing (`Vec<FxHashMap<..>>`,
/// `-> FxHashMap<..>`, `use` paths).
fn decl_name(before: &str) -> Option<String> {
    let mut s = before.trim_end();
    loop {
        if let Some(rest) = s.strip_suffix('&') {
            s = rest.trim_end();
        } else if let Some(rest) = s.strip_suffix("mut") {
            if rest.ends_with(scan::is_ident_char) {
                break;
            }
            s = rest.trim_end();
        } else {
            break;
        }
    }
    let s = if let Some(rest) = s.strip_suffix(':') {
        // A `::` path segment declares nothing.
        if rest.ends_with(':') {
            return None;
        }
        rest
    } else if let Some(rest) = s.strip_suffix('=') {
        // Comparison / arrow operators are not bindings.
        if rest.ends_with(['=', '<', '>', '!', '+', '-', '*', '/']) {
            return None;
        }
        rest
    } else {
        return None;
    };
    let s = s.trim_end();
    let tail: Vec<char> = s.chars().rev().take_while(|c| scan::is_ident_char(*c)).collect();
    let name: String = tail.into_iter().rev().collect();
    let first = name.chars().next()?;
    if first.is_ascii_uppercase() || first.is_ascii_digit() {
        return None;
    }
    Some(name)
}

fn map_idents(f: &SourceFile) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for line in &f.code {
        for ty in ["FxHashMap", "FxHashSet", "HashMap", "HashSet"] {
            for at in scan::token_positions(line, ty) {
                if let Some(name) = decl_name(&line[..at]) {
                    if !out.contains(&name) {
                        out.push(name);
                    }
                }
            }
        }
    }
    out
}

fn r3_match(line: &str, idents: &[String]) -> Option<String> {
    for name in idents {
        for call in ITER_CALLS {
            let pat = format!("{name}{call}");
            if scan::has_token(line, &pat) {
                return Some(pat);
            }
        }
        if scan::has_token(line, "for") {
            for prefix in ["in ", "in &", "in &mut "] {
                let pat = format!("{prefix}{name}");
                if scan::has_token(line, &pat) {
                    return Some(format!("for .. {pat}"));
                }
            }
        }
    }
    None
}

impl Rule for UnorderedIter {
    fn id(&self) -> &'static str {
        R3
    }

    fn summary(&self) -> &'static str {
        "no unattested iteration over unordered maps near output"
    }

    fn explain(&self) -> &'static str {
        "DESIGN.md, determinism rules (\"Simulator performance model\"): map iteration\n\
         order must never feed metrics.  Fx hashing makes the order deterministic for\n\
         one binary, but it still shifts with insertion history and rebuilds, so any\n\
         iteration in a file that touches Metrics or Json must either be provably\n\
         order-independent (a commutative fold) or sort before emitting.  Attest such\n\
         lines with `lint: sorted`; collect-then-sort is the house pattern."
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Diagnostic>) {
        for f in &repo.files {
            if !feeds_output(f) {
                continue;
            }
            let idents = map_idents(f);
            if idents.is_empty() {
                continue;
            }
            for (i, line) in f.code.iter().enumerate() {
                if f.allows(i, R3) || f.sorted_ok(i) {
                    continue;
                }
                if let Some(what) = r3_match(line, &idents) {
                    let msg = format!(
                        "`{what}` iterates an unordered map in a file that feeds \
                         Metrics/JSON; sort (or prove order-independence) and attest \
                         with `lint: sorted`"
                    );
                    out.push(Diagnostic::new(&f.path, i + 1, R3, msg));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::lint::run;

    fn check_one(rule: &dyn Rule, files: &[(&str, &str)]) -> Vec<Diagnostic> {
        let repo = Repo::from_fixtures(files, &[]);
        let mut out = Vec::new();
        rule.check(&repo, &mut out);
        out
    }

    #[test]
    fn r1_flags_std_collections_and_bare_ctors() {
        let bad = "use std::collections::HashMap;\nlet m = HashMap::new();\n\
                   let s: std::collections::HashSet<u8> = Default::default();\n";
        let d = check_one(&RandState, &[("rust/src/x.rs", bad)]);
        assert_eq!(d.len(), 3);
        assert_eq!((d[0].line, d[0].rule), (1, R1));
        assert!(d[1].message.contains("HashMap::new"));
    }

    #[test]
    fn r1_ignores_fx_aliases_comments_and_strings() {
        let ok = "use crate::util::hash::{FxHashMap, FxHashSet};\n\
                  let m: FxHashMap<u64, u32> = FxHashMap::default();\n\
                  // std::collections::HashMap is banned here\n\
                  let s = \"std::collections::HashMap\";\n\
                  use std::collections::VecDeque;\n";
        assert!(check_one(&RandState, &[("rust/src/x.rs", ok)]).is_empty());
    }

    #[test]
    fn r1_respects_the_allowlist_file() {
        let bad = "use std::collections::{HashMap, HashSet};\n";
        assert!(check_one(&RandState, &[("rust/src/util/hash.rs", bad)]).is_empty());
        assert_eq!(check_one(&RandState, &[("rust/src/mem/x.rs", bad)]).len(), 1);
    }

    #[test]
    fn r1_allow_attestation_round_trips_through_run() {
        let with = "// lint: allow(R1): fixture justification\n\
                    use std::collections::HashMap;\n";
        let without = "use std::collections::HashMap;\n";
        let clean = run(&Repo::from_fixtures(&[("rust/src/x.rs", with)], &[]));
        assert!(clean.is_empty(), "attested site still flagged: {clean:?}");
        let dirty = run(&Repo::from_fixtures(&[("rust/src/x.rs", without)], &[]));
        assert_eq!(dirty.len(), 1);
        assert!(dirty[0].to_string().starts_with("rust/src/x.rs:1: R1-rand-state"));
    }

    #[test]
    fn r2_flags_clock_and_env_outside_allowlist() {
        let bad = "let t = std::time::Instant::now();\nlet e = std::env::var(\"X\");\n";
        let d = check_one(&WallClock, &[("rust/src/system/x.rs", bad)]);
        assert_eq!(d.len(), 2);
        assert!(d[0].message.contains("Instant::now"));
        assert!(check_one(&WallClock, &[("rust/src/main.rs", bad)]).is_empty());
        assert!(check_one(&WallClock, &[("benches/x.rs", bad)]).is_empty());
        assert!(check_one(&WallClock, &[("rust/src/bin/lint.rs", bad)]).is_empty());
    }

    #[test]
    fn r3_flags_map_iteration_only_in_output_feeding_files() {
        let body = "let mut counts: FxHashMap<u64, u64> = FxHashMap::default();\n\
                    for (k, v) in &counts {\n    emit(k, v);\n}\n\
                    let ks: Vec<_> = counts.keys().collect();\n";
        let plain = format!("fn quiet() {{\n{body}}}\n");
        assert!(check_one(&UnorderedIter, &[("rust/src/x.rs", &plain)]).is_empty());
        let feeds = format!("fn to_json(m: &Metrics) {{\n{body}}}\n");
        let d = check_one(&UnorderedIter, &[("rust/src/x.rs", &feeds)]);
        assert_eq!(d.len(), 2);
        assert!(d[0].message.contains("for .. in &counts"));
        assert!(d[1].message.contains("counts.keys()"));
    }

    #[test]
    fn r3_sorted_attestation_silences_the_line() {
        let src = "fn f() -> Json {\n\
                   let m: FxHashMap<u64, u64> = FxHashMap::default();\n\
                   // lint: sorted\n\
                   let mut v: Vec<_> = m.iter().collect();\n\
                   v.sort();\n\
                   for (k, _) in &m {}\n\
                   }\n";
        let d = check_one(&UnorderedIter, &[("rust/src/x.rs", src)]);
        assert_eq!(d.len(), 1, "only the unattested loop is flagged: {d:?}");
        assert_eq!(d[0].line, 6);
    }

    #[test]
    fn r3_tracks_fields_params_and_drain() {
        let src = "struct S { cache: FxHashMap<u64, u32> }\n\
                   fn dump(s: &mut S, out: &mut Json) {\n\
                   s.cache.retain(|_, v| *v > 0);\n\
                   for v in s.cache.drain() {}\n\
                   }\n";
        let d = check_one(&UnorderedIter, &[("rust/src/x.rs", src)]);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn decl_name_extraction() {
        assert_eq!(decl_name("    let mut stamp: ").as_deref(), Some("stamp"));
        assert_eq!(decl_name("let counts = ").as_deref(), Some("counts"));
        assert_eq!(decl_name("pub fn f(map: &mut "), Some("map".to_string()));
        assert_eq!(decl_name("use crate::util::hash::"), None);
        assert_eq!(decl_name("    -> "), None);
        assert_eq!(decl_name("Vec<"), None);
        assert_eq!(decl_name("if x == "), None);
    }
}
