//! Golden manifest of the shard wire format, pinned for R5.
//!
//! The sharded sweep writes `ShardData` JSON (tagged with
//! `SHARD_FORMAT` in `experiments::orchestrator`) whose payload rows
//! are `Metrics::to_json` objects.  Merging shards produced by
//! different builds is only sound if both the field list and the
//! version tag are what the merger expects — so both are pinned here,
//! and R5 (`wire::WireDrift`) fails the build when the source drifts
//! from this manifest.
//!
//! To change the wire format intentionally: update `Metrics::to_json`
//! / `from_json`, bump the version in `SHARD_FORMAT`, and record both
//! here in the same commit.  The lint makes it impossible to do one
//! without the others.

/// Must equal `orchestrator::SHARD_FORMAT`.
pub const WIRE_FORMAT: &str = "daemon-sim-shard-v6";

/// Field names of `Metrics::to_json`, in serialization order.  Every
/// field must also be read back by `Metrics::from_json`.
pub const METRICS_FIELDS: [&str; 34] = [
    "instructions",
    "cycles",
    "stall_cycles",
    "access_cost_n",
    "access_cost_sum",
    "access_cost_min",
    "access_cost_max",
    "local_hits",
    "local_misses",
    "pages_moved",
    "pages_throttled",
    "lines_moved",
    "writeback_bytes",
    "net_bytes_in",
    "reclaimed_bytes",
    "downtime_cycles",
    "aborted_transfers",
    "deferred_requests",
    "controller_actuations",
    "net_utilization",
    "net_util_series",
    "compression_ratio",
    "access_hist",
    "interval_instructions",
    "interval_local_hits",
    "interval_local_total",
    "requests_completed",
    "requests_timed_out",
    "requests_shed",
    "request_retries",
    "request_hedges",
    "request_hedge_wins",
    "requests_slo_good",
    "request_hist",
];
