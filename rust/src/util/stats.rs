//! Small statistics toolkit for experiment reporting.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; the paper reports speedups as geomeans.  Zero/negative
/// entries are clamped to a tiny positive value.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
/// NaN entries sort last (IEEE total order), so they never panic the sort
/// and only contaminate the percentiles that actually reach them.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Pearson correlation coefficient; 0.0 if degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Streaming counter with mean/min/max — cheap enough for hot paths.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn merge(&mut self, other: &Running) {
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed 64-bucket base-2 log histogram — cheap, deterministic percentile
/// estimates for hot counters (per-tenant p99 access cost).  Bucket 0
/// holds `[0, 1)` (and any non-finite/negative input); bucket `k` in
/// `1..=63` holds `[2^(k-1), 2^k)`, the top bucket absorbing everything
/// larger.  Bucketing reads the exponent bits directly rather than libm
/// logs, so results are bit-identical across platforms.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    pub counts: [u64; 64],
    pub total: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: [0; 64], total: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket_of(x: f64) -> usize {
        // NaN and anything below 1.0 (negatives included) land in bucket 0.
        if !(x >= 1.0) {
            return 0;
        }
        let e = ((x.to_bits() >> 52) & 0x7FF) as i64 - 1023; // floor(log2 x)
        (e.min(62) as usize) + 1 // +inf has e = 1024 -> top bucket
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.counts[Self::bucket_of(x)] += 1;
        self.total += 1;
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Rebuild from serialized bucket counts (inverse of reading `counts`).
    pub fn from_counts(counts: &[u64]) -> LogHistogram {
        assert_eq!(counts.len(), 64, "log histogram carries 64 buckets");
        let mut h = LogHistogram::new();
        h.counts.copy_from_slice(counts);
        h.total = counts.iter().sum();
        h
    }

    /// Approximate `q`-quantile (`q` in [0,1]): the geometric midpoint of
    /// the bucket containing the target rank.  0.0 when empty.
    pub fn value_at(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (k, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if k == 0 { 0.5 } else { 1.5 * (1u64 << (k - 1)) as f64 };
            }
        }
        1.5 * (1u64 << 62) as f64 // unreachable: counts sum to total
    }

    /// Approximate mean: bucket-midpoint weighted average (same
    /// midpoints as [`value_at`](Self::value_at)).  0.0 when empty.
    /// Midpoints over- or under-shoot the true mean by at most the
    /// bucket width, so the estimate is within [2/3, 3/2] of truth.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut sum = 0.0;
        for (k, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                let mid = if k == 0 { 0.5 } else { 1.5 * (1u64 << (k - 1)) as f64 };
                sum += mid * c as f64;
            }
        }
        sum / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let xs = [1.0, 4.0, 16.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_clamps_nonpositive() {
        assert!(geomean(&[0.0, 1.0]) >= 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 75.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan_input() {
        // Pre-fix this panicked in the sort's `partial_cmp(..).unwrap()`.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        // NaNs sort last under total order: low/mid percentiles are clean.
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn geomean_and_stddev_degenerate_inputs() {
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[7.0]) - 7.0).abs() < 1e-12, "singleton geomean");
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[4.2]), 0.0, "singleton stddev is degenerate");
    }

    #[test]
    fn log_histogram_buckets_and_quantiles() {
        let mut h = LogHistogram::new();
        assert_eq!(h.value_at(0.99), 0.0, "empty histogram");
        for _ in 0..99 {
            h.add(3.0); // bucket 2: [2, 4)
        }
        h.add(1000.0); // bucket 10: [512, 1024)
        assert_eq!(h.total, 100);
        assert_eq!(h.counts[2], 99);
        assert_eq!(h.counts[10], 1);
        assert!((h.value_at(0.5) - 3.0).abs() < 1e-9, "midpoint of [2,4)");
        assert!((h.value_at(0.99) - 3.0).abs() < 1e-9);
        assert!((h.value_at(1.0) - 768.0).abs() < 1e-9, "midpoint of [512,1024)");
    }

    #[test]
    fn log_histogram_mean_is_midpoint_weighted() {
        let h = LogHistogram::new();
        assert_eq!(h.mean(), 0.0, "empty histogram");
        let mut h = LogHistogram::new();
        for _ in 0..3 {
            h.add(3.0); // bucket 2, midpoint 3.0
        }
        h.add(1000.0); // bucket 10, midpoint 768.0
        assert!((h.mean() - (3.0 * 3.0 + 768.0) / 4.0).abs() < 1e-9);
        // Bucket-midpoint error bound: estimate within [2/3, 3/2] of truth.
        let truth = (3.0 * 3.0 + 1000.0) / 4.0;
        assert!(h.mean() > truth * 2.0 / 3.0 && h.mean() < truth * 1.5);
    }

    #[test]
    fn log_histogram_edge_inputs_and_merge() {
        let mut h = LogHistogram::new();
        h.add(0.0);
        h.add(-5.0);
        h.add(f64::NAN);
        h.add(0.99);
        assert_eq!(h.counts[0], 4, "sub-1/negative/NaN land in bucket 0");
        h.add(f64::INFINITY);
        h.add(1e300);
        assert_eq!(h.counts[63], 2, "top bucket absorbs the tail");
        let mut g = LogHistogram::new();
        g.add(2.0);
        g.merge(&h);
        assert_eq!(g.total, 7);
        let back = LogHistogram::from_counts(&g.counts);
        assert_eq!(back, g, "counts round-trip");
    }

    #[test]
    fn log_histogram_percentiles_meet_the_bucketing_error_bound() {
        // A sample >= 1 in bucket k (holding [2^(k-1), 2^k)) is reported
        // as the bucket's geometric midpoint 1.5*2^(k-1), so the ratio
        // estimate/sample lies in (0.75, 1.5].  `value_at` picks the
        // bucket holding rank ceil(q*n) — the bucket of the rank-based
        // order statistic — so every quantile estimate inherits exactly
        // that relative-error bound.
        crate::util::proptest::check(0x10_6_81, 200, |rng| {
            let n = 1 + rng.index(400);
            let mut xs: Vec<f64> = (0..n)
                .map(|_| (rng.f64() * 40.0).exp2() * (1.0 + rng.f64()))
                .collect();
            let mut h = LogHistogram::new();
            for &x in &xs {
                h.add(x);
            }
            xs.sort_by(f64::total_cmp);
            for q in [0.5, 0.9, 0.99, 0.999] {
                let target = ((q * n as f64).ceil().max(1.0) as usize).min(n);
                let truth = xs[target - 1];
                let est = h.value_at(q);
                let ratio = est / truth;
                assert!(
                    ratio > 0.75 && ratio <= 1.5,
                    "q={q} n={n}: estimate {est} vs sample {truth} (ratio {ratio})"
                );
            }
        });
    }

    #[test]
    fn log_histogram_quantile_edge_cases_pin() {
        // Empty histogram reports 0.0 at every quantile.
        let h = LogHistogram::new();
        assert_eq!(h.value_at(0.0), 0.0);
        assert_eq!(h.value_at(0.999), 0.0);
        // Single sample: every quantile is that sample's bucket midpoint.
        let mut one = LogHistogram::new();
        one.add(100.0); // bucket 7: [64, 128)
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.value_at(q), 96.0);
        }
        // Duplicate-heavy: 9,999 copies of one value plus one outlier —
        // p99/p999 stay on the dominant bucket, p100 reaches the tail.
        let mut dup = LogHistogram::new();
        for _ in 0..9_999 {
            dup.add(12.0); // bucket 4: [8, 16), midpoint exactly 12.0
        }
        dup.add(1e6);
        assert_eq!(dup.value_at(0.99), 12.0);
        assert_eq!(dup.value_at(0.999), 12.0);
        assert!(dup.value_at(1.0) > 1e5);
        // Sub-1 samples collapse to bucket 0's 0.5 representative.
        let mut tiny = LogHistogram::new();
        tiny.add(0.25);
        assert_eq!(tiny.value_at(0.99), 0.5);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn running_counter() {
        let mut r = Running::new();
        for x in [3.0, 1.0, 2.0] {
            r.add(x);
        }
        assert_eq!(r.n, 3);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 3.0);
        assert!((r.mean() - 2.0).abs() < 1e-12);
        let mut s = Running::new();
        s.add(5.0);
        r.merge(&s);
        assert_eq!(r.n, 4);
        assert_eq!(r.max, 5.0);
    }
}
