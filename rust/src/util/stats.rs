//! Small statistics toolkit for experiment reporting.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; the paper reports speedups as geomeans.  Zero/negative
/// entries are clamped to a tiny positive value.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Pearson correlation coefficient; 0.0 if degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Streaming counter with mean/min/max — cheap enough for hot paths.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn merge(&mut self, other: &Running) {
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let xs = [1.0, 4.0, 16.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_clamps_nonpositive() {
        assert!(geomean(&[0.0, 1.0]) >= 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 75.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn running_counter() {
        let mut r = Running::new();
        for x in [3.0, 1.0, 2.0] {
            r.add(x);
        }
        assert_eq!(r.n, 3);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 3.0);
        assert!((r.mean() - 2.0).abs() < 1e-12);
        let mut s = Running::new();
        s.add(5.0);
        r.merge(&s);
        assert_eq!(r.n, 4);
        assert_eq!(r.max, 5.0);
    }
}
