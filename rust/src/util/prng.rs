//! Deterministic PRNGs for workload generation and property tests.
//!
//! No external `rand` crate is available offline, and a simulator wants
//! deterministic, seedable, splittable streams anyway: every workload trace,
//! page content, and property-test case is reproducible from a `u64` seed.

/// SplitMix64 — used to seed and to derive independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-page / per-core substreams).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (n > 0), Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Approximately Zipf-distributed index in `[0, n)` with exponent `s`,
    /// via inverse-CDF on the continuous approximation.  Used for graph
    /// degree distributions and hot/cold page popularity.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.index(n);
        }
        let u = self.f64().max(1e-12);
        let nf = n as f64;
        let idx = if (s - 1.0).abs() < 1e-9 {
            nf.powf(u) - 1.0
        } else {
            let e = 1.0 - s;
            ((u * (nf.powf(e) - 1.0)) + 1.0).powf(1.0 / e) - 1.0
        };
        (idx as usize).min(n - 1)
    }

    /// Standard-normal sample (Box-Muller, one value per call).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 100_000.0;
        assert!((0.49..0.51).contains(&mean), "mean {mean}");
    }

    #[test]
    fn zipf_is_skewed_toward_low_indices() {
        let mut r = Rng::new(13);
        let mut head = 0usize;
        let n = 10_000;
        let samples = 100_000;
        for _ in 0..samples {
            if r.zipf(n, 1.1) < n / 100 {
                head += 1;
            }
        }
        // With s=1.1 the top 1% of indices should hold far more than 1%.
        assert!(head > samples / 10, "head {head}");
    }

    #[test]
    fn zipf_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.zipf(5, 1.2) < 5);
        }
        assert_eq!(r.zipf(1, 1.2), 0);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(19);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gaussian();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
