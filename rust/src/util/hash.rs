//! Fast, deterministic hashing for the simulator's hot tables.
//!
//! `std::collections::HashMap`'s default `RandomState` is SipHash-1-3 —
//! DoS-resistant, but ~10x the cost of a multiply-rotate mix on the
//! small fixed-width keys every hot map here uses (page ids, `(u64,
//! u64)` fingerprints).  The replay loop probes those maps on every
//! simulated access, so the hasher is hot-path arithmetic, not I/O.
//!
//! This is the Fx ("Firefox") hash: per 8-byte word,
//! `hash = (hash.rotate_left(5) ^ word) * K` with a golden-ratio-derived
//! odd constant.  Two properties matter here:
//!
//! * **speed** — one rotate, one xor, one multiply per word, no lanes,
//!   no finalizer;
//! * **determinism** — no random per-process seed, so any code that
//!   (incorrectly) let map iteration order reach the metrics would at
//!   least fail *reproducibly* across runs of the same binary.  The
//!   determinism rules still forbid iterating these maps into results —
//!   see DESIGN.md §"Simulator performance model".
//!
//! The crate is dependency-free by policy (offline registry), so this is
//! a from-scratch implementation of the well-known algorithm, not a
//! vendored crate.

// The one sanctioned import of the std map types: everything downstream
// goes through the Fx aliases below (clippy `disallowed_types` +
// daemon-lint R1 enforce this).
#[allow(clippy::disallowed_types)]
use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiplier: 2^64 / phi, forced odd (the same constant the simulator's
/// PRNG and placement hash already use as a mixing multiplier).
const K: u64 = 0x9E37_79B9_7F4A_7C15;

/// Fx multiply-rotate hasher.  Not DoS-resistant — keys here are
/// simulator-internal (page numbers, fingerprints), never adversarial.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(K);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            // Length-tag the tail word so "ab" != "ab\0".
            self.add(u64::from_le_bytes(tail) ^ (rest.len() as u64) << 56);
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add(i as u64);
        self.add((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

/// Zero-sized deterministic builder (no per-map random state).
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed by [`FxHasher`] — construct with `FxHashMap::default()`.
#[allow(clippy::disallowed_types)]
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed by [`FxHasher`] — construct with `FxHashSet::default()`.
#[allow(clippy::disallowed_types)]
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

/// Hash one value to a `u64` with [`FxHasher`] (shard selection, key
/// fingerprints).
#[inline]
pub fn fx_hash_one<T: std::hash::Hash>(v: &T) -> u64 {
    let mut h = FxHasher::default();
    v.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_hasher_instances() {
        // No random state: two maps / two hashers agree, always.
        assert_eq!(fx_hash_one(&42u64), fx_hash_one(&42u64));
        assert_eq!(fx_hash_one(&"page"), fx_hash_one(&"page"));
        assert_eq!(fx_hash_one(&(7u64, 9u64)), fx_hash_one(&(7u64, 9u64)));
    }

    #[test]
    fn distinguishes_nearby_keys() {
        // Not a collision-resistance proof — just a sanity screen that the
        // mix isn't degenerate on the simulator's typical key shapes.
        let pages: Vec<u64> = (0..1000).map(|p| fx_hash_one(&(p as u64))).collect();
        let mut uniq = pages.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), pages.len(), "adjacent page ids collided");
        assert_ne!(fx_hash_one(&0u64), fx_hash_one(&1u64));
    }

    #[test]
    fn byte_tail_is_length_tagged() {
        let mut a = FxHasher::default();
        a.write(b"ab");
        let mut b = FxHasher::default();
        b.write(b"ab\0");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn map_and_set_aliases_work() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        m.insert(4096, 1);
        assert_eq!(m.get(&4096), Some(&1));
        let mut s: FxHashSet<u64> = FxHashSet::default();
        assert!(s.insert(7) && !s.insert(7));
    }
}
