//! Zero-dependency splitmix64 stream for the request-serving front-end.
//!
//! The service layer (arrival times, backoff jitter, request-class choice)
//! needs a deterministic random stream that is independent of the simulation
//! PRNG in [`crate::util::prng`]: drawing service randomness from the same
//! stream as workload generation would make arrival patterns depend on how
//! many accesses a trace happened to sample. `SplitMix` is the raw splitmix64
//! generator (the same mixer that seeds `Rng`), seeded purely from config —
//! never from entropy — so replays are byte-identical (daemon-lint R1/R2).

/// Raw splitmix64 stream. Distinct from [`crate::util::prng::SplitMix64`]
/// (which is a private seeding detail of `Rng`): this type is the public,
/// forkable stream used by the service layer.
#[derive(Clone, Debug)]
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    /// Seed from a config-derived value. Zero is perturbed so the first
    /// output is not the fixed point of the mixer.
    pub fn new(seed: u64) -> Self {
        SplitMix {
            state: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform index in `[0, n)`. `n` must be nonzero.
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Exponentially distributed sample with the given mean (inter-arrival
    /// gap for a Poisson process). Clamps the uniform draw away from 0 so
    /// the log is finite; the result is always strictly positive.
    pub fn exp(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }

    /// Fork an independent stream keyed by `tag`. Forked streams do not
    /// perturb the parent, so adding a consumer never shifts existing draws.
    pub fn split(&self, tag: u64) -> SplitMix {
        SplitMix::new(
            self.state
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(tag),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_identically() {
        let mut a = SplitMix::new(0xDAE0);
        let mut b = SplitMix::new(0xDAE0);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix::new(1);
        let mut b = SplitMix::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_stays_in_unit_interval() {
        let mut r = SplitMix::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_is_positive_with_roughly_correct_mean() {
        let mut r = SplitMix::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.exp(100.0);
            assert!(x > 0.0);
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((80.0..120.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn split_streams_are_independent_of_parent_position() {
        let parent = SplitMix::new(9);
        let mut f1 = parent.split(1);
        let mut parent2 = parent.clone();
        parent2.next_u64();
        let mut f1_again = parent.split(1);
        for _ in 0..100 {
            assert_eq!(f1.next_u64(), f1_again.next_u64());
        }
        let mut f2 = parent.split(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn any_seed_replays_and_forks_are_position_independent() {
        // Property form of the pins above, over random seeds: replay
        // determinism, draw bounds, and fork purity.  `split` seeds the
        // child from (parent state, tag) and splitmix64's output mixer
        // is a bijection of its state, so distinct tags guarantee
        // distinct first draws — asserted exactly, no tolerance.
        crate::util::proptest::check(0xDAE0_51, 200, |pt| {
            let seed = pt.next_u64();
            let mut a = SplitMix::new(seed);
            let mut b = SplitMix::new(seed);
            for _ in 0..32 {
                assert_eq!(a.next_u64(), b.next_u64(), "seed {seed:#x}: replay diverged");
            }
            let x = a.f64();
            assert!((0.0..1.0).contains(&x), "seed {seed:#x}: f64 out of range");
            let e = a.exp(1.0 + x * 1e6);
            assert!(e > 0.0 && e.is_finite(), "seed {seed:#x}: exp draw {e}");
            let parent = SplitMix::new(seed);
            let tag = b.next_u64();
            let (mut f1, mut f2) = (parent.split(tag), parent.split(tag));
            let mut g = parent.split(tag.wrapping_add(1));
            let (x1, x2, y) = (f1.next_u64(), f2.next_u64(), g.next_u64());
            assert_eq!(x1, x2, "seed {seed:#x}: fork replay diverged");
            assert_ne!(x1, y, "seed {seed:#x}: adjacent fork tags collided");
        });
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = SplitMix::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }
}
