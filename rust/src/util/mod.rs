//! Shared infrastructure: PRNGs, statistics, tables, JSON, CLI parsing and
//! a property-test harness — all in-repo because the offline registry
//! carries no rand/serde/clap/proptest.

pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
