//! Shared infrastructure: PRNGs, statistics, tables, JSON, CLI parsing,
//! fast deterministic hashing, a sharded concurrent memo and a
//! property-test harness — all in-repo because the offline registry
//! carries no rand/serde/clap/proptest/rustc-hash.

pub mod cli;
pub mod hash;
pub mod json;
pub mod memo;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
