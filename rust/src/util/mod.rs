//! Shared infrastructure: PRNGs, statistics, tables, JSON, CLI parsing,
//! fast deterministic hashing, a sharded concurrent memo, a
//! property-test harness and a static-analysis rule engine (`lint`) —
//! all in-repo because the offline registry carries no
//! rand/serde/clap/proptest/rustc-hash.

pub mod cli;
pub mod hash;
pub mod json;
pub mod lint;
pub mod memo;
pub mod prng;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
