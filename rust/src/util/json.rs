//! Minimal JSON emit + parse (no serde offline).  Used for experiment
//! result files and machine-readable configs.  Supports the JSON subset we
//! produce: objects, arrays, strings, finite numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field as a string, in one step.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Object field as a number, in one step.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// Object field as an array, in one step.
    pub fn get_arr(&self, key: &str) -> Option<&[Json]> {
        self.get(key).and_then(Json::as_arr)
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::str("daemon")),
            ("speedup", Json::num(2.39)),
            ("flags", Json::arr([Json::Bool(true), Json::Null])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested_with_whitespace() {
        let s = r#" { "a" : [ 1 , 2.5 , { "b" : "x\ny" } ] , "c" : false } "#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn string_escapes() {
        let v = Json::str("a\"b\\c\nd");
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn typed_field_accessors() {
        let v = Json::obj(vec![
            ("name", Json::str("shard")),
            ("slots", Json::num(12.0)),
            ("list", Json::arr([Json::num(1.0)])),
        ]);
        assert_eq!(v.get_str("name"), Some("shard"));
        assert_eq!(v.get_f64("slots"), Some(12.0));
        assert_eq!(v.get_arr("list").map(|a| a.len()), Some(1));
        assert_eq!(v.get_str("slots"), None);
        assert_eq!(v.get_f64("missing"), None);
    }
}
