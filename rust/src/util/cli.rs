//! Tiny argument parser (no clap offline): subcommand + `--key value` /
//! `--flag` options, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  The first non-option token becomes the
    /// subcommand; later non-option tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// `--name I/N` shard option (CI grid splitting): returns
    /// `(index, total)` with `index < total` and `total >= 1`.
    pub fn get_shard(&self, name: &str) -> Result<Option<(usize, usize)>, String> {
        let Some(v) = self.get(name) else { return Ok(None) };
        let bad = || format!("--{name} expects I/N (e.g. 0/2), got '{v}'");
        let (i, n) = v.split_once('/').ok_or_else(|| bad())?;
        let i = i.trim().parse::<usize>().map_err(|_| bad())?;
        let n = n.trim().parse::<usize>().map_err(|_| bad())?;
        if n == 0 || i >= n {
            return Err(format!(
                "--{name}: shard index {i} out of range for {n} shard(s)"
            ));
        }
        Ok(Some((i, n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse(&["run", "--workload", "pr", "--verbose", "--ratio=0.25"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("workload"), Some("pr"));
        assert_eq!(a.get("ratio"), Some("0.25"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["experiment", "fig8", "fig9"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig8", "fig9"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "42", "--r", "2.5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("r", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        assert!(a.get_usize("r", 0).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--workloads", "pr, nw,bf"]);
        assert_eq!(
            a.get_list("workloads").unwrap(),
            vec!["pr", "nw", "bf"]
        );
    }

    #[test]
    fn shard_option() {
        let a = parse(&["sweep", "--shard", "1/4"]);
        assert_eq!(a.get_shard("shard").unwrap(), Some((1, 4)));
        assert_eq!(parse(&["sweep"]).get_shard("shard").unwrap(), None);
        for bad in ["2/2", "3/2", "x/2", "1/x", "1", "1/0", "/"] {
            let a = parse(&["sweep", &format!("--shard={bad}")]);
            assert!(a.get_shard("shard").is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn trailing_flag_not_eating_subcommand() {
        let a = parse(&["--quiet", "run"]);
        // '--quiet run': 'run' is consumed as the option value by design;
        // users write 'run --quiet'.  Assert the documented behaviour.
        assert_eq!(a.get("quiet"), Some("run"));
    }
}
