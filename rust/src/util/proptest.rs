//! Minimal property-testing harness (no proptest crate offline).
//!
//! `check(seed, cases, |rng| { ... })` runs the closure `cases` times with
//! independent RNG streams; a panic inside the closure is reported with the
//! exact stream seed so the failing case replays deterministically:
//!
//! ```text
//! property failed at case 17 (replay seed 0xDEADBEEF)
//! ```
//!
//! There is no shrinking — cases are kept small instead.

use super::prng::Rng;

/// Run `f` for `cases` deterministic cases derived from `seed`.
pub fn check<F: FnMut(&mut Rng)>(seed: u64, cases: usize, mut f: F) {
    for i in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a vector of length in `[min_len, max_len]` using `gen`.
pub fn vec_of<T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let len = min_len + rng.index(max_len - min_len + 1);
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 50, |rng| {
            let v = rng.below(100);
            assert!(v < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case_with_seed() {
        check(2, 50, |rng| {
            // Fails for roughly half the cases.
            assert!(rng.f64() < 0.5, "value too large");
        });
    }

    #[test]
    fn vec_of_respects_bounds() {
        check(3, 50, |rng| {
            let v = vec_of(rng, 2, 9, |r| r.below(10));
            assert!((2..=9).contains(&v.len()));
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check(4, 10, |rng| first.push(rng.next_u64()));
        let mut second: Vec<u64> = Vec::new();
        check(4, 10, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
