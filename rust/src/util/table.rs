//! ASCII table formatting — the experiment drivers print the same
//! rows/series the paper's figures plot, as aligned text tables.

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Convenience: first cell is a label, the rest are numbers.
    pub fn row_f(&mut self, label: &str, values: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| fmt_num(*v)));
        self.row(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // Left-align first column (labels), right-align the rest.
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// JSON form — the unit the sharded-vs-unsharded byte-identity check
    /// compares (cells are already-formatted strings, so the comparison is
    /// exact).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// CSV form for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Compact numeric formatting: 3 significant decimals, no trailing noise.
pub fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["workload", "speedup"]);
        t.row_f("pr", &[2.39]);
        t.row_f("needleman-wunsch", &[1.5]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("pr"));
        assert!(s.contains("2.390"));
        // All data lines equal width.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w = lines[0].len();
        for l in &lines[1..] {
            assert_eq!(l.len(), w, "misaligned: {l:?}");
        }
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_wrong_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_f("r1", &[1.0]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert_eq!(csv.lines().next().unwrap(), "a,b");
    }

    #[test]
    fn json_form_keeps_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_f("r1", &[1.5]);
        let j = t.to_json();
        assert_eq!(j.get_str("title"), Some("x"));
        assert_eq!(j.get_arr("headers").unwrap().len(), 2);
        let rows = j.get_arr("rows").unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[1].as_str(), Some("1.500"));
    }

    #[test]
    fn fmt_num_ranges() {
        assert_eq!(fmt_num(0.0), "0");
        assert_eq!(fmt_num(2.39), "2.390");
        assert_eq!(fmt_num(17.0), "17.00");
        assert_eq!(fmt_num(12345.6), "12346");
    }
}
