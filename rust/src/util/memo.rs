//! Fixed-shard concurrent memo for pure-function results.
//!
//! The compressed-size store used to be one process-global
//! `Mutex<HashMap>`, locked on *every* `Compressor::size_of` miss and
//! every insert — which serialized the orchestrator's `--jobs K` workers
//! exactly where they spend their time.  A [`ShardedMemo`] splits the key
//! space across N independent `RwLock`ed shards selected by the key's
//! [`fx_hash_one`](crate::util::hash::fx_hash_one) fingerprint: readers
//! of different keys never contend, readers of the *same* shard share the
//! read lock, and writers only exclude their own shard.
//!
//! The memo is an optimization, not a correctness store — callers must
//! recompute on a miss — so each shard enforces a hard entry cap instead
//! of evicting: once a shard is full, further inserts are dropped and
//! counted in `full_drops` (surfaced by `Compressor` stats as
//! `memo_full`).  Dropping is deterministic-per-key-set but fill *order*
//! under concurrency is not; that only ever changes how often a value is
//! recomputed, never its value.

use crate::util::hash::{fx_hash_one, FxHashMap};
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Occupancy counters for a [`ShardedMemo`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Entries currently memoized across all shards.
    pub entries: usize,
    /// Inserts dropped because their shard was at capacity.
    pub full_drops: u64,
}

/// N-way sharded, bounded, concurrent memo of pure values.
pub struct ShardedMemo<K, V> {
    shards: Vec<RwLock<FxHashMap<K, V>>>,
    /// Power-of-two mask selecting a shard from a key fingerprint.
    mask: u64,
    per_shard_cap: usize,
    full_drops: AtomicU64,
}

impl<K: Hash + Eq, V: Copy> ShardedMemo<K, V> {
    /// `shards` is rounded up to a power of two; `per_shard_cap` bounds
    /// each shard (total capacity = shards x per_shard_cap).
    pub fn new(shards: usize, per_shard_cap: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| RwLock::new(FxHashMap::default())).collect(),
            mask: n as u64 - 1,
            per_shard_cap,
            full_drops: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: &K) -> &RwLock<FxHashMap<K, V>> {
        // High bits select the shard so shard index and in-shard bucket
        // (which uses the low bits) stay decorrelated.
        &self.shards[((fx_hash_one(key) >> 48) & self.mask) as usize]
    }

    /// Memoized value for `key`, if present (shared read lock).
    #[inline]
    pub fn get(&self, key: &K) -> Option<V> {
        self.shard(key).read().unwrap().get(key).copied()
    }

    /// Memoize `value` under `key`.  Returns false (and counts the drop)
    /// when the shard is at capacity — the caller keeps its value either
    /// way; only future callers lose the shortcut.
    pub fn insert(&self, key: K, value: V) -> bool {
        let mut shard = self.shard(&key).write().unwrap();
        if shard.len() >= self.per_shard_cap && !shard.contains_key(&key) {
            drop(shard);
            self.full_drops.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        shard.insert(key, value);
        true
    }

    /// Lookup, computing and memoizing on a miss.  `compute` runs outside
    /// any lock — concurrent same-key callers may both compute (the value
    /// is pure, so both arrive at the same answer and the second insert
    /// is a no-op overwrite of an equal value).
    pub fn get_or_insert_with(&self, key: K, compute: impl FnOnce() -> V) -> V {
        if let Some(v) = self.get(&key) {
            return v;
        }
        let v = compute();
        self.insert(key, v);
        v
    }

    pub fn stats(&self) -> MemoStats {
        MemoStats {
            entries: self.shards.iter().map(|s| s.read().unwrap().len()).sum(),
            full_drops: self.full_drops.load(Ordering::Relaxed),
        }
    }

    /// Drop every memoized entry and reset the drop counter.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
        self.full_drops.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoizes_and_counts() {
        let m: ShardedMemo<(u64, u64), u32> = ShardedMemo::new(8, 100);
        assert_eq!(m.get(&(1, 2)), None);
        assert_eq!(m.get_or_insert_with((1, 2), || 42), 42);
        assert_eq!(m.get(&(1, 2)), Some(42));
        // Hit path: the closure must not run again.
        assert_eq!(m.get_or_insert_with((1, 2), || panic!("recompute on hit")), 42);
        assert_eq!(m.stats(), MemoStats { entries: 1, full_drops: 0 });
        m.clear();
        assert_eq!(m.stats(), MemoStats::default());
    }

    #[test]
    fn full_shard_drops_inserts_but_stays_correct() {
        // 1 shard x 4 entries: the 5th distinct key is dropped and
        // counted, but get_or_insert_with still returns the right value
        // (computed, just not memoized).
        let m: ShardedMemo<u64, u32> = ShardedMemo::new(1, 4);
        for k in 0..4u64 {
            assert!(m.insert(k, k as u32 * 10), "insert under cap");
        }
        assert!(!m.insert(99, 990), "insert past cap must be dropped");
        assert_eq!(m.get(&99), None, "dropped key is not memoized");
        assert_eq!(m.stats(), MemoStats { entries: 4, full_drops: 1 });
        // The caller-facing contract survives the full memo.
        assert_eq!(m.get_or_insert_with(99, || 990), 990);
        assert_eq!(m.stats().full_drops, 2, "each dropped insert is counted");
        // Existing keys still hit and may be overwritten in place.
        assert_eq!(m.get(&3), Some(30));
        assert!(m.insert(3, 31), "overwrite of a resident key is not a drop");
        assert_eq!(m.get(&3), Some(31));
        assert_eq!(m.stats().entries, 4);
    }

    #[test]
    fn keys_spread_over_shards() {
        let m: ShardedMemo<u64, u32> = ShardedMemo::new(8, 2);
        // 64 keys into 8 shards x 2 cap: spreading must memoize far more
        // than one shard's worth even though shards individually fill.
        let mut kept = 0u64;
        for k in 0..64u64 {
            if m.insert(k, 0) {
                kept += 1;
            }
        }
        assert_eq!(kept as usize, m.stats().entries);
        assert!(kept > 2, "all keys landed in one shard");
        assert_eq!(m.stats().full_drops, 64 - kept);
    }

    #[test]
    fn concurrent_fill_and_read_converge() {
        use std::sync::Arc;
        let m: Arc<ShardedMemo<u64, u64>> = Arc::new(ShardedMemo::new(16, 1000));
        std::thread::scope(|s| {
            for t in 0..4 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for i in 0..500u64 {
                        let k = (i + t * 31) % 400;
                        assert_eq!(m.get_or_insert_with(k, || k * 3), k * 3);
                    }
                });
            }
        });
        let st = m.stats();
        assert_eq!(st.entries, 400);
        assert_eq!(st.full_drops, 0);
        for k in 0..400u64 {
            assert_eq!(m.get(&k), Some(k * 3));
        }
    }
}
