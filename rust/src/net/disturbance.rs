//! Artificial network disturbance (§6 "Network Disturbance", Fig. 13/14).
//!
//! The paper simulates contention from other compute components by
//! injecting packets into the network during runtime.  We model phases of
//! load: within an active phase, a fraction of the link capacity is
//! consumed by injected packets, applied per accounting interval as the
//! simulation clock advances.

use crate::net::link::Link;

/// One disturbance phase: during `[from_cycle, to_cycle)`, inject traffic
/// equal to `load` x link capacity.
#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub from_cycle: f64,
    pub to_cycle: f64,
    pub load: f64,
}

pub struct Disturbance {
    phases: Vec<Phase>,
    /// Injection granularity in cycles.
    step: f64,
    /// Next cycle at which injection is due.
    cursor: f64,
    /// Link capacity in bytes/cycle (sum over channels).
    capacity: f64,
}

impl Disturbance {
    pub fn new(phases: Vec<Phase>, step_cycles: f64, capacity_bytes_per_cycle: f64) -> Self {
        Self { phases, step: step_cycles.max(1.0), cursor: 0.0, capacity: capacity_bytes_per_cycle }
    }

    /// No disturbance.
    pub fn none() -> Self {
        Self { phases: Vec::new(), step: f64::INFINITY, cursor: f64::INFINITY, capacity: 0.0 }
    }

    /// Periodic square-wave load: alternating `busy_load` / 0 with the
    /// given period (used by Fig. 13/14's runtime variation).
    pub fn square_wave(period_cycles: f64, busy_load: f64, horizon_cycles: f64,
                       step_cycles: f64, capacity: f64) -> Self {
        let mut phases = Vec::new();
        let mut t = 0.0;
        let mut on = true;
        while t < horizon_cycles {
            if on {
                phases.push(Phase { from_cycle: t, to_cycle: t + period_cycles, load: busy_load });
            }
            t += period_cycles;
            on = !on;
        }
        Self::new(phases, step_cycles, capacity)
    }

    fn load_at(&self, cycle: f64) -> f64 {
        for p in &self.phases {
            if cycle >= p.from_cycle && cycle < p.to_cycle {
                return p.load;
            }
        }
        0.0
    }

    /// Advance to `now`, injecting the due traffic into `link`.
    pub fn advance(&mut self, now: f64, link: &mut Link) {
        while self.cursor <= now {
            let load = self.load_at(self.cursor);
            if load > 0.0 {
                let bytes = (load * self.capacity * self.step) as u64;
                if bytes > 0 {
                    link.inject(self.cursor, bytes);
                }
            }
            self.cursor += self.step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::{Class, Link};

    #[test]
    fn none_never_injects() {
        let mut d = Disturbance::none();
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(1e9, &mut l);
        let t = l.send(0.0, 10, Class::Line);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn active_phase_slows_traffic() {
        let mut d = Disturbance::new(
            vec![Phase { from_cycle: 0.0, to_cycle: 1000.0, load: 0.5 }],
            100.0,
            1.0,
        );
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(999.0, &mut l);
        // 10 steps x 50 bytes injected = 500 cycles of occupancy.
        let t = l.send(0.0, 10, Class::Line);
        assert!(t >= 500.0, "expected queueing behind injected load, got {t}");
    }

    #[test]
    fn square_wave_alternates() {
        let d = Disturbance::square_wave(100.0, 0.8, 400.0, 10.0, 1.0);
        assert!(d.load_at(50.0) > 0.0);
        assert_eq!(d.load_at(150.0), 0.0);
        assert!(d.load_at(250.0) > 0.0);
        assert_eq!(d.load_at(350.0), 0.0);
    }

    #[test]
    fn advance_is_incremental() {
        let mut d = Disturbance::new(
            vec![Phase { from_cycle: 0.0, to_cycle: 200.0, load: 1.0 }],
            100.0,
            1.0,
        );
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(50.0, &mut l);
        let backlog_1 = l.backlog(0.0, Class::Line);
        d.advance(150.0, &mut l);
        let backlog_2 = l.backlog(0.0, Class::Line);
        assert!(backlog_2 > backlog_1);
    }
}
