//! Time-varying network conditions (§6 "Network Disturbance" and the
//! runtime-variability regime of Figs. 13/14).
//!
//! Two mechanisms, composable per fabric port:
//!
//! * [`Disturbance`] — *injection*: phases of load during which a
//!   fraction of the link capacity is consumed by other components'
//!   packets, applied per accounting interval as the simulation clock
//!   advances.  The link's nominal rate never changes; the injected
//!   traffic occupies its timeline.
//! * [`NetSchedule`] — *conditions*: a piecewise-constant schedule of
//!   bandwidth and switch-latency phases the channel itself obeys
//!   (degraded links, bursty cross-traffic modeled as capacity loss).
//!   Serialization integrates the rate over the phases a transfer spans.

use crate::config::{ns_to_cycles, ScheduleSpec};
use crate::net::link::Link;
use std::sync::Arc;

/// One disturbance phase: during `[from_cycle, to_cycle)`, inject traffic
/// equal to `load` x link capacity.
#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub from_cycle: f64,
    pub to_cycle: f64,
    pub load: f64,
}

pub struct Disturbance {
    /// Sorted by `from_cycle`, non-overlapping (asserted).
    phases: Vec<Phase>,
    /// Injection granularity in cycles.
    step: f64,
    /// Next cycle at which injection is due.
    cursor: f64,
    /// Monotone cursor into `phases`: the first phase whose `to_cycle`
    /// lies beyond the injection cursor.  `advance` visits cycles in
    /// nondecreasing order, so the cursor only ever moves forward — this
    /// replaces a per-step linear scan over all phases (`square_wave` on
    /// long horizons builds thousands, making injection O(phases x
    /// steps) without it).
    phase_idx: usize,
    /// Link capacity in bytes/cycle (sum over channels).
    capacity: f64,
}

impl Disturbance {
    pub fn new(phases: Vec<Phase>, step_cycles: f64, capacity_bytes_per_cycle: f64) -> Self {
        // Hard assert (matching `NetSchedule::new`): the monotone phase
        // cursor silently mis-injects on unsorted/overlapping lists that
        // the old linear scan tolerated.
        assert!(
            phases.windows(2).all(|w| w[0].to_cycle <= w[1].from_cycle),
            "disturbance phases must be sorted and non-overlapping"
        );
        Self {
            phases,
            step: step_cycles.max(1.0),
            cursor: 0.0,
            phase_idx: 0,
            capacity: capacity_bytes_per_cycle,
        }
    }

    /// No disturbance.
    pub fn none() -> Self {
        Self {
            phases: Vec::new(),
            step: f64::INFINITY,
            cursor: f64::INFINITY,
            phase_idx: 0,
            capacity: 0.0,
        }
    }

    /// Periodic square-wave load: alternating `busy_load` / 0 with the
    /// given period (used by Fig. 13/14's runtime variation).
    pub fn square_wave(
        period_cycles: f64,
        busy_load: f64,
        horizon_cycles: f64,
        step_cycles: f64,
        capacity: f64,
    ) -> Self {
        let mut phases = Vec::new();
        let mut t = 0.0;
        let mut on = true;
        while t < horizon_cycles {
            if on {
                phases.push(Phase { from_cycle: t, to_cycle: t + period_cycles, load: busy_load });
            }
            t += period_cycles;
            on = !on;
        }
        Self::new(phases, step_cycles, capacity)
    }

    /// Load active at `cycle`.  Queries must be nondecreasing across
    /// calls (they come from the monotone injection cursor); the phase
    /// cursor advances past every phase that ended at or before `cycle`
    /// and never rewinds.
    fn load_at(&mut self, cycle: f64) -> f64 {
        while self.phase_idx < self.phases.len()
            && cycle >= self.phases[self.phase_idx].to_cycle
        {
            self.phase_idx += 1;
        }
        match self.phases.get(self.phase_idx) {
            Some(p) if cycle >= p.from_cycle => p.load,
            _ => 0.0,
        }
    }

    /// Advance to `now`, injecting the due traffic into `link`.
    pub fn advance(&mut self, now: f64, link: &mut Link) {
        while self.cursor <= now {
            let cursor = self.cursor;
            let load = self.load_at(cursor);
            if load > 0.0 {
                let bytes = (load * self.capacity * self.step) as u64;
                if bytes > 0 {
                    link.inject(cursor, bytes);
                }
            }
            self.cursor += self.step;
        }
    }
}

/// One piecewise-constant phase of link conditions, active from
/// `from_cycle` until the next phase's start (the last phase extends
/// forever).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetPhase {
    pub from_cycle: f64,
    /// Multiplier on the channel's nominal bytes/cycle (> 0).
    pub rate_scale: f64,
    /// Extra switch latency while the phase is active, cycles.
    pub extra_latency_cycles: f64,
}

/// A schedule of bandwidth/latency phases a link obeys — the §6
/// time-varying operating condition (bursty degradation, diurnal load).
/// Before the first phase the link runs nominal (scale 1, no extra
/// latency); an empty schedule is nominal forever and is timing-identical
/// to no schedule at all.  Lookups binary-search on `from_cycle`, so
/// arbitrary (non-monotone) query times stay O(log phases).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NetSchedule {
    phases: Vec<NetPhase>,
}

impl NetSchedule {
    pub fn new(phases: Vec<NetPhase>) -> NetSchedule {
        assert!(
            phases.windows(2).all(|w| w[0].from_cycle <= w[1].from_cycle),
            "schedule phases must be sorted by from_cycle"
        );
        assert!(
            phases.iter().all(|p| p.rate_scale > 0.0 && p.rate_scale.is_finite()),
            "rate_scale must be positive and finite"
        );
        assert!(
            phases
                .iter()
                .all(|p| p.extra_latency_cycles >= 0.0 && p.extra_latency_cycles.is_finite()),
            "extra_latency_cycles must be non-negative and finite"
        );
        NetSchedule { phases }
    }

    /// Steady nominal conditions.
    pub fn steady() -> NetSchedule {
        NetSchedule { phases: Vec::new() }
    }

    /// Alternating degraded / nominal phases of `period_cycles` each,
    /// starting degraded at cycle 0, until `horizon_cycles` (the tail
    /// past the horizon runs nominal).
    pub fn square_wave(
        period_cycles: f64,
        rate_scale: f64,
        extra_latency_cycles: f64,
        horizon_cycles: f64,
    ) -> NetSchedule {
        assert!(period_cycles > 0.0, "schedule period must be positive");
        let mut phases = Vec::new();
        let mut t = 0.0;
        let mut degraded = true;
        while t < horizon_cycles {
            phases.push(if degraded {
                NetPhase { from_cycle: t, rate_scale, extra_latency_cycles }
            } else {
                NetPhase { from_cycle: t, rate_scale: 1.0, extra_latency_cycles: 0.0 }
            });
            t += period_cycles;
            degraded = !degraded;
        }
        // Nominal tail from the horizon on (clamped: when the horizon is
        // not a period multiple, the last phase must still end there).
        phases.push(NetPhase {
            from_cycle: horizon_cycles.min(t),
            rate_scale: 1.0,
            extra_latency_cycles: 0.0,
        });
        NetSchedule::new(phases)
    }

    /// Materialize a plain-data [`ScheduleSpec`] (the config-level
    /// description cluster cells carry).
    pub fn from_spec(spec: &ScheduleSpec) -> NetSchedule {
        NetSchedule::square_wave(
            spec.period_cycles,
            spec.rate_scale,
            ns_to_cycles(spec.extra_latency_ns),
            spec.horizon_cycles,
        )
    }

    /// The phase active at `cycle` (`None` before the first phase).
    fn phase_at(&self, cycle: f64) -> Option<&NetPhase> {
        let i = self.phases.partition_point(|p| p.from_cycle <= cycle);
        if i == 0 {
            None
        } else {
            Some(&self.phases[i - 1])
        }
    }

    pub fn rate_scale_at(&self, cycle: f64) -> f64 {
        self.phase_at(cycle).map(|p| p.rate_scale).unwrap_or(1.0)
    }

    pub fn extra_latency_at(&self, cycle: f64) -> f64 {
        self.phase_at(cycle).map(|p| p.extra_latency_cycles).unwrap_or(0.0)
    }

    /// End time of a transfer of `bytes` starting at `start` on a channel
    /// with nominal `base_rate` bytes/cycle, integrating the rate over
    /// every phase the transfer spans.
    pub fn transfer_end(&self, start: f64, bytes: f64, base_rate: f64) -> f64 {
        let mut t = start;
        let mut left = bytes;
        let mut i = self.phases.partition_point(|p| p.from_cycle <= t);
        loop {
            let scale = if i == 0 { 1.0 } else { self.phases[i - 1].rate_scale };
            let rate = base_rate * scale;
            let bound = self.phases.get(i).map(|p| p.from_cycle).unwrap_or(f64::INFINITY);
            let capacity = (bound - t) * rate;
            if left <= capacity {
                return t + left / rate;
            }
            left -= capacity;
            t = bound;
            i += 1;
        }
    }

    pub fn is_steady(&self) -> bool {
        self.phases.is_empty()
    }
}

/// Shared handle the channels hold (one schedule per port, `Arc`-shared
/// between its channels and the owning link).
pub type ScheduleHandle = Arc<NetSchedule>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::{Class, Link};

    #[test]
    fn none_never_injects() {
        let mut d = Disturbance::none();
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(1e9, &mut l);
        let t = l.send(0.0, 10, Class::Line);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn active_phase_slows_traffic() {
        let mut d = Disturbance::new(
            vec![Phase { from_cycle: 0.0, to_cycle: 1000.0, load: 0.5 }],
            100.0,
            1.0,
        );
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(999.0, &mut l);
        // 10 steps x 50 bytes injected = 500 cycles of occupancy.
        let t = l.send(0.0, 10, Class::Line);
        assert!(t >= 500.0, "expected queueing behind injected load, got {t}");
    }

    #[test]
    fn square_wave_alternates() {
        let mut d = Disturbance::square_wave(100.0, 0.8, 400.0, 10.0, 1.0);
        assert!(d.load_at(50.0) > 0.0);
        assert_eq!(d.load_at(150.0), 0.0);
        assert!(d.load_at(250.0) > 0.0);
        assert_eq!(d.load_at(350.0), 0.0);
    }

    #[test]
    fn advance_is_incremental() {
        let mut d = Disturbance::new(
            vec![Phase { from_cycle: 0.0, to_cycle: 200.0, load: 1.0 }],
            100.0,
            1.0,
        );
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(50.0, &mut l);
        let backlog_1 = l.backlog(0.0, Class::Line);
        d.advance(150.0, &mut l);
        let backlog_2 = l.backlog(0.0, Class::Line);
        assert!(backlog_2 > backlog_1);
    }

    #[test]
    fn phase_boundaries_land_in_the_right_phase() {
        // Two adjacent phases + a gap: queries at exact from_cycle /
        // to_cycle boundaries must resolve per the [from, to) convention,
        // through the monotone cursor.
        let phases = vec![
            Phase { from_cycle: 100.0, to_cycle: 200.0, load: 0.5 },
            Phase { from_cycle: 200.0, to_cycle: 300.0, load: 0.9 },
            Phase { from_cycle: 400.0, to_cycle: 500.0, load: 0.3 },
        ];
        let mut d = Disturbance::new(phases.clone(), 10.0, 1.0);
        assert_eq!(d.load_at(0.0), 0.0, "before the first phase");
        assert_eq!(d.load_at(100.0), 0.5, "inclusive from_cycle");
        assert_eq!(d.load_at(199.0), 0.5);
        assert_eq!(d.load_at(200.0), 0.9, "to_cycle is exclusive; next from is inclusive");
        assert_eq!(d.load_at(300.0), 0.0, "gap after an exclusive to_cycle");
        assert_eq!(d.load_at(400.0), 0.3);
        assert_eq!(d.load_at(500.0), 0.0, "past the last phase");
        // The cursor path must agree with a straight linear scan at every
        // (monotone) step boundary.
        let mut cursor = Disturbance::new(phases.clone(), 10.0, 1.0);
        let mut t = 0.0;
        while t <= 600.0 {
            let linear = phases
                .iter()
                .find(|p| t >= p.from_cycle && t < p.to_cycle)
                .map(|p| p.load)
                .unwrap_or(0.0);
            assert_eq!(cursor.load_at(t), linear, "divergence at cycle {t}");
            t += 10.0;
        }
    }

    #[test]
    fn boundary_injection_matches_phase_bytes() {
        // Step boundaries aligned with the phase edges: exactly the
        // cycles in [100, 200) inject (10 steps x 0.5 x 10 = 50 bytes).
        let mut d = Disturbance::new(
            vec![Phase { from_cycle: 100.0, to_cycle: 200.0, load: 0.5 }],
            10.0,
            1.0,
        );
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        d.advance(90.0, &mut l);
        assert_eq!(l.utilization(100.0), 0.0, "no injection before from_cycle");
        d.advance(200.0, &mut l);
        // Steps at 100,110,...,190 inject 5 bytes each (50 busy cycles at
        // 1 B/cyc); the step at exactly 200 (== to_cycle) must not.
        assert!((l.utilization(200.0) - 50.0 / 200.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_lookup_and_defaults() {
        let s = NetSchedule::square_wave(100.0, 0.5, 36.0, 350.0);
        // Degraded [0,100), nominal [100,200), degraded [200,300),
        // nominal [300,400) + nominal tail at 400.
        assert_eq!(s.rate_scale_at(0.0), 0.5);
        assert_eq!(s.extra_latency_at(50.0), 36.0);
        assert_eq!(s.rate_scale_at(100.0), 1.0);
        assert_eq!(s.extra_latency_at(150.0), 0.0);
        assert_eq!(s.rate_scale_at(250.0), 0.5);
        assert_eq!(s.rate_scale_at(1e9), 1.0, "nominal past the horizon");
        assert!(NetSchedule::steady().is_steady());
        assert_eq!(NetSchedule::steady().rate_scale_at(123.0), 1.0);
        assert_eq!(NetSchedule::steady().extra_latency_at(123.0), 0.0);
    }

    #[test]
    fn square_wave_tail_is_clamped_to_the_horizon() {
        // Horizon not a period multiple: the degraded phase at 200 must
        // still end at the 250-cycle horizon, per the ScheduleSpec
        // contract ("nominal after the horizon").
        let s = NetSchedule::square_wave(100.0, 0.25, 9.0, 250.0);
        assert_eq!(s.rate_scale_at(249.0), 0.25);
        assert_eq!(s.rate_scale_at(250.0), 1.0, "nominal from the horizon on");
        assert_eq!(s.extra_latency_at(250.0), 0.0);
        assert_eq!(s.rate_scale_at(1e12), 1.0);
    }

    #[test]
    fn transfer_end_integrates_across_phases() {
        // Rate 1 B/cyc nominal, halved during [0,100).
        let s = NetSchedule::square_wave(100.0, 0.5, 0.0, 100.0);
        // 40 bytes at t=0: 0.5 B/cyc -> 80 cycles, inside the phase.
        assert!((s.transfer_end(0.0, 40.0, 1.0) - 80.0).abs() < 1e-9);
        // 80 bytes at t=0: 50 bytes drain by cycle 100, the remaining 30
        // at full rate -> ends at 130.
        assert!((s.transfer_end(0.0, 80.0, 1.0) - 130.0).abs() < 1e-9);
        // Entirely inside the nominal tail.
        assert!((s.transfer_end(500.0, 40.0, 1.0) - 540.0).abs() < 1e-9);
        // A steady schedule is one plain division — bit-identical to the
        // unscheduled path.
        let steady = NetSchedule::steady();
        let end = steady.transfer_end(7.0, 123.0, 3.0);
        assert_eq!(end.to_bits(), (7.0f64 + 123.0 / 3.0).to_bits());
    }

    #[test]
    fn from_spec_converts_latency_ns() {
        let spec = crate::config::ScheduleSpec {
            period_cycles: 100.0,
            rate_scale: 0.25,
            extra_latency_ns: 100.0,
            horizon_cycles: 150.0,
        };
        let s = NetSchedule::from_spec(&spec);
        assert_eq!(s.rate_scale_at(0.0), 0.25);
        assert!((s.extra_latency_at(0.0) - 360.0).abs() < 1e-9);
        assert_eq!(s.rate_scale_at(100.0), 1.0);
    }
}
