//! Switched fabric connecting C compute components (tenants) to M memory
//! modules — replaces the hardwired point-to-point `MemComponent` links.
//!
//! Ports live at the memory modules: each module owns one full-duplex
//! port pair *per tenant*, carved out of the module's link bandwidth by
//! the tenant's weight.  Under [`SharingMode::Strict`] partitioning is
//! §4.1-style strict — a tenant's share is reserved even while other
//! tenants idle — which is what gives the cluster its QoS isolation;
//! within a tenant's share, that tenant's own scheme decides class
//! partitioning.  Under [`SharingMode::WorkConserving`] a transfer also
//! draws on capacity that is idle at request time (peer tenants' port
//! channels and the sibling class channel of a partitioned share),
//! split proportionally to the candidate channels' rates — deficit-style:
//! borrowed bytes are charged to the lending channel's timeline, so a
//! lender waking up queues behind what it lent, and nothing is reserved
//! twice.  Strict mode takes the exact historical code path.
//!
//! Every traversal pays the module's switch latency plus an optional
//! extra fabric hop (`hop_cycles`); a
//! [`NetSchedule`](crate::net::disturbance::NetSchedule) per port adds
//! §6's time-varying bandwidth/latency conditions.  With a single tenant
//! and a zero hop the fabric is timing-identical to the old
//! point-to-point links, which is what lets a single-tenant cluster
//! reproduce `Machine` exactly.
//!
//! Ports are additionally **failure-isolated components**: a
//! [`FaultPlan`] installs per-port Up/Down windows (module crashes +
//! link flaps).  A send issued while its port is down is *deferred* to
//! the recovery edge; a send whose issue→arrival interval overlaps a
//! down window is *aborted* (the occupied wire time is wasted) and
//! replayed after recovery.  A port with no fault windows takes the
//! exact historical code path, and other ports' timing is untouched —
//! the isolation property the resilience experiment measures.

use crate::config::{ns_to_cycles, NetConfig, SharingMode, TenantShare};
use crate::net::disturbance::{Disturbance, ScheduleHandle};
use crate::net::link::{work_conserving_issue, work_conserving_plan, Class, Link};
use crate::system::fault::{FaultCounters, FaultPlan, FaultTimeline, PortState};

/// One tenant's full-duplex port on a memory module.
struct PortPair {
    down: Link, // memory -> compute (data)
    up: Link,   // compute -> memory (writebacks)
    /// Unsplit port capacity, bytes/cycle (disturbance injection base).
    capacity: f64,
    disturbance: Disturbance,
    /// Bytes this tenant served on borrowed (idle peer / sibling-class)
    /// capacity, both directions — work-conserving mode only.
    reclaimed_bytes: u64,
    /// Down windows of this port (module crashes + its own link flaps);
    /// empty = the exact no-fault code path.
    faults: FaultTimeline,
    /// Latest arrival among fault-deferred/replayed transfers — the port
    /// reads as `Recovering` until it passes.
    recovering_until: f64,
    /// Aborted/deferred transfer counts on this port.
    counters: FaultCounters,
}

fn dir(p: &PortPair, down: bool) -> &Link {
    if down {
        &p.down
    } else {
        &p.up
    }
}

fn dir_mut(p: &mut PortPair, down: bool) -> &mut Link {
    if down {
        &mut p.down
    } else {
        &mut p.up
    }
}

struct ModulePorts {
    switch_cycles: f64,
    ports: Vec<PortPair>,
}

/// The switched fabric: per-(module × tenant) full-duplex port pairs —
/// see the module docs for the sharing, scheduling and failure models.
pub struct Fabric {
    hop_cycles: f64,
    sharing: SharingMode,
    modules: Vec<ModulePorts>,
}

impl Fabric {
    /// Build a fabric of one port pair per `(module, tenant share)` —
    /// each module's link bandwidth (from its [`NetConfig`]) is carved
    /// across `shares` by weight.
    pub fn new(
        nets: &[NetConfig],
        dram_gbps: f64,
        shares: &[TenantShare],
        hop_cycles: f64,
        interval: f64,
        sharing: SharingMode,
    ) -> Fabric {
        assert!(!nets.is_empty(), "fabric needs at least one memory module");
        let modules = nets
            .iter()
            .map(|n| {
                let bpc = n.bytes_per_cycle(dram_gbps);
                let sw = ns_to_cycles(n.switch_latency_ns);
                let ports = shares
                    .iter()
                    .zip(TenantShare::rates(shares, bpc))
                    .map(|(s, rate)| {
                        let mk = || {
                            if s.partitioned {
                                Link::partitioned(sw, rate, s.line_ratio, interval)
                            } else {
                                Link::shared(sw, rate, interval)
                            }
                        };
                        PortPair {
                            down: mk(),
                            up: mk(),
                            capacity: rate,
                            disturbance: Disturbance::none(),
                            reclaimed_bytes: 0,
                            faults: FaultTimeline::default(),
                            recovering_until: 0.0,
                            counters: FaultCounters::default(),
                        }
                    })
                    .collect();
                ModulePorts { switch_cycles: sw, ports }
            })
            .collect();
        Fabric { hop_cycles, sharing, modules }
    }

    /// Number of memory modules on the fabric.
    pub fn modules(&self) -> usize {
        self.modules.len()
    }

    /// Number of tenant port pairs per module.
    pub fn tenants(&self) -> usize {
        self.modules[0].ports.len()
    }

    /// The idle-capacity policy this fabric was built with.
    pub fn sharing(&self) -> SharingMode {
        self.sharing
    }

    /// Latency of a control message from a tenant to module `m`.
    pub fn request_latency(&self, m: usize) -> f64 {
        self.modules[m].switch_cycles + self.hop_cycles
    }

    /// Send data from module `m` down to tenant `t`; returns arrival time
    /// at the compute component (serialization + switch + fabric hop).
    pub fn send_down(&mut self, m: usize, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        match self.sharing {
            SharingMode::Strict => {
                let hop = self.hop_cycles;
                let p = &mut self.modules[m].ports[t];
                if p.faults.is_empty() {
                    p.down.send(now, bytes, class) + hop
                } else {
                    Self::send_faulted(p, now, bytes, class, true) + hop
                }
            }
            SharingMode::WorkConserving => self.send_wc(m, t, now, bytes, class, true),
        }
    }

    /// Send data from tenant `t` up to module `m` (writebacks).
    pub fn send_up(&mut self, m: usize, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        match self.sharing {
            SharingMode::Strict => {
                let hop = self.hop_cycles;
                let p = &mut self.modules[m].ports[t];
                if p.faults.is_empty() {
                    p.up.send(now, bytes, class) + hop
                } else {
                    Self::send_faulted(p, now, bytes, class, false) + hop
                }
            }
            SharingMode::WorkConserving => self.send_wc(m, t, now, bytes, class, false),
        }
    }

    /// Send on a port carrying fault windows through the shared
    /// [`FaultTimeline::replay`] discipline: issue while down defers to
    /// the recovery edge; an issue→arrival interval overlapping a later
    /// window aborts (the wire time already occupied is wasted — the
    /// data was in flight or queued at the component when it died) and
    /// replays from that window's end.
    fn send_faulted(p: &mut PortPair, now: f64, bytes: u64, class: Class, down: bool) -> f64 {
        let PortPair { down: d, up: u, faults, counters, recovering_until, .. } = p;
        let link = if down { d } else { u };
        let (arr, at) = faults.replay(now, counters, |at| link.send(at, bytes, class));
        if at > now {
            *recovering_until = recovering_until.max(arr);
        }
        arr
    }

    /// Work-conserving transfer: split `bytes` across tenant `t`'s own
    /// `class` channel plus every candidate channel idle at `now` (the
    /// sibling class inside a partitioned share, and peer tenants' port
    /// channels), proportionally to the candidates' service rates.  The
    /// arrival is when the slowest chunk lands; borrowed chunks occupy
    /// the lending channels' timelines.
    fn send_wc(
        &mut self,
        m: usize,
        t: usize,
        now: f64,
        bytes: u64,
        class: Class,
        down: bool,
    ) -> f64 {
        let module = &mut self.modules[m];
        let (cands, chunks) = {
            let ports = &module.ports;
            work_conserving_plan(
                t,
                class,
                ports.len(),
                bytes,
                |u| dir(&ports[u], down).is_partitioned(),
                |u, c| dir(&ports[u], down).idle(now, c),
                |u, c| dir(&ports[u], down).rate(c),
            )
        };
        let (arrival, borrowed) = work_conserving_issue(&cands, &chunks, |u, c, chunk| {
            dir_mut(&mut module.ports[u], down).send(now, chunk, c)
        });
        module.ports[t].reclaimed_bytes += borrowed;
        arrival + self.hop_cycles
    }

    pub fn down_backlog(&self, m: usize, t: usize, now: f64, class: Class) -> f64 {
        self.modules[m].ports[t].down.backlog(now, class)
    }

    /// Service rate of tenant `t`'s downlink `class` channel on module
    /// `m` (the strict share; work-conserving borrowing comes on top).
    pub fn down_rate(&self, m: usize, t: usize, class: Class) -> f64 {
        self.modules[m].ports[t].down.rate(class)
    }

    /// Bytes tenant `t` moved on borrowed capacity at module `m`.
    pub fn reclaimed_bytes(&self, m: usize, t: usize) -> u64 {
        self.modules[m].ports[t].reclaimed_bytes
    }

    /// Advance tenant `t`'s disturbance injector on module `m` to `now`.
    pub fn advance_disturbance(&mut self, m: usize, t: usize, now: f64) {
        let p = &mut self.modules[m].ports[t];
        p.disturbance.advance(now, &mut p.down);
    }

    /// Install a disturbance on every port (capacity = that port's rate).
    pub fn set_disturbance(&mut self, mk: impl Fn(f64) -> Disturbance) {
        for m in self.modules.iter_mut() {
            for p in m.ports.iter_mut() {
                p.disturbance = mk(p.capacity);
            }
        }
    }

    /// Install a disturbance on every port of module `m` only — other
    /// modules' ports keep whatever injector they have.
    pub fn set_disturbance_at(&mut self, m: usize, mk: impl Fn(f64) -> Disturbance) {
        for p in self.modules[m].ports.iter_mut() {
            p.disturbance = mk(p.capacity);
        }
    }

    /// Install time-varying link conditions: `mk(module, tenant)` yields
    /// the schedule for that port pair (both directions; `None` clears).
    pub fn set_schedule(&mut self, mk: impl Fn(usize, usize) -> Option<ScheduleHandle>) {
        for (m, module) in self.modules.iter_mut().enumerate() {
            for (t, p) in module.ports.iter_mut().enumerate() {
                let s = mk(m, t);
                p.down.set_schedule(s.clone());
                p.up.set_schedule(s);
            }
        }
    }

    /// Materialize a [`FaultPlan`] onto every port: each port gets the
    /// merged timeline of its module's crash windows plus its own link
    /// flaps.  Fault injection composes with strict sharing only — the
    /// work-conserving borrow planner would read a down port as merely
    /// idle and lend its capacity away.
    pub fn set_faults(&mut self, plan: &FaultPlan) {
        assert!(
            crate::policy::sharing(self.sharing).supports_faults(),
            "fault injection requires strict sharing (SharingMode::Strict)"
        );
        for (m, module) in self.modules.iter_mut().enumerate() {
            for (t, p) in module.ports.iter_mut().enumerate() {
                p.faults = plan.port_timeline(m, t);
            }
        }
    }

    /// Lifecycle state of tenant `t`'s port on module `m` at `now`:
    /// `Down` inside a fault window, `Recovering` while draining
    /// fault-deferred/replayed transfers, `Up` otherwise.  Derived by
    /// replaying the port's fault timeline through the declared
    /// [`PortState`] lifecycle machine
    /// ([`FaultTimeline::port_state`](crate::system::fault::FaultTimeline::port_state)).
    pub fn port_state(&self, m: usize, t: usize, now: f64) -> PortState {
        let p = &self.modules[m].ports[t];
        p.faults.port_state(p.recovering_until, now)
    }

    /// Whether tenant `t` can reach module `m` at `now` (not inside a
    /// fault window) — the query
    /// [`RecoveryPolicy::Refetch`](crate::system::fault::RecoveryPolicy)
    /// routes by.
    pub fn port_up(&self, m: usize, t: usize, now: f64) -> bool {
        !self.modules[m].ports[t].faults.is_down(now)
    }

    /// Down time of tenant `t`'s port on module `m` within `[0, horizon)`.
    pub fn port_downtime(&self, m: usize, t: usize, horizon: f64) -> f64 {
        self.modules[m].ports[t].faults.downtime(horizon)
    }

    /// `(aborted, deferred)` transfer counts of tenant `t`'s port on
    /// module `m` — both zero unless a fault plan is installed.
    pub fn fault_counts(&self, m: usize, t: usize) -> (u64, u64) {
        let c = self.modules[m].ports[t].counters;
        (c.aborted, c.deferred)
    }

    /// Schedule rate multiplier on tenant `t`'s downlink at module `m`
    /// (1.0 = nominal conditions) — the closed loop's distress signal.
    pub fn down_rate_scale(&self, m: usize, t: usize, now: f64) -> f64 {
        self.modules[m].ports[t].down.rate_scale_at(now)
    }

    /// Closed-loop `ratio-tune` actuation: re-split tenant `t`'s
    /// partitioned port capacity on every module so `ratio` of it serves
    /// cache lines (both directions).  Unpartitioned (shared-channel)
    /// ports are untouched — they have no class split to retune.  Only
    /// transfers issued after the call see the new rates.
    pub fn retune_tenant_ratio(&mut self, t: usize, ratio: f64) {
        for module in self.modules.iter_mut() {
            let p = &mut module.ports[t];
            p.down.retune_partition(p.capacity, ratio);
            p.up.retune_partition(p.capacity, ratio);
        }
    }

    /// Closed-loop `share-rebalance` actuation: re-carve every module's
    /// total port capacity across tenants by `weights` (normalized here),
    /// preserving each port's internal class split.  Each port's
    /// disturbance-injection base (`capacity`) moves with it so future
    /// injector installs see the rebalanced shares.
    pub fn retune_weights(&mut self, weights: &[f64]) {
        assert_eq!(weights.len(), self.tenants(), "one weight per tenant");
        let wsum: f64 = weights.iter().sum();
        assert!(wsum > 0.0 && wsum.is_finite(), "weights must sum positive");
        for module in self.modules.iter_mut() {
            let total: f64 = module.ports.iter().map(|p| p.capacity).sum();
            for (p, &w) in module.ports.iter_mut().zip(weights) {
                let cap = total * (w / wsum);
                p.capacity = cap;
                p.down.set_capacity(cap);
                p.up.set_capacity(cap);
            }
        }
    }

    pub fn down_utilization(&self, m: usize, t: usize, horizon: f64) -> f64 {
        self.modules[m].ports[t].down.utilization(horizon)
    }

    /// Per-interval downlink utilization series over `[0, horizon)`.
    pub fn down_series(&self, m: usize, t: usize, horizon: f64) -> Vec<f64> {
        self.modules[m].ports[t].down.utilization_series(horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::disturbance::{NetSchedule, Phase};
    use std::sync::Arc;

    fn share(weight: f64) -> TenantShare {
        TenantShare { weight, partitioned: false, line_ratio: 0.25 }
    }

    fn strict(nets: &[NetConfig], gbps: f64, shares: &[TenantShare], hop: f64, iv: f64) -> Fabric {
        Fabric::new(nets, gbps, shares, hop, iv, SharingMode::Strict)
    }

    #[test]
    fn single_tenant_matches_point_to_point_link() {
        let net = NetConfig::new(100.0, 4.0);
        let bpc = net.bytes_per_cycle(17.0);
        let mut f = strict(&[net], 17.0, &[share(1.0)], 0.0, 1000.0);
        let mut l = Link::shared(ns_to_cycles(100.0), bpc, 1000.0);
        for (now, bytes) in [(0.0, 4096u64), (10.0, 64), (5000.0, 640)] {
            let a = f.send_down(0, 0, now, bytes, Class::Page);
            let b = l.send(now, bytes, Class::Page);
            assert_eq!(a.to_bits(), b.to_bits(), "fabric must degrade exactly");
        }
        assert_eq!(f.request_latency(0), ns_to_cycles(100.0));
    }

    #[test]
    fn tenants_are_strictly_isolated() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net], 7.2, &[share(1.0), share(1.0)], 0.0, 1000.0);
        assert_eq!(f.tenants(), 2);
        assert_eq!(f.modules(), 1);
        assert_eq!(f.sharing(), SharingMode::Strict);
        // Each tenant gets 1 B/cycle of the 2 B/cycle port.
        assert!((f.down_rate(0, 0, Class::Line) - 1.0).abs() < 1e-12);
        // Tenant 0 saturates its partition ...
        let t0 = f.send_down(0, 0, 0.0, 1000, Class::Line);
        assert!((t0 - 1000.0).abs() < 1e-9);
        // ... tenant 1's transfers are unaffected (strict shares).
        let t1 = f.send_down(0, 1, 0.0, 100, Class::Line);
        assert!((t1 - 100.0).abs() < 1e-9, "cross-tenant interference: {t1}");
        assert_eq!(f.reclaimed_bytes(0, 0), 0, "strict mode never borrows");
    }

    #[test]
    fn weights_skew_port_rates() {
        let net = NetConfig::new(0.0, 1.0);
        let f = strict(&[net], 10.8, &[share(3.0), share(1.0)], 0.0, 1e4);
        assert!((f.down_rate(0, 0, Class::Line) - 2.25).abs() < 1e-12);
        assert!((f.down_rate(0, 1, Class::Line) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn fabric_hop_adds_to_every_traversal() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net], 3.6, &[share(1.0)], 25.0, 1e4);
        assert_eq!(f.request_latency(0), 25.0);
        let t = f.send_down(0, 0, 0.0, 100, Class::Line);
        assert!((t - 125.0).abs() < 1e-9, "serialization + hop: {t}");
        let u = f.send_up(0, 0, 0.0, 100, Class::Line);
        assert!((u - 125.0).abs() < 1e-9);
    }

    #[test]
    fn partitioned_tenant_share_splits_classes() {
        let net = NetConfig::new(0.0, 1.0);
        let sh = TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 };
        let f = strict(&[net], 14.4, &[sh, sh], 0.0, 1e4);
        // 4 B/cyc port, 2 B/cyc per tenant, 25% of that for lines.
        assert!((f.down_rate(0, 0, Class::Line) - 0.5).abs() < 1e-12);
        assert!((f.down_rate(0, 0, Class::Page) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn work_conserving_borrows_idle_peer_capacity() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = Fabric::new(
            &[net],
            7.2,
            &[share(1.0), share(1.0)],
            0.0,
            1e6,
            SharingMode::WorkConserving,
        );
        // Tenant 1 idle: tenant 0's 1000-byte transfer runs at the full
        // 2 B/cyc port rate (500 + 500 split over both 1 B/cyc channels)
        // instead of 1000 cycles on its own 1 B/cyc share.
        let t0 = f.send_down(0, 0, 0.0, 1000, Class::Line);
        assert!((t0 - 500.0).abs() < 1e-9, "idle capacity not reclaimed: {t0}");
        assert_eq!(f.reclaimed_bytes(0, 0), 500);
        // Tenant 1 wakes up mid-lease: it queues behind what it lent
        // (deficit accounting — nothing is reserved twice).
        let t1 = f.send_down(0, 1, 100.0, 100, Class::Line);
        assert!((t1 - 600.0).abs() < 1e-9, "lender must queue behind its lease: {t1}");
        // With both channels busy there is nothing to borrow.
        let t0b = f.send_down(0, 0, 100.0, 100, Class::Line);
        assert!((t0b - 600.0).abs() < 1e-9, "{t0b}");
        assert_eq!(f.reclaimed_bytes(0, 0), 500, "no borrow while peers are busy");
    }

    #[test]
    fn work_conserving_with_single_unpartitioned_tenant_degrades_exactly() {
        let net = NetConfig::new(100.0, 4.0);
        let mk = |mode| Fabric::new(&[net], 17.0, &[share(1.0)], 0.0, 1000.0, mode);
        let mut a = mk(SharingMode::Strict);
        let mut b = mk(SharingMode::WorkConserving);
        for (now, bytes) in [(0.0, 4096u64), (10.0, 64), (5000.0, 640)] {
            let x = a.send_down(0, 0, now, bytes, Class::Page);
            let y = b.send_down(0, 0, now, bytes, Class::Page);
            assert_eq!(x.to_bits(), y.to_bits(), "WC with no idle candidates must be strict");
        }
        assert_eq!(b.reclaimed_bytes(0, 0), 0);
    }

    #[test]
    fn work_conserving_borrows_idle_sibling_class() {
        let net = NetConfig::new(0.0, 1.0);
        let sh = TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 };
        let mut f = Fabric::new(&[net], 14.4, &[sh], 0.0, 1e6, SharingMode::WorkConserving);
        // 4 B/cyc port: line channel 1 B/cyc, page channel 3 B/cyc.  With
        // the page class idle, a 1000-byte line burst runs at 4 B/cyc.
        let t = f.send_down(0, 0, 0.0, 1000, Class::Line);
        assert!((t - 250.0).abs() < 1e-9, "sibling class capacity not reclaimed: {t}");
        assert_eq!(f.reclaimed_bytes(0, 0), 750);
    }

    #[test]
    fn disturbance_degrades_only_the_targeted_module() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net, net], 7.2, &[share(1.0)], 0.0, 1e6);
        // 80% load on module 0's ports only, for the first 1e5 cycles.
        f.set_disturbance_at(0, |cap| {
            Disturbance::new(vec![Phase { from_cycle: 0.0, to_cycle: 1e5, load: 0.8 }], 100.0, cap)
        });
        f.advance_disturbance(0, 0, 5000.0);
        f.advance_disturbance(1, 0, 5000.0);
        let rate = f.down_rate(1, 0, Class::Line);
        let clean = f.send_down(1, 0, 5000.0, 100, Class::Line);
        assert!(
            (clean - (5000.0 + 100.0 / rate)).abs() < 1e-9,
            "untargeted module must be clean: {clean}"
        );
        // 80% load injects 160 bytes per 100-cycle step (80 busy cycles),
        // so the send queues behind the current step's injection.
        let dirty = f.send_down(0, 0, 5000.0, 100, Class::Line);
        assert!(
            dirty > clean + 50.0,
            "targeted module must queue behind injected load: {dirty} vs {clean}"
        );
    }

    #[test]
    fn faulted_port_defers_aborts_and_recovers() {
        let net = NetConfig::new(0.0, 1.0);
        // 2 modules, 1 tenant: each port runs at 7.2/3.6 = 2 B/cycle.
        let mut f = strict(&[net, net], 7.2, &[share(1.0)], 0.0, 1e6);
        f.set_faults(&FaultPlan::new().module_crash(0, 100.0, 500.0));
        assert_eq!(f.port_state(0, 0, 50.0), PortState::Up);
        assert_eq!(f.port_state(0, 0, 100.0), PortState::Down);
        assert_eq!(f.port_state(1, 0, 300.0), PortState::Up, "other module unaffected");
        assert!(!f.port_up(0, 0, 300.0) && f.port_up(1, 0, 300.0));
        // In flight at the crash: 400 bytes issued at 0 serialize over
        // [0, 200), overlapping the window — aborted, replayed from the
        // recovery edge 500, arriving 700 (wasted wire time stays).
        let a = f.send_down(0, 0, 0.0, 400, Class::Line);
        assert!((a - 700.0).abs() < 1e-9, "{a}");
        assert_eq!(f.fault_counts(0, 0), (1, 0));
        // Issued while down: deferred to 500, queued behind the replay.
        let b = f.send_down(0, 0, 300.0, 100, Class::Line);
        assert!((b - 750.0).abs() < 1e-9, "{b}");
        assert_eq!(f.fault_counts(0, 0), (1, 1));
        // Recovering while the deferred backlog drains, Up afterwards.
        assert_eq!(f.port_state(0, 0, 600.0), PortState::Recovering);
        assert_eq!(f.port_state(0, 0, 800.0), PortState::Up);
        // Failure isolation: module 1's timing is byte-identical clean.
        let c = f.send_down(1, 0, 0.0, 400, Class::Line);
        assert!((c - 200.0).abs() < 1e-9, "{c}");
        assert_eq!(f.fault_counts(1, 0), (0, 0));
        assert_eq!(f.port_downtime(1, 0, 1e4), 0.0);
        assert!((f.port_downtime(0, 0, 1e4) - 400.0).abs() < 1e-9);
    }

    #[test]
    fn link_flap_hits_only_its_tenant() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net], 7.2, &[share(1.0), share(1.0)], 0.0, 1e6);
        f.set_faults(&FaultPlan::new().link_flap(0, 1, 0.0, 300.0));
        let t0 = f.send_down(0, 0, 0.0, 100, Class::Line);
        assert!((t0 - 100.0).abs() < 1e-9, "tenant 0 must be clean: {t0}");
        let t1 = f.send_down(0, 1, 0.0, 100, Class::Line);
        assert!((t1 - 400.0).abs() < 1e-9, "tenant 1 must defer to recovery: {t1}");
        assert_eq!(f.fault_counts(0, 0), (0, 0));
        assert_eq!(f.fault_counts(0, 1), (0, 1));
    }

    #[test]
    fn empty_fault_plan_degrades_exactly() {
        let net = NetConfig::new(100.0, 4.0);
        let mk = || strict(&[net], 17.0, &[share(1.0)], 0.0, 1000.0);
        let mut a = mk();
        let mut b = mk();
        b.set_faults(&FaultPlan::new());
        for (now, bytes) in [(0.0, 4096u64), (10.0, 64), (5000.0, 640)] {
            let x = a.send_down(0, 0, now, bytes, Class::Page);
            let y = b.send_down(0, 0, now, bytes, Class::Page);
            assert_eq!(x.to_bits(), y.to_bits(), "empty plan must be the no-fault path");
            let x = a.send_up(0, 0, now, bytes, Class::Page);
            let y = b.send_up(0, 0, now, bytes, Class::Page);
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(b.fault_counts(0, 0), (0, 0));
        assert_eq!(b.port_state(0, 0, 0.0), PortState::Up);
    }

    #[test]
    #[should_panic(expected = "strict sharing")]
    fn fault_injection_requires_strict_sharing() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f =
            Fabric::new(&[net], 7.2, &[share(1.0)], 0.0, 1e6, SharingMode::WorkConserving);
        f.set_faults(&FaultPlan::new().module_crash(0, 0.0, 10.0));
    }

    #[test]
    fn retune_tenant_ratio_resplits_partitioned_ports_only() {
        let net = NetConfig::new(0.0, 1.0);
        let part = TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 };
        let flat = share(1.0);
        let mut f = strict(&[net, net], 14.4, &[part, flat], 0.0, 1e4);
        // Tenant 0: 2 B/cyc per module, 25% lines.
        assert!((f.down_rate(0, 0, Class::Line) - 0.5).abs() < 1e-12);
        f.retune_tenant_ratio(0, 0.6);
        for m in 0..2 {
            assert!((f.down_rate(m, 0, Class::Line) - 1.2).abs() < 1e-12);
            assert!((f.down_rate(m, 0, Class::Page) - 0.8).abs() < 1e-12);
        }
        // Tenant 1's shared-channel port is untouched.
        assert!((f.down_rate(0, 1, Class::Line) - 2.0).abs() < 1e-12);
        // Retuning back restores the original rates exactly.
        f.retune_tenant_ratio(0, 0.25);
        assert!((f.down_rate(0, 0, Class::Line) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn retune_weights_recarves_total_capacity() {
        let net = NetConfig::new(0.0, 1.0);
        let part = TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 };
        let mut f = strict(&[net], 14.4, &[part, part], 0.0, 1e4);
        // 4 B/cyc port split 2/2; rebalance to 3:1.
        f.retune_weights(&[3.0, 1.0]);
        assert!((f.down_rate(0, 0, Class::Line) - 0.75).abs() < 1e-12);
        assert!((f.down_rate(0, 0, Class::Page) - 2.25).abs() < 1e-12);
        assert!((f.down_rate(0, 1, Class::Line) - 0.25).abs() < 1e-12);
        // Total capacity is conserved regardless of the weights.
        let total = f.down_rate(0, 0, Class::Line)
            + f.down_rate(0, 0, Class::Page)
            + f.down_rate(0, 1, Class::Line)
            + f.down_rate(0, 1, Class::Page);
        assert!((total - 4.0).abs() < 1e-12, "{total}");
        // Equal weights restore the original carve exactly.
        f.retune_weights(&[1.0, 1.0]);
        assert!((f.down_rate(0, 0, Class::Line) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn down_rate_scale_tracks_schedule_per_port() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net, net], 7.2, &[share(1.0)], 0.0, 1e6);
        assert_eq!(f.down_rate_scale(0, 0, 0.0), 1.0, "unscheduled = nominal");
        let sched = Arc::new(NetSchedule::square_wave(100.0, 0.25, 0.0, 400.0));
        f.set_schedule(|m, _| if m == 0 { Some(sched.clone()) } else { None });
        assert!((f.down_rate_scale(0, 0, 50.0) - 0.25).abs() < 1e-12);
        assert!((f.down_rate_scale(0, 0, 150.0) - 1.0).abs() < 1e-12);
        assert_eq!(f.down_rate_scale(1, 0, 50.0), 1.0, "other module nominal");
    }

    #[test]
    fn port_schedules_apply_per_module_and_tenant() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = strict(&[net, net], 7.2, &[share(1.0)], 0.0, 1e6);
        // Halve module 0's port bandwidth for 1e12 cycles; module 1
        // nominal.
        let sched = Arc::new(NetSchedule::square_wave(1e12, 0.5, 0.0, 1e12));
        f.set_schedule(|m, _| if m == 0 { Some(sched.clone()) } else { None });
        let rate = f.down_rate(0, 0, Class::Line);
        let slow = f.send_down(0, 0, 0.0, 100, Class::Line);
        assert!((slow - 200.0 / rate).abs() < 1e-9, "degraded module: {slow}");
        let fast = f.send_down(1, 0, 0.0, 100, Class::Line);
        assert!((fast - 100.0 / rate).abs() < 1e-9, "nominal module: {fast}");
    }
}
