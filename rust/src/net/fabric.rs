//! Switched fabric connecting C compute components (tenants) to M memory
//! modules — replaces the hardwired point-to-point `MemComponent` links.
//!
//! Ports live at the memory modules: each module owns one full-duplex
//! port pair *per tenant*, carved out of the module's link bandwidth by
//! the tenant's weight.  Partitioning is strict, like §4.1's class
//! partitioning — a tenant's share is reserved even while other tenants
//! idle — which is what gives the cluster its QoS isolation; within a
//! tenant's share, that tenant's own scheme decides class partitioning.
//! Every traversal pays the module's switch latency plus an optional
//! extra fabric hop (`hop_cycles`).  With a single tenant and a zero hop
//! the fabric is timing-identical to the old point-to-point links, which
//! is what lets a single-tenant cluster reproduce `Machine` exactly.

use crate::config::{ns_to_cycles, NetConfig, TenantShare};
use crate::net::disturbance::Disturbance;
use crate::net::link::{Class, Link};

/// One tenant's full-duplex port on a memory module.
struct PortPair {
    down: Link, // memory -> compute (data)
    up: Link,   // compute -> memory (writebacks)
    /// Unsplit port capacity, bytes/cycle (disturbance injection base).
    capacity: f64,
    disturbance: Disturbance,
}

struct ModulePorts {
    switch_cycles: f64,
    ports: Vec<PortPair>,
}

pub struct Fabric {
    hop_cycles: f64,
    modules: Vec<ModulePorts>,
}

impl Fabric {
    pub fn new(
        nets: &[NetConfig],
        dram_gbps: f64,
        shares: &[TenantShare],
        hop_cycles: f64,
        interval: f64,
    ) -> Fabric {
        assert!(!nets.is_empty(), "fabric needs at least one memory module");
        let modules = nets
            .iter()
            .map(|n| {
                let bpc = n.bytes_per_cycle(dram_gbps);
                let sw = ns_to_cycles(n.switch_latency_ns);
                let ports = shares
                    .iter()
                    .zip(TenantShare::rates(shares, bpc))
                    .map(|(s, rate)| {
                        let mk = || {
                            if s.partitioned {
                                Link::partitioned(sw, rate, s.line_ratio, interval)
                            } else {
                                Link::shared(sw, rate, interval)
                            }
                        };
                        PortPair {
                            down: mk(),
                            up: mk(),
                            capacity: rate,
                            disturbance: Disturbance::none(),
                        }
                    })
                    .collect();
                ModulePorts { switch_cycles: sw, ports }
            })
            .collect();
        Fabric { hop_cycles, modules }
    }

    pub fn modules(&self) -> usize {
        self.modules.len()
    }

    pub fn tenants(&self) -> usize {
        self.modules[0].ports.len()
    }

    /// Latency of a control message from a tenant to module `m`.
    pub fn request_latency(&self, m: usize) -> f64 {
        self.modules[m].switch_cycles + self.hop_cycles
    }

    /// Send data from module `m` down to tenant `t`; returns arrival time
    /// at the compute component (serialization + switch + fabric hop).
    pub fn send_down(&mut self, m: usize, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        self.modules[m].ports[t].down.send(now, bytes, class) + self.hop_cycles
    }

    /// Send data from tenant `t` up to module `m` (writebacks).
    pub fn send_up(&mut self, m: usize, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        self.modules[m].ports[t].up.send(now, bytes, class) + self.hop_cycles
    }

    pub fn down_backlog(&self, m: usize, t: usize, now: f64, class: Class) -> f64 {
        self.modules[m].ports[t].down.backlog(now, class)
    }

    /// Service rate of tenant `t`'s downlink `class` channel on module `m`.
    pub fn down_rate(&self, m: usize, t: usize, class: Class) -> f64 {
        self.modules[m].ports[t].down.rate(class)
    }

    /// Advance tenant `t`'s disturbance injector on module `m` to `now`.
    pub fn advance_disturbance(&mut self, m: usize, t: usize, now: f64) {
        let p = &mut self.modules[m].ports[t];
        p.disturbance.advance(now, &mut p.down);
    }

    /// Install a disturbance on every port (capacity = that port's rate).
    pub fn set_disturbance(&mut self, mk: impl Fn(f64) -> Disturbance) {
        for m in self.modules.iter_mut() {
            for p in m.ports.iter_mut() {
                p.disturbance = mk(p.capacity);
            }
        }
    }

    pub fn down_utilization(&self, m: usize, t: usize, horizon: f64) -> f64 {
        self.modules[m].ports[t].down.utilization(horizon)
    }

    pub fn down_series(&self, m: usize, t: usize) -> Vec<f64> {
        self.modules[m].ports[t].down.utilization_series()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn share(weight: f64) -> TenantShare {
        TenantShare { weight, partitioned: false, line_ratio: 0.25 }
    }

    #[test]
    fn single_tenant_matches_point_to_point_link() {
        let net = NetConfig::new(100.0, 4.0);
        let bpc = net.bytes_per_cycle(17.0);
        let mut f = Fabric::new(&[net], 17.0, &[share(1.0)], 0.0, 1000.0);
        let mut l = Link::shared(ns_to_cycles(100.0), bpc, 1000.0);
        for (now, bytes) in [(0.0, 4096u64), (10.0, 64), (5000.0, 640)] {
            let a = f.send_down(0, 0, now, bytes, Class::Page);
            let b = l.send(now, bytes, Class::Page);
            assert_eq!(a.to_bits(), b.to_bits(), "fabric must degrade exactly");
        }
        assert_eq!(f.request_latency(0), ns_to_cycles(100.0));
    }

    #[test]
    fn tenants_are_strictly_isolated() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = Fabric::new(&[net], 7.2, &[share(1.0), share(1.0)], 0.0, 1000.0);
        assert_eq!(f.tenants(), 2);
        assert_eq!(f.modules(), 1);
        // Each tenant gets 1 B/cycle of the 2 B/cycle port.
        assert!((f.down_rate(0, 0, Class::Line) - 1.0).abs() < 1e-12);
        // Tenant 0 saturates its partition ...
        let t0 = f.send_down(0, 0, 0.0, 1000, Class::Line);
        assert!((t0 - 1000.0).abs() < 1e-9);
        // ... tenant 1's transfers are unaffected (strict shares).
        let t1 = f.send_down(0, 1, 0.0, 100, Class::Line);
        assert!((t1 - 100.0).abs() < 1e-9, "cross-tenant interference: {t1}");
    }

    #[test]
    fn weights_skew_port_rates() {
        let net = NetConfig::new(0.0, 1.0);
        let f = Fabric::new(&[net], 10.8, &[share(3.0), share(1.0)], 0.0, 1e4);
        assert!((f.down_rate(0, 0, Class::Line) - 2.25).abs() < 1e-12);
        assert!((f.down_rate(0, 1, Class::Line) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn fabric_hop_adds_to_every_traversal() {
        let net = NetConfig::new(0.0, 1.0);
        let mut f = Fabric::new(&[net], 3.6, &[share(1.0)], 25.0, 1e4);
        assert_eq!(f.request_latency(0), 25.0);
        let t = f.send_down(0, 0, 0.0, 100, Class::Line);
        assert!((t - 125.0).abs() < 1e-9, "serialization + hop: {t}");
        let u = f.send_up(0, 0, 0.0, 100, Class::Line);
        assert!((u - 125.0).abs() < 1e-9);
    }

    #[test]
    fn partitioned_tenant_share_splits_classes() {
        let net = NetConfig::new(0.0, 1.0);
        let sh = TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 };
        let f = Fabric::new(&[net], 14.4, &[sh, sh], 0.0, 1e4);
        // 4 B/cyc port, 2 B/cyc per tenant, 25% of that for lines.
        assert!((f.down_rate(0, 0, Class::Line) - 0.5).abs() < 1e-12);
        assert!((f.down_rate(0, 0, Class::Page) - 1.5).abs() < 1e-12);
    }
}
