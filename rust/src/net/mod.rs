//! Network substrate: bandwidth-serialized links with switch latency,
//! per-interval utilization accounting, optional §4.1 bandwidth
//! partitioning, the Fig. 13/14 disturbance injector, and piecewise
//! time-varying rate/latency schedules (`NetSchedule`).

pub mod disturbance;
pub mod fabric;
pub mod link;

pub use disturbance::{Disturbance, NetPhase, NetSchedule, Phase, ScheduleHandle};
pub use fabric::Fabric;
pub use link::{
    proportional_split, work_conserving_issue, work_conserving_plan, BwChannel, Class, Link,
    Transfer,
};
