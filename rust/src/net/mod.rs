//! Network substrate: bandwidth-serialized links with switch latency,
//! per-interval utilization accounting, optional §4.1 bandwidth
//! partitioning, and the Fig. 13/14 disturbance injector.

pub mod disturbance;
pub mod fabric;
pub mod link;

pub use disturbance::{Disturbance, Phase};
pub use fabric::Fabric;
pub use link::{BwChannel, Class, Link, Transfer};
