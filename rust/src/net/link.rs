//! Bandwidth-serialized channel — the core timing resource of the
//! simulator.
//!
//! A `BwChannel` serializes transfers at a fixed bytes/cycle rate and
//! tracks per-interval busy time for utilization reporting (Fig. 19).  A
//! `Link` composes switch latency with either one shared channel or two
//! partitioned sub-channels (DaeMon's §4.1 approximate bandwidth
//! partitioning: the queue controller's alternate serving reserves a fixed
//! fraction for each class *even when the other queue is empty*, so the
//! partitions are strict).

/// A transfer scheduled on a channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Transfer {
    pub start: f64,
    pub end: f64,
}

pub struct BwChannel {
    bytes_per_cycle: f64,
    next_free: f64,
    /// Interval length (cycles) for utilization accounting.
    interval: f64,
    /// Busy cycles accumulated per interval index.
    busy: Vec<f64>,
    pub bytes_moved: u64,
}

impl BwChannel {
    pub fn new(bytes_per_cycle: f64, interval_cycles: f64) -> Self {
        assert!(bytes_per_cycle > 0.0);
        Self {
            bytes_per_cycle,
            next_free: 0.0,
            interval: interval_cycles.max(1.0),
            busy: Vec::new(),
            bytes_moved: 0,
        }
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes_per_cycle
    }

    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Queue occupancy ahead of a request issued at `now`, in cycles.
    pub fn backlog(&self, now: f64) -> f64 {
        (self.next_free - now).max(0.0)
    }

    /// Schedule `bytes` at time `now`; FIFO behind earlier transfers.
    pub fn transfer(&mut self, now: f64, bytes: u64) -> Transfer {
        let start = self.next_free.max(now);
        let dur = bytes as f64 / self.bytes_per_cycle;
        let end = start + dur;
        self.next_free = end;
        self.bytes_moved += bytes;
        self.account(start, end);
        Transfer { start, end }
    }

    /// Inject external occupancy (network disturbance, Fig. 13/14): other
    /// tenants' packets consume the link without producing a result.
    pub fn inject(&mut self, now: f64, bytes: u64) {
        self.transfer(now, bytes);
        self.bytes_moved -= bytes; // injected traffic is not ours
    }

    fn account(&mut self, start: f64, end: f64) {
        let mut t = start;
        while t < end {
            let idx = (t / self.interval) as usize;
            if self.busy.len() <= idx {
                self.busy.resize(idx + 1, 0.0);
            }
            let bound = (idx as f64 + 1.0) * self.interval;
            let slice = end.min(bound) - t;
            self.busy[idx] += slice;
            t = bound;
        }
    }

    /// Mean utilization in [0,1] over `[0, horizon_cycles)`.  Busy cycles
    /// recorded past the horizon (transfers that straddle or start after
    /// it) are clipped: interval `i` contributes at most the portion of
    /// `[i*interval, (i+1)*interval)` that lies before the horizon —
    /// otherwise a transfer draining after the run's end inflates the
    /// Fig. 19 numbers above what the link carried within the run.
    ///
    /// Accounting is bucketed per interval (positions within a bucket are
    /// not stored), so inside the one straddling bucket the clip is an
    /// upper bound: busy time there may actually lie after the horizon.
    /// The residual overcount is bounded by `interval / horizon` (one
    /// bucket out of a whole run, <1% at the default 100µs interval) —
    /// exact clipping would need per-transfer segments.
    pub fn utilization(&self, horizon_cycles: f64) -> f64 {
        if horizon_cycles <= 0.0 {
            return 0.0;
        }
        let mut total_busy = 0.0;
        for (idx, &busy) in self.busy.iter().enumerate() {
            let start = idx as f64 * self.interval;
            if start >= horizon_cycles {
                break;
            }
            let covered = (horizon_cycles - start).min(self.interval);
            total_busy += busy.min(covered);
        }
        (total_busy / horizon_cycles).min(1.0)
    }

    /// Per-interval utilization series (for the disturbance time plots).
    pub fn utilization_series(&self) -> Vec<f64> {
        self.busy.iter().map(|b| (b / self.interval).min(1.0)).collect()
    }
}

/// Traffic class on a partitioned link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Line,
    Page,
}

/// A network hop: switch latency + bandwidth, optionally partitioned.
pub struct Link {
    pub switch_cycles: f64,
    /// `None` partition ⇒ single shared FIFO channel.
    shared: Option<BwChannel>,
    line_chan: Option<BwChannel>,
    page_chan: Option<BwChannel>,
}

impl Link {
    /// Unpartitioned link (Remote, cache-line, LC, cache-line+page).
    pub fn shared(switch_cycles: f64, bytes_per_cycle: f64, interval: f64) -> Self {
        Self {
            switch_cycles,
            shared: Some(BwChannel::new(bytes_per_cycle, interval)),
            line_chan: None,
            page_chan: None,
        }
    }

    /// Partitioned link (§4.1): `ratio` of bandwidth reserved for lines.
    pub fn partitioned(
        switch_cycles: f64,
        bytes_per_cycle: f64,
        ratio: f64,
        interval: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&ratio) && ratio > 0.0);
        Self {
            switch_cycles,
            shared: None,
            line_chan: Some(BwChannel::new(bytes_per_cycle * ratio, interval)),
            page_chan: Some(BwChannel::new(bytes_per_cycle * (1.0 - ratio), interval)),
        }
    }

    pub fn is_partitioned(&self) -> bool {
        self.shared.is_none()
    }

    fn chan_mut(&mut self, class: Class) -> &mut BwChannel {
        if let Some(c) = self.shared.as_mut() {
            return c;
        }
        match class {
            Class::Line => self.line_chan.as_mut().unwrap(),
            Class::Page => self.page_chan.as_mut().unwrap(),
        }
    }

    fn chan(&self, class: Class) -> &BwChannel {
        if let Some(c) = self.shared.as_ref() {
            return c;
        }
        match class {
            Class::Line => self.line_chan.as_ref().unwrap(),
            Class::Page => self.page_chan.as_ref().unwrap(),
        }
    }

    /// Send `bytes` of `class` at `now`; returns arrival time at the far
    /// end (serialization + switch latency).
    pub fn send(&mut self, now: f64, bytes: u64, class: Class) -> f64 {
        let sw = self.switch_cycles;
        let t = self.chan_mut(class).transfer(now, bytes);
        t.end + sw
    }

    /// Queue backlog for `class` at `now` (cycles).
    pub fn backlog(&self, now: f64, class: Class) -> f64 {
        self.chan(class).backlog(now)
    }

    /// Service rate of the channel carrying `class`, bytes/cycle.
    pub fn rate(&self, class: Class) -> f64 {
        self.chan(class).bytes_per_cycle()
    }

    /// Disturbance injection on all channels proportionally.
    pub fn inject(&mut self, now: f64, bytes: u64) {
        if let Some(c) = self.shared.as_mut() {
            c.inject(now, bytes);
        } else {
            // Split by capacity share.
            let lc = self.line_chan.as_mut().unwrap();
            let lshare = lc.bytes_per_cycle();
            let pc_rate = self.page_chan.as_ref().unwrap().bytes_per_cycle();
            let lb = (bytes as f64 * lshare / (lshare + pc_rate)) as u64;
            self.line_chan.as_mut().unwrap().inject(now, lb);
            self.page_chan.as_mut().unwrap().inject(now, bytes - lb);
        }
    }

    pub fn bytes_moved(&self) -> u64 {
        match &self.shared {
            Some(c) => c.bytes_moved,
            None => {
                self.line_chan.as_ref().unwrap().bytes_moved
                    + self.page_chan.as_ref().unwrap().bytes_moved
            }
        }
    }

    /// Utilization over `[0, horizon)` — capacity-weighted across channels.
    pub fn utilization(&self, horizon: f64) -> f64 {
        match &self.shared {
            Some(c) => c.utilization(horizon),
            None => {
                let lc = self.line_chan.as_ref().unwrap();
                let pc = self.page_chan.as_ref().unwrap();
                let wl = lc.bytes_per_cycle();
                let wp = pc.bytes_per_cycle();
                (lc.utilization(horizon) * wl + pc.utilization(horizon) * wp)
                    / (wl + wp)
            }
        }
    }

    pub fn utilization_series(&self) -> Vec<f64> {
        match &self.shared {
            Some(c) => c.utilization_series(),
            None => {
                let a = self.line_chan.as_ref().unwrap().utilization_series();
                let b = self.page_chan.as_ref().unwrap().utilization_series();
                let n = a.len().max(b.len());
                let wl = self.line_chan.as_ref().unwrap().bytes_per_cycle();
                let wp = self.page_chan.as_ref().unwrap().bytes_per_cycle();
                (0..n)
                    .map(|i| {
                        let x = a.get(i).copied().unwrap_or(0.0);
                        let y = b.get(i).copied().unwrap_or(0.0);
                        (x * wl + y * wp) / (wl + wp)
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_back_to_back() {
        let mut c = BwChannel::new(2.0, 1000.0);
        let a = c.transfer(0.0, 100); // 50 cycles
        assert_eq!(a, Transfer { start: 0.0, end: 50.0 });
        let b = c.transfer(10.0, 100); // queued behind a
        assert_eq!(b, Transfer { start: 50.0, end: 100.0 });
        let d = c.transfer(200.0, 100); // idle gap
        assert_eq!(d, Transfer { start: 200.0, end: 250.0 });
    }

    #[test]
    fn backlog_reflects_queue() {
        let mut c = BwChannel::new(1.0, 1000.0);
        c.transfer(0.0, 100);
        assert_eq!(c.backlog(20.0), 80.0);
        assert_eq!(c.backlog(150.0), 0.0);
    }

    #[test]
    fn utilization_accounting_spans_intervals() {
        let mut c = BwChannel::new(1.0, 100.0);
        c.transfer(50.0, 100); // busy 50..150: half of interval 0 and 1
        let series = c.utilization_series();
        assert!((series[0] - 0.5).abs() < 1e-9);
        assert!((series[1] - 0.5).abs() < 1e-9);
        assert!((c.utilization(200.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn utilization_clips_busy_time_past_horizon() {
        // Transfer straddles the horizon: busy 50..150, horizon 100.
        let mut c = BwChannel::new(1.0, 100.0);
        c.transfer(50.0, 100);
        // Pre-fix this summed all 100 busy cycles against a 100-cycle
        // horizon (reporting 1.0); only the 50 cycles in [0,100) count.
        assert!((c.utilization(100.0) - 0.5).abs() < 1e-9, "{}", c.utilization(100.0));
        // Intervals entirely past the horizon contribute nothing.
        let mut d = BwChannel::new(1.0, 100.0);
        d.transfer(250.0, 50); // busy 250..300
        assert_eq!(d.utilization(200.0), 0.0);
        assert!((d.utilization(300.0) - 50.0 / 300.0).abs() < 1e-9);
        // Horizon beyond all activity: unchanged accounting.
        assert!((c.utilization(200.0) - 0.5).abs() < 1e-9);
        // Mid-bucket horizon with busy time after it in the same bucket:
        // the per-interval accounting can only clip to the covered span
        // (an upper bound, documented) — never more than that.
        let mut e = BwChannel::new(1.0, 100.0);
        e.transfer(120.0, 60); // busy 120..180, all inside bucket 1
        let u = e.utilization(150.0);
        assert!((u - 50.0 / 150.0).abs() < 1e-9, "clip to covered span: {u}");
        assert!((e.utilization(180.0) - 60.0 / 180.0).abs() < 1e-9);
    }

    #[test]
    fn partitioned_link_isolates_classes() {
        let mut l = Link::partitioned(10.0, 4.0, 0.25, 1000.0);
        // Saturate the page channel (3 B/cyc).
        let page_arr = l.send(0.0, 3000, Class::Page); // 1000 cyc + 10
        assert!((page_arr - 1010.0).abs() < 1e-9);
        // Line goes through its own 1 B/cyc partition without queueing.
        let line_arr = l.send(0.0, 64, Class::Line);
        assert!((line_arr - 74.0).abs() < 1e-9);
    }

    #[test]
    fn shared_link_queues_lines_behind_pages() {
        let mut l = Link::shared(10.0, 4.0, 1000.0);
        let page_arr = l.send(0.0, 4096, Class::Page); // 1024 cyc
        let line_arr = l.send(0.0, 64, Class::Line); // queued behind
        assert!(line_arr > page_arr - 10.0, "{line_arr} vs {page_arr}");
    }

    #[test]
    fn injection_consumes_bandwidth_but_not_bytes_moved() {
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        l.inject(0.0, 500);
        let a = l.send(0.0, 100, Class::Line);
        assert!((a - 600.0).abs() < 1e-9);
        assert_eq!(l.bytes_moved(), 100);
    }

    #[test]
    fn partitioned_utilization_is_weighted() {
        let mut l = Link::partitioned(0.0, 4.0, 0.25, 100.0);
        // Fill line channel (1 B/c) for 100 cycles; page idle.
        l.send(0.0, 100, Class::Line);
        let u = l.utilization(100.0);
        assert!((u - 0.25).abs() < 1e-9, "{u}");
    }

    #[test]
    fn fifo_order_property() {
        crate::util::proptest::check(0x71F0, 30, |rng| {
            let mut c = BwChannel::new(1.0 + rng.f64() * 4.0, 1000.0);
            let mut last_end: f64 = 0.0;
            let mut now: f64 = 0.0;
            for _ in 0..50 {
                now += rng.f64() * 20.0;
                let t = c.transfer(now, 1 + rng.below(500));
                // FIFO: starts no earlier than request time or prior end.
                assert!(t.start + 1e-9 >= now);
                assert!(t.start + 1e-9 >= last_end);
                assert!(t.end > t.start);
                last_end = t.end;
            }
        });
    }
}
