//! Bandwidth-serialized channel — the core timing resource of the
//! simulator.
//!
//! A `BwChannel` serializes transfers at a nominal bytes/cycle rate —
//! optionally modulated by a piecewise-constant
//! [`NetSchedule`](crate::net::disturbance::NetSchedule) of
//! rate/latency phases (§6's time-varying conditions) — and tracks
//! per-interval busy time for utilization reporting (Fig. 19).  A
//! `Link` composes switch latency with either one shared channel or two
//! partitioned sub-channels (DaeMon's §4.1 approximate bandwidth
//! partitioning: the queue controller's alternate serving reserves a fixed
//! fraction for each class *even when the other queue is empty*, so the
//! partitions are strict).  Without a schedule the timing math is
//! bit-identical to the historical fixed-rate behavior.

use crate::net::disturbance::ScheduleHandle;

/// A transfer scheduled on a channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Transfer {
    pub start: f64,
    pub end: f64,
}

pub struct BwChannel {
    bytes_per_cycle: f64,
    next_free: f64,
    /// Interval length (cycles) for utilization accounting.
    interval: f64,
    /// Busy cycles accumulated per interval index.
    busy: Vec<f64>,
    /// Time-varying rate schedule (`None` = fixed nominal rate, with the
    /// exact historical duration math).
    schedule: Option<ScheduleHandle>,
    pub bytes_moved: u64,
}

impl BwChannel {
    pub fn new(bytes_per_cycle: f64, interval_cycles: f64) -> Self {
        assert!(bytes_per_cycle > 0.0);
        Self {
            bytes_per_cycle,
            next_free: 0.0,
            interval: interval_cycles.max(1.0),
            busy: Vec::new(),
            schedule: None,
            bytes_moved: 0,
        }
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.bytes_per_cycle
    }

    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Install (or clear) a rate schedule; applies to subsequent
    /// transfers.
    pub fn set_schedule(&mut self, schedule: Option<ScheduleHandle>) {
        self.schedule = schedule;
    }

    /// Retune the nominal service rate.  Applies only to transfers issued
    /// after the call — `next_free` and accumulated busy accounting are
    /// untouched, so already-scheduled transfers keep the timing they were
    /// issued with and the change is deterministic at any actuation cycle.
    pub fn set_rate(&mut self, bytes_per_cycle: f64) {
        assert!(
            bytes_per_cycle > 0.0 && bytes_per_cycle.is_finite(),
            "channel rate must be positive and finite, got {bytes_per_cycle}"
        );
        self.bytes_per_cycle = bytes_per_cycle;
    }

    /// Queue occupancy ahead of a request issued at `now`, in cycles.
    pub fn backlog(&self, now: f64) -> f64 {
        (self.next_free - now).max(0.0)
    }

    /// Whether the channel has no queued or in-service transfer at `now`.
    pub fn idle_at(&self, now: f64) -> bool {
        self.next_free <= now
    }

    /// Schedule `bytes` at time `now`; FIFO behind earlier transfers.
    pub fn transfer(&mut self, now: f64, bytes: u64) -> Transfer {
        let start = self.next_free.max(now);
        let end = match &self.schedule {
            None => start + bytes as f64 / self.bytes_per_cycle,
            Some(s) => s.transfer_end(start, bytes as f64, self.bytes_per_cycle),
        };
        self.next_free = end;
        self.bytes_moved += bytes;
        self.account(start, end);
        Transfer { start, end }
    }

    /// Inject external occupancy (network disturbance, Fig. 13/14): other
    /// tenants' packets consume the link without producing a result.
    pub fn inject(&mut self, now: f64, bytes: u64) {
        self.transfer(now, bytes);
        self.bytes_moved -= bytes; // injected traffic is not ours
    }

    fn account(&mut self, start: f64, end: f64) {
        let mut t = start;
        while t < end {
            let idx = (t / self.interval) as usize;
            if self.busy.len() <= idx {
                self.busy.resize(idx + 1, 0.0);
            }
            let bound = (idx as f64 + 1.0) * self.interval;
            let slice = end.min(bound) - t;
            self.busy[idx] += slice;
            t = bound;
        }
    }

    /// Mean utilization in [0,1] over `[0, horizon_cycles)`.  Busy cycles
    /// recorded past the horizon (transfers that straddle or start after
    /// it) are clipped: interval `i` contributes at most the portion of
    /// `[i*interval, (i+1)*interval)` that lies before the horizon —
    /// otherwise a transfer draining after the run's end inflates the
    /// Fig. 19 numbers above what the link carried within the run.
    ///
    /// Accounting is bucketed per interval (positions within a bucket are
    /// not stored), so inside the one straddling bucket the clip is an
    /// upper bound: busy time there may actually lie after the horizon.
    /// The residual overcount is bounded by `interval / horizon` (one
    /// bucket out of a whole run, <1% at the default 100µs interval) —
    /// exact clipping would need per-transfer segments.
    pub fn utilization(&self, horizon_cycles: f64) -> f64 {
        if horizon_cycles <= 0.0 {
            return 0.0;
        }
        let mut total_busy = 0.0;
        for (idx, &busy) in self.busy.iter().enumerate() {
            let start = idx as f64 * self.interval;
            if start >= horizon_cycles {
                break;
            }
            let covered = (horizon_cycles - start).min(self.interval);
            total_busy += busy.min(covered);
        }
        (total_busy / horizon_cycles).min(1.0)
    }

    /// Per-interval utilization series over `[0, horizon_cycles)` (for
    /// the disturbance/variability time plots).  Clipped at the horizon
    /// exactly like [`BwChannel::utilization`]: buckets past the horizon
    /// are dropped, and the one straddling bucket counts at most the
    /// busy time that fits in its covered span (normalized by that span,
    /// so `sum(series[i] * covered_i) / horizon == utilization(horizon)`)
    /// — otherwise the tail point reports busy time the link spent after
    /// the run ended.
    pub fn utilization_series(&self, horizon_cycles: f64) -> Vec<f64> {
        if horizon_cycles <= 0.0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for (idx, &busy) in self.busy.iter().enumerate() {
            let start = idx as f64 * self.interval;
            if start >= horizon_cycles {
                break;
            }
            let covered = (horizon_cycles - start).min(self.interval);
            out.push((busy.min(covered) / covered).min(1.0));
        }
        out
    }
}

/// Traffic class on a partitioned link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Line,
    Page,
}

impl Class {
    /// The sibling class on a partitioned resource.
    pub fn other(self) -> Class {
        match self {
            Class::Line => Class::Page,
            Class::Page => Class::Line,
        }
    }
}

/// A network hop: switch latency + bandwidth, optionally partitioned.
pub struct Link {
    pub switch_cycles: f64,
    /// `None` partition ⇒ single shared FIFO channel.
    shared: Option<BwChannel>,
    line_chan: Option<BwChannel>,
    page_chan: Option<BwChannel>,
    /// Time-varying conditions: the channels obey its rate phases; the
    /// link adds its extra switch latency (sampled at send time).
    schedule: Option<ScheduleHandle>,
}

impl Link {
    /// Unpartitioned link (Remote, cache-line, LC, cache-line+page).
    pub fn shared(switch_cycles: f64, bytes_per_cycle: f64, interval: f64) -> Self {
        Self {
            switch_cycles,
            shared: Some(BwChannel::new(bytes_per_cycle, interval)),
            line_chan: None,
            page_chan: None,
            schedule: None,
        }
    }

    /// Partitioned link (§4.1): `ratio` of bandwidth reserved for lines.
    pub fn partitioned(
        switch_cycles: f64,
        bytes_per_cycle: f64,
        ratio: f64,
        interval: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&ratio) && ratio > 0.0);
        Self {
            switch_cycles,
            shared: None,
            line_chan: Some(BwChannel::new(bytes_per_cycle * ratio, interval)),
            page_chan: Some(BwChannel::new(bytes_per_cycle * (1.0 - ratio), interval)),
            schedule: None,
        }
    }

    /// Install (or clear) a schedule of time-varying link conditions on
    /// every channel (rate phases) and on the link itself (extra switch
    /// latency).
    pub fn set_schedule(&mut self, schedule: Option<ScheduleHandle>) {
        if let Some(c) = self.shared.as_mut() {
            c.set_schedule(schedule.clone());
        }
        if let Some(c) = self.line_chan.as_mut() {
            c.set_schedule(schedule.clone());
        }
        if let Some(c) = self.page_chan.as_mut() {
            c.set_schedule(schedule.clone());
        }
        self.schedule = schedule;
    }

    pub fn is_partitioned(&self) -> bool {
        self.shared.is_none()
    }

    fn chan_mut(&mut self, class: Class) -> &mut BwChannel {
        if let Some(c) = self.shared.as_mut() {
            return c;
        }
        match class {
            Class::Line => self.line_chan.as_mut().unwrap(),
            Class::Page => self.page_chan.as_mut().unwrap(),
        }
    }

    fn chan(&self, class: Class) -> &BwChannel {
        if let Some(c) = self.shared.as_ref() {
            return c;
        }
        match class {
            Class::Line => self.line_chan.as_ref().unwrap(),
            Class::Page => self.page_chan.as_ref().unwrap(),
        }
    }

    /// Send `bytes` of `class` at `now`; returns arrival time at the far
    /// end (serialization + switch latency, plus any schedule-phase extra
    /// latency sampled at send time).
    pub fn send(&mut self, now: f64, bytes: u64, class: Class) -> f64 {
        let mut sw = self.switch_cycles;
        if let Some(s) = &self.schedule {
            sw += s.extra_latency_at(now);
        }
        let t = self.chan_mut(class).transfer(now, bytes);
        t.end + sw
    }

    /// Queue backlog for `class` at `now` (cycles).
    pub fn backlog(&self, now: f64, class: Class) -> f64 {
        self.chan(class).backlog(now)
    }

    /// Whether the channel carrying `class` is idle at `now`.
    pub fn idle(&self, now: f64, class: Class) -> bool {
        self.chan(class).idle_at(now)
    }

    /// Service rate of the channel carrying `class`, bytes/cycle.
    pub fn rate(&self, class: Class) -> f64 {
        self.chan(class).bytes_per_cycle()
    }

    /// Total nominal capacity across channels, bytes/cycle.
    pub fn total_rate(&self) -> f64 {
        match &self.shared {
            Some(c) => c.bytes_per_cycle(),
            None => {
                self.line_chan.as_ref().unwrap().bytes_per_cycle()
                    + self.page_chan.as_ref().unwrap().bytes_per_cycle()
            }
        }
    }

    /// Re-split a partitioned link's `total` capacity so `ratio` of it is
    /// reserved for lines (closed-loop `ratio-tune` actuation).  No-op on
    /// a shared link, which has no class partition to retune.  Like
    /// [`BwChannel::set_rate`], affects only subsequently issued
    /// transfers.
    pub fn retune_partition(&mut self, total: f64, ratio: f64) {
        assert!((0.0..1.0).contains(&ratio) && ratio > 0.0, "bad line ratio {ratio}");
        if self.shared.is_some() {
            return;
        }
        self.line_chan.as_mut().unwrap().set_rate(total * ratio);
        self.page_chan.as_mut().unwrap().set_rate(total * (1.0 - ratio));
    }

    /// Rescale the link to a new total capacity, preserving the current
    /// line/page split on a partitioned link (closed-loop
    /// `share-rebalance` actuation).
    pub fn set_capacity(&mut self, total: f64) {
        match &mut self.shared {
            Some(c) => c.set_rate(total),
            None => {
                let lr = self.line_chan.as_ref().unwrap().bytes_per_cycle();
                let pr = self.page_chan.as_ref().unwrap().bytes_per_cycle();
                let ratio = lr / (lr + pr);
                self.line_chan.as_mut().unwrap().set_rate(total * ratio);
                self.page_chan.as_mut().unwrap().set_rate(total * (1.0 - ratio));
            }
        }
    }

    /// The schedule's bandwidth multiplier at `now` (1.0 when nominal or
    /// unscheduled) — the closed loop's link-condition distress signal.
    /// Deliberately the *scale*, not the absolute rate: it is invariant
    /// under controller rate actuation, so observation cannot feed back
    /// on actuation.
    pub fn rate_scale_at(&self, now: f64) -> f64 {
        self.schedule.as_ref().map_or(1.0, |s| s.rate_scale_at(now))
    }

    /// Disturbance injection on all channels proportionally.
    pub fn inject(&mut self, now: f64, bytes: u64) {
        if let Some(c) = self.shared.as_mut() {
            c.inject(now, bytes);
        } else {
            // Split by capacity share.
            let lc = self.line_chan.as_mut().unwrap();
            let lshare = lc.bytes_per_cycle();
            let pc_rate = self.page_chan.as_ref().unwrap().bytes_per_cycle();
            let lb = (bytes as f64 * lshare / (lshare + pc_rate)) as u64;
            self.line_chan.as_mut().unwrap().inject(now, lb);
            self.page_chan.as_mut().unwrap().inject(now, bytes - lb);
        }
    }

    pub fn bytes_moved(&self) -> u64 {
        match &self.shared {
            Some(c) => c.bytes_moved,
            None => {
                self.line_chan.as_ref().unwrap().bytes_moved
                    + self.page_chan.as_ref().unwrap().bytes_moved
            }
        }
    }

    /// Utilization over `[0, horizon)` — capacity-weighted across channels.
    pub fn utilization(&self, horizon: f64) -> f64 {
        match &self.shared {
            Some(c) => c.utilization(horizon),
            None => {
                let lc = self.line_chan.as_ref().unwrap();
                let pc = self.page_chan.as_ref().unwrap();
                let wl = lc.bytes_per_cycle();
                let wp = pc.bytes_per_cycle();
                (lc.utilization(horizon) * wl + pc.utilization(horizon) * wp)
                    / (wl + wp)
            }
        }
    }

    /// Per-interval utilization series over `[0, horizon)` —
    /// capacity-weighted across channels, horizon-clipped like
    /// [`Link::utilization`].
    pub fn utilization_series(&self, horizon: f64) -> Vec<f64> {
        match &self.shared {
            Some(c) => c.utilization_series(horizon),
            None => {
                let a = self.line_chan.as_ref().unwrap().utilization_series(horizon);
                let b = self.page_chan.as_ref().unwrap().utilization_series(horizon);
                let n = a.len().max(b.len());
                let wl = self.line_chan.as_ref().unwrap().bytes_per_cycle();
                let wp = self.page_chan.as_ref().unwrap().bytes_per_cycle();
                (0..n)
                    .map(|i| {
                        let x = a.get(i).copied().unwrap_or(0.0);
                        let y = b.get(i).copied().unwrap_or(0.0);
                        (x * wl + y * wp) / (wl + wp)
                    })
                    .collect()
            }
        }
    }
}

/// Work-conserving candidate plan — the single borrow policy shared by
/// the fabric ports and the memory-engine bus queues, so the two can
/// never diverge.  Candidates are `(slot, class)` channels: the owner's
/// own `class` channel first (the remainder slot of the proportional
/// split — always issued, even for zero bytes), then the sibling class
/// inside a partitioned owner, then every peer channel idle at request
/// time (same class, and the sibling when that peer is partitioned).
/// `bytes` is split across the candidates proportionally to their
/// service rates.
pub fn work_conserving_plan(
    owner: usize,
    class: Class,
    slots: usize,
    bytes: u64,
    is_partitioned: impl Fn(usize) -> bool,
    idle: impl Fn(usize, Class) -> bool,
    rate: impl Fn(usize, Class) -> f64,
) -> (Vec<(usize, Class)>, Vec<u64>) {
    let mut cands: Vec<(usize, Class)> = vec![(owner, class)];
    if is_partitioned(owner) && idle(owner, class.other()) {
        cands.push((owner, class.other()));
    }
    for u in 0..slots {
        if u == owner {
            continue;
        }
        if idle(u, class) {
            cands.push((u, class));
        }
        if is_partitioned(u) && idle(u, class.other()) {
            cands.push((u, class.other()));
        }
    }
    let rates: Vec<f64> = cands.iter().map(|&(u, c)| rate(u, c)).collect();
    let chunks = proportional_split(bytes, &rates);
    (cands, chunks)
}

/// Execute a [`work_conserving_plan`]: issue each chunk on its channel
/// via `issue(slot, class, chunk)` and return `(finish, borrowed)` —
/// the slowest chunk's completion time and the bytes served off the
/// owner's own channel.  The owner chunk (slot 0 of the plan) is always
/// issued, even zero-byte, so a plan with no idle candidates degrades
/// exactly to the strict single-channel path; borrowed zero chunks are
/// skipped.  Shared by the fabric ports and the memory-engine bus
/// queues so the execution rules can never diverge either.
pub fn work_conserving_issue(
    cands: &[(usize, Class)],
    chunks: &[u64],
    mut issue: impl FnMut(usize, Class, u64) -> f64,
) -> (f64, u64) {
    let mut finish = f64::NEG_INFINITY;
    let mut borrowed = 0u64;
    for (k, (&(u, c), &chunk)) in cands.iter().zip(chunks).enumerate() {
        if chunk == 0 && k > 0 {
            continue;
        }
        finish = finish.max(issue(u, c, chunk));
        if k > 0 {
            borrowed += chunk;
        }
    }
    (finish, borrowed)
}

/// Split `bytes` across capacity `rates` proportionally — the
/// work-conserving redistribution rule shared by the fabric ports and
/// the memory-engine bus queues.  Slot `i > 0` gets
/// `floor(bytes * rates[i] / sum)`; slot 0 (the requesting owner) takes
/// the remainder, so no byte is ever lost and the result is
/// deterministic.
pub fn proportional_split(bytes: u64, rates: &[f64]) -> Vec<u64> {
    let total: f64 = rates.iter().sum();
    let mut out = vec![0u64; rates.len()];
    if bytes == 0 || rates.is_empty() || total <= 0.0 {
        if let Some(first) = out.first_mut() {
            *first = bytes;
        }
        return out;
    }
    let mut assigned = 0u64;
    for (i, &r) in rates.iter().enumerate().skip(1) {
        let share = (bytes as f64 * (r / total)).floor() as u64;
        out[i] = share;
        assigned += share;
    }
    out[0] = bytes - assigned;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_back_to_back() {
        let mut c = BwChannel::new(2.0, 1000.0);
        let a = c.transfer(0.0, 100); // 50 cycles
        assert_eq!(a, Transfer { start: 0.0, end: 50.0 });
        let b = c.transfer(10.0, 100); // queued behind a
        assert_eq!(b, Transfer { start: 50.0, end: 100.0 });
        let d = c.transfer(200.0, 100); // idle gap
        assert_eq!(d, Transfer { start: 200.0, end: 250.0 });
    }

    #[test]
    fn backlog_reflects_queue() {
        let mut c = BwChannel::new(1.0, 1000.0);
        c.transfer(0.0, 100);
        assert_eq!(c.backlog(20.0), 80.0);
        assert_eq!(c.backlog(150.0), 0.0);
    }

    #[test]
    fn utilization_accounting_spans_intervals() {
        let mut c = BwChannel::new(1.0, 100.0);
        c.transfer(50.0, 100); // busy 50..150: half of interval 0 and 1
        let series = c.utilization_series(200.0);
        assert!((series[0] - 0.5).abs() < 1e-9);
        assert!((series[1] - 0.5).abs() < 1e-9);
        assert!((c.utilization(200.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn series_clips_at_horizon_like_utilization() {
        // Regression: the same straddling transfer through both paths.
        // Busy 50..150 over 100-cycle buckets; horizon 100 cuts bucket 1
        // entirely and leaves 50 busy cycles in bucket 0 — previously the
        // series reported bucket 1's post-run busy time as a tail point.
        let mut c = BwChannel::new(1.0, 100.0);
        c.transfer(50.0, 100);
        assert!((c.utilization(100.0) - 0.5).abs() < 1e-9);
        let s = c.utilization_series(100.0);
        assert_eq!(s.len(), 1, "bucket past the horizon must be dropped");
        assert!((s[0] - 0.5).abs() < 1e-9);
        // Mid-bucket horizon: bucket 1 is covered for 20 cycles and its
        // 50 busy cycles clip to the covered span (fully busy).
        let s = c.utilization_series(120.0);
        assert_eq!(s.len(), 2);
        assert!((s[0] - 0.5).abs() < 1e-9);
        assert!((s[1] - 1.0).abs() < 1e-9);
        // Covered-span weighting keeps the two paths consistent:
        // sum(series[i] * covered_i) / horizon == utilization(horizon).
        let weighted = (s[0] * 100.0 + s[1] * 20.0) / 120.0;
        assert!((weighted - c.utilization(120.0)).abs() < 1e-9);
        // Horizon beyond all activity: the unclipped shape.
        assert_eq!(c.utilization_series(1000.0), vec![0.5, 0.5]);
        assert_eq!(c.utilization_series(0.0), Vec::<f64>::new());
    }

    #[test]
    fn partitioned_series_is_weighted_and_clipped() {
        let mut l = Link::partitioned(0.0, 4.0, 0.25, 100.0);
        // Fill the 1 B/c line channel for 150 cycles; page idle.
        l.send(0.0, 150, Class::Line);
        let s = l.utilization_series(100.0);
        assert_eq!(s.len(), 1, "straddling line bucket clipped at horizon");
        assert!((s[0] - 0.25).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn schedule_scales_rate_and_latency() {
        use crate::net::disturbance::NetSchedule;
        use std::sync::Arc;
        // Degraded [0,100): half rate + 7 extra switch cycles.
        let sched = Arc::new(NetSchedule::square_wave(100.0, 0.5, 7.0, 100.0));
        let mut l = Link::shared(10.0, 1.0, 1000.0);
        l.set_schedule(Some(sched));
        // 40 bytes at t=0: 80 cycles at half rate + 10 switch + 7 extra.
        let a = l.send(0.0, 40, Class::Line);
        assert!((a - 97.0).abs() < 1e-9, "{a}");
        // Next transfer starts in the nominal tail (idle since 80): full
        // rate, and the extra latency no longer applies at send time 150.
        let b = l.send(150.0, 40, Class::Line);
        assert!((b - 200.0).abs() < 1e-9, "{b}");
        // Clearing the schedule restores fixed-rate timing.
        l.set_schedule(None);
        let c = l.send(1000.0, 40, Class::Line);
        assert!((c - 1050.0).abs() < 1e-9, "{c}");
    }

    #[test]
    fn class_other_and_idle() {
        assert_eq!(Class::Line.other(), Class::Page);
        assert_eq!(Class::Page.other(), Class::Line);
        let mut l = Link::partitioned(0.0, 4.0, 0.25, 1000.0);
        assert!(l.idle(0.0, Class::Line) && l.idle(0.0, Class::Page));
        l.send(0.0, 100, Class::Line); // 100 cycles on the 1 B/c channel
        assert!(!l.idle(50.0, Class::Line));
        assert!(l.idle(50.0, Class::Page), "sibling class unaffected");
        assert!(l.idle(100.0, Class::Line), "idle again once drained");
    }

    #[test]
    fn work_conserving_plan_orders_and_filters_candidates() {
        // 3 slots; owner 0 partitioned with an idle sibling; slot 1 idle
        // (unpartitioned); slot 2 busy.  Owner-first ordering is what
        // makes slot 0 the remainder taker.
        let partitioned = [true, false, false];
        let idle = [true, true, false];
        let (cands, chunks) = work_conserving_plan(
            0,
            Class::Line,
            3,
            100,
            |u| partitioned[u],
            |u, _| idle[u],
            |_, _| 1.0,
        );
        assert_eq!(
            cands,
            vec![(0, Class::Line), (0, Class::Page), (1, Class::Line)]
        );
        assert_eq!(chunks, vec![34, 33, 33]);
        // Nothing idle: the owner carries everything.
        let (cands, chunks) = work_conserving_plan(
            0,
            Class::Line,
            3,
            100,
            |_| false,
            |_, _| false,
            |_, _| 1.0,
        );
        assert_eq!(cands, vec![(0, Class::Line)]);
        assert_eq!(chunks, vec![100]);
    }

    #[test]
    fn proportional_split_conserves_bytes() {
        assert_eq!(proportional_split(100, &[1.0, 1.0]), vec![50, 50]);
        assert_eq!(proportional_split(100, &[1.0, 3.0]), vec![25, 75]);
        // Remainder goes to the owner slot.
        assert_eq!(proportional_split(10, &[1.0, 1.0, 1.0]), vec![4, 3, 3]);
        assert_eq!(proportional_split(7, &[2.0]), vec![7]);
        assert_eq!(proportional_split(0, &[1.0, 1.0]), vec![0, 0]);
        // Tiny transfers stay whole on the owner.
        assert_eq!(proportional_split(1, &[1.0, 5.0]), vec![1, 0]);
        crate::util::proptest::check(0x5917, 40, |rng| {
            let n = 1 + rng.index(5);
            let rates: Vec<f64> = (0..n).map(|_| 0.1 + rng.f64() * 9.9).collect();
            let bytes = rng.below(1 << 20);
            let split = proportional_split(bytes, &rates);
            assert_eq!(split.iter().sum::<u64>(), bytes, "bytes lost in split");
        });
    }

    #[test]
    fn utilization_clips_busy_time_past_horizon() {
        // Transfer straddles the horizon: busy 50..150, horizon 100.
        let mut c = BwChannel::new(1.0, 100.0);
        c.transfer(50.0, 100);
        // Pre-fix this summed all 100 busy cycles against a 100-cycle
        // horizon (reporting 1.0); only the 50 cycles in [0,100) count.
        assert!((c.utilization(100.0) - 0.5).abs() < 1e-9, "{}", c.utilization(100.0));
        // Intervals entirely past the horizon contribute nothing.
        let mut d = BwChannel::new(1.0, 100.0);
        d.transfer(250.0, 50); // busy 250..300
        assert_eq!(d.utilization(200.0), 0.0);
        assert!((d.utilization(300.0) - 50.0 / 300.0).abs() < 1e-9);
        // Horizon beyond all activity: unchanged accounting.
        assert!((c.utilization(200.0) - 0.5).abs() < 1e-9);
        // Mid-bucket horizon with busy time after it in the same bucket:
        // the per-interval accounting can only clip to the covered span
        // (an upper bound, documented) — never more than that.
        let mut e = BwChannel::new(1.0, 100.0);
        e.transfer(120.0, 60); // busy 120..180, all inside bucket 1
        let u = e.utilization(150.0);
        assert!((u - 50.0 / 150.0).abs() < 1e-9, "clip to covered span: {u}");
        assert!((e.utilization(180.0) - 60.0 / 180.0).abs() < 1e-9);
    }

    #[test]
    fn partitioned_link_isolates_classes() {
        let mut l = Link::partitioned(10.0, 4.0, 0.25, 1000.0);
        // Saturate the page channel (3 B/cyc).
        let page_arr = l.send(0.0, 3000, Class::Page); // 1000 cyc + 10
        assert!((page_arr - 1010.0).abs() < 1e-9);
        // Line goes through its own 1 B/cyc partition without queueing.
        let line_arr = l.send(0.0, 64, Class::Line);
        assert!((line_arr - 74.0).abs() < 1e-9);
    }

    #[test]
    fn shared_link_queues_lines_behind_pages() {
        let mut l = Link::shared(10.0, 4.0, 1000.0);
        let page_arr = l.send(0.0, 4096, Class::Page); // 1024 cyc
        let line_arr = l.send(0.0, 64, Class::Line); // queued behind
        assert!(line_arr > page_arr - 10.0, "{line_arr} vs {page_arr}");
    }

    #[test]
    fn injection_consumes_bandwidth_but_not_bytes_moved() {
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        l.inject(0.0, 500);
        let a = l.send(0.0, 100, Class::Line);
        assert!((a - 600.0).abs() < 1e-9);
        assert_eq!(l.bytes_moved(), 100);
    }

    #[test]
    fn partitioned_utilization_is_weighted() {
        let mut l = Link::partitioned(0.0, 4.0, 0.25, 100.0);
        // Fill line channel (1 B/c) for 100 cycles; page idle.
        l.send(0.0, 100, Class::Line);
        let u = l.utilization(100.0);
        assert!((u - 0.25).abs() < 1e-9, "{u}");
    }

    #[test]
    fn retune_affects_only_future_transfers() {
        let mut c = BwChannel::new(1.0, 1000.0);
        let a = c.transfer(0.0, 100); // 100 cycles at 1 B/c
        assert_eq!(a, Transfer { start: 0.0, end: 100.0 });
        c.set_rate(2.0);
        // Queued behind `a` but served at the new rate.
        let b = c.transfer(0.0, 100);
        assert_eq!(b, Transfer { start: 100.0, end: 150.0 });
    }

    #[test]
    fn link_retune_partition_and_capacity() {
        let mut l = Link::partitioned(0.0, 4.0, 0.25, 1000.0);
        assert!((l.total_rate() - 4.0).abs() < 1e-9);
        assert!((l.rate(Class::Line) - 1.0).abs() < 1e-9);
        l.retune_partition(4.0, 0.5);
        assert!((l.rate(Class::Line) - 2.0).abs() < 1e-9);
        assert!((l.rate(Class::Page) - 2.0).abs() < 1e-9);
        // Capacity rescale preserves the current 50/50 split.
        l.set_capacity(8.0);
        assert!((l.rate(Class::Line) - 4.0).abs() < 1e-9);
        assert!((l.rate(Class::Page) - 4.0).abs() < 1e-9);
        assert!((l.total_rate() - 8.0).abs() < 1e-9);
        // Shared links rescale their single channel; retune is a no-op.
        let mut s = Link::shared(0.0, 4.0, 1000.0);
        s.retune_partition(4.0, 0.5);
        assert!((s.total_rate() - 4.0).abs() < 1e-9);
        s.set_capacity(2.0);
        assert!((s.rate(Class::Line) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn rate_scale_reports_schedule_phase() {
        use crate::net::disturbance::NetSchedule;
        use std::sync::Arc;
        let mut l = Link::shared(0.0, 1.0, 1000.0);
        assert_eq!(l.rate_scale_at(0.0), 1.0, "unscheduled links are nominal");
        let sched = Arc::new(NetSchedule::square_wave(100.0, 0.5, 0.0, 400.0));
        l.set_schedule(Some(sched));
        assert!((l.rate_scale_at(50.0) - 0.5).abs() < 1e-9, "degraded phase");
        assert!((l.rate_scale_at(150.0) - 1.0).abs() < 1e-9, "nominal phase");
        // Rate actuation does not leak into the observed scale.
        l.set_capacity(0.25);
        assert!((l.rate_scale_at(50.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fifo_order_property() {
        crate::util::proptest::check(0x71F0, 30, |rng| {
            let mut c = BwChannel::new(1.0 + rng.f64() * 4.0, 1000.0);
            let mut last_end: f64 = 0.0;
            let mut now: f64 = 0.0;
            for _ in 0..50 {
                now += rng.f64() * 20.0;
                let t = c.transfer(now, 1 + rng.below(500));
                // FIFO: starts no earlier than request time or prior end.
                assert!(t.start + 1e-9 >= now);
                assert!(t.start + 1e-9 >= last_end);
                assert!(t.end > t.start);
                last_end = t.end;
            }
        });
    }
}
