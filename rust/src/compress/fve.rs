//! Frequent Value Encoding (Thuresson & Stenström [91]).
//!
//! A 256B dictionary (64 x 32-bit entries) is trained on the data; words
//! that hit the dictionary are replaced by a 6-bit index (+1 flag bit),
//! misses are emitted raw (+1 flag bit).  The paper's LC comparison
//! (Fig. 12) uses a 256B dictionary table and 6-cycle latency per line —
//! timing is charged by the simulator.

use crate::util::hash::{FxHashMap, FxHashSet};

const DICT_ENTRIES: usize = 64;

/// Build the dictionary: the `DICT_ENTRIES` most frequent words.
fn build_dict(words: &[u32]) -> Vec<u32> {
    let mut counts: FxHashMap<u32, u32> = FxHashMap::default();
    for &w in words {
        *counts.entry(w).or_insert(0) += 1;
    }
    let mut pairs: Vec<(u32, u32)> = counts.into_iter().collect();
    // Total order (count desc, then word) — map iteration order is
    // irrelevant to the chosen dictionary.
    pairs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    pairs.into_iter().take(DICT_ENTRIES).map(|(w, _)| w).collect()
}

/// Compressed size in bytes, including the dictionary itself (the hardware
/// keeps per-link dictionaries synchronized; we charge the miss-driven
/// updates by including dictionary bytes once per page).
pub fn compressed_size(data: &[u8]) -> usize {
    let words: Vec<u32> = data
        .chunks(4)
        .map(|c| {
            let mut w = [0u8; 4];
            w[..c.len()].copy_from_slice(c);
            u32::from_le_bytes(w)
        })
        .collect();
    let dict = build_dict(&words);
    let dict_set: FxHashSet<u32> = dict.iter().copied().collect();
    let mut bits: u64 = 0;
    for &w in &words {
        bits += 1; // hit/miss flag
        if dict_set.contains(&w) {
            bits += 6; // dictionary index
        } else {
            bits += 32; // raw word
        }
    }
    // Dictionary sync cost: count distinct hit values actually used.
    let used: FxHashSet<u32> =
        words.iter().copied().filter(|w| dict_set.contains(w)).collect();
    let dict_bytes = 4 * used.len();
    ((bits.div_ceil(8)) as usize + dict_bytes).min(data.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn constant_page_compresses() {
        let mut page = Vec::new();
        for _ in 0..1024 {
            page.extend_from_slice(&0xABCD_1234u32.to_le_bytes());
        }
        let sz = compressed_size(&page);
        // 1024 x 7 bits + 4B dict = ~900B.
        assert!(sz < 1024, "got {sz}");
    }

    #[test]
    fn few_distinct_values_compress() {
        let mut rng = Rng::new(10);
        let vals: Vec<u32> = (0..16).map(|_| rng.next_u32()).collect();
        let mut page = Vec::new();
        for _ in 0..1024 {
            page.extend_from_slice(&vals[rng.index(16)].to_le_bytes());
        }
        let sz = compressed_size(&page);
        assert!(sz < 1100, "got {sz}");
    }

    #[test]
    fn random_page_near_raw() {
        let mut rng = Rng::new(11);
        let page: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        let sz = compressed_size(&page);
        assert!(sz > 3800, "got {sz}");
        assert!(sz <= 4096);
    }

    #[test]
    fn dict_holds_most_frequent() {
        let words = vec![7u32, 7, 7, 9, 9, 1];
        let dict = build_dict(&words);
        assert_eq!(dict[0], 7);
        assert_eq!(dict[1], 9);
    }

    #[test]
    fn size_bounded_by_raw() {
        crate::util::proptest::check(0xF7E, 30, |rng| {
            let len = 4 * (1 + rng.index(1024));
            let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            assert!(compressed_size(&data) <= len);
        });
    }
}
