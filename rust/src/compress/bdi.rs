//! Base-Delta-Immediate compression (Pekhimenko et al. [73]).
//!
//! Operates per 64B cache line: the line is encoded as a base value plus
//! narrow deltas if all deltas fit a small width.  We implement the standard
//! configuration set {(8,1),(8,2),(8,4),(4,1),(4,2),(2,1)} plus the
//! zero-line and repeated-value special cases, picking the best per line.

const LINE: usize = 64;

fn all_zero(line: &[u8]) -> bool {
    line.iter().all(|&b| b == 0)
}

fn repeated_u64(line: &[u8]) -> bool {
    let first = &line[0..8];
    line.chunks_exact(8).all(|c| c == first)
}

fn fits_deltas(line: &[u8], base_size: usize, delta_size: usize) -> bool {
    let mut chunks = line.chunks_exact(base_size);
    let base = read_int(chunks.next().unwrap());
    let max: i128 = 1i128 << (8 * delta_size - 1);
    // First chunk is the base; remaining must fit signed delta.
    line.chunks_exact(base_size).all(|c| {
        let v = read_int(c);
        let d = v - base;
        d >= -max && d < max
    })
}

fn read_int(bytes: &[u8]) -> i128 {
    let mut v: i128 = 0;
    for (i, &b) in bytes.iter().enumerate() {
        v |= (b as i128) << (8 * i);
    }
    // Sign-extend.
    let bits = 8 * bytes.len();
    let sign = 1i128 << (bits - 1);
    if v & sign != 0 {
        v - (1i128 << bits)
    } else {
        v
    }
}

/// Compressed size of one 64B line under the best BDI configuration,
/// including a 1B encoding tag.
pub fn line_size(line: &[u8]) -> usize {
    assert_eq!(line.len(), LINE);
    if all_zero(line) {
        return 1 + 1; // tag + 1B zero marker
    }
    if repeated_u64(line) {
        return 1 + 8; // tag + the repeated value
    }
    let mut best = LINE + 1; // raw fallback + tag
    for &(b, d) in &[(8usize, 1usize), (8, 2), (8, 4), (4, 1), (4, 2), (2, 1)] {
        if fits_deltas(line, b, d) {
            let n = LINE / b;
            let sz = 1 + b + (n - 1) * d;
            best = best.min(sz);
        }
    }
    best
}

/// Compressed size of a page = sum over 64B lines.
pub fn compressed_size(data: &[u8]) -> usize {
    data.chunks(LINE)
        .map(|c| {
            if c.len() == LINE {
                line_size(c)
            } else {
                c.len() + 1
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_line_is_two_bytes() {
        assert_eq!(line_size(&[0u8; 64]), 2);
    }

    #[test]
    fn repeated_value_is_nine_bytes() {
        let mut line = [0u8; 64];
        for c in line.chunks_exact_mut(8) {
            c.copy_from_slice(&0x0102_0304_0506_0708u64.to_le_bytes());
        }
        assert_eq!(line_size(&line), 9);
    }

    #[test]
    fn small_deltas_compress() {
        // 8B values near a large base: base 8 + deltas 1.
        let mut line = [0u8; 64];
        let base: u64 = 0x7FFF_FFFF_0000_0000;
        for (i, c) in line.chunks_exact_mut(8).enumerate() {
            c.copy_from_slice(&(base + i as u64).to_le_bytes());
        }
        let sz = line_size(&line);
        assert_eq!(sz, 1 + 8 + 7); // tag + base + 7 x 1B deltas
    }

    #[test]
    fn random_line_falls_back_to_raw() {
        let mut rng = Rng::new(4);
        let line: Vec<u8> = (0..64).map(|_| rng.next_u32() as u8).collect();
        assert_eq!(line_size(&line), 65);
    }

    #[test]
    fn page_size_is_sum_of_lines() {
        let page = [0u8; 4096];
        assert_eq!(compressed_size(&page), 64 * 2);
    }

    #[test]
    fn read_int_sign_extension() {
        assert_eq!(read_int(&[0xFF]), -1);
        assert_eq!(read_int(&[0xFF, 0x00]), 255);
        assert_eq!(read_int(&[0x00, 0x80]), -32768);
    }

    #[test]
    fn size_never_exceeds_raw_plus_tag() {
        crate::util::proptest::check(0xBD1, 50, |rng| {
            let line: Vec<u8> = (0..64).map(|_| rng.next_u32() as u8).collect();
            assert!(line_size(&line) <= 65);
        });
    }
}
