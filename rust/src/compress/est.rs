//! Native mirror of the L1 pallas compression-size estimator.
//!
//! MUST stay formula-identical to
//! `python/compile/kernels/compress_model.py` — the integration test
//! `tests/pjrt_estimator.rs` asserts bit-comparable agreement between this
//! implementation and the AOT artifact executed through PJRT, and a looser
//! correlation bound against the real algorithms in this module's siblings.

pub const WORDS_PER_PAGE: usize = 1024;
pub const BLOCKS_PER_PAGE: usize = 16;
pub const WORDS_PER_BLOCK: usize = 64;
pub const DICT_WORDS: usize = 8;

// Coefficients — keep in sync with compress_model.py.
const LZ_RUN_GAIN: f32 = 3.5;
const LZ_DICT_GAIN: f32 = 2.5;
const LZ_ZERO_GAIN: f32 = 3.8;
const FPC_ZERO_GAIN: f32 = 3.5;
const FPC_NARROW_GAIN: f32 = 2.75;
const BDI_DELTA_GAIN: f32 = 2.0;
const FVE_HIT_GAIN: f32 = 3.0;
const HEADER_BYTES: f32 = 8.0;
const CALIB_POW: f32 = 0.55;
const BLOCK_BYTES: f32 = 256.0;

/// Per-page byte estimates under `[lz, fpcbdi, fve]`.
pub fn estimate_page(words: &[i32]) -> [f32; 3] {
    assert_eq!(words.len(), WORDS_PER_PAGE);
    let mut total = [0f32; 3];
    for blk in words.chunks_exact(WORDS_PER_BLOCK) {
        let mut zeros = 0f32;
        let mut narrow = 0f32;
        let mut runs = 0f32;
        let mut deltas = 0f32;
        let mut dhits = 0f32;
        let base = blk[0];
        for (i, &w) in blk.iter().enumerate() {
            if w == 0 {
                zeros += 1.0;
            } else {
                if w.unsigned_abs() < 128 {
                    narrow += 1.0;
                }
                if (w.wrapping_sub(base)).unsigned_abs() < 32768 {
                    deltas += 1.0;
                }
            }
            if i > 0 && w == blk[i - 1] {
                runs += 1.0;
            }
            if i >= DICT_WORDS && blk[..DICT_WORDS].contains(&w) {
                dhits += 1.0;
            }
        }
        let lz = BLOCK_BYTES + HEADER_BYTES
            - LZ_ZERO_GAIN * zeros
            - LZ_RUN_GAIN * runs
            - LZ_DICT_GAIN * dhits;
        let fpcbdi = BLOCK_BYTES + HEADER_BYTES
            - FPC_ZERO_GAIN * zeros
            - FPC_NARROW_GAIN * narrow
            - BDI_DELTA_GAIN * (deltas - narrow).max(0.0) * 0.5;
        let fve = BLOCK_BYTES + HEADER_BYTES
            - FVE_HIT_GAIN * dhits
            - FPC_ZERO_GAIN * zeros * 0.5;
        for (slot, est) in total.iter_mut().zip([lz, fpcbdi, fve]) {
            // Saturating calibration — keep in sync with compress_model.py.
            let frac = ((est - HEADER_BYTES) / BLOCK_BYTES).clamp(0.0, 1.0);
            *slot += HEADER_BYTES + BLOCK_BYTES * frac.powf(CALIB_POW);
        }
    }
    total
}

/// Byte-slice convenience: interpret `page` as little-endian i32 words.
pub fn estimate_page_bytes(page: &[u8]) -> [f32; 3] {
    assert_eq!(page.len(), 4 * WORDS_PER_PAGE);
    let words: Vec<i32> = page
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    estimate_page(&words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_page_hits_lz_floor() {
        let est = estimate_page(&[0i32; WORDS_PER_PAGE]);
        assert!((est[0] - 16.0 * 8.0).abs() < 1e-3, "{:?}", est);
    }

    #[test]
    fn random_page_near_raw() {
        let mut rng = Rng::new(5);
        let words: Vec<i32> = (0..WORDS_PER_PAGE).map(|_| rng.next_u32() as i32).collect();
        let est = estimate_page(&words);
        for v in est {
            assert!(v > 3200.0, "{est:?}");
        }
    }

    #[test]
    fn bytes_and_words_agree() {
        let mut rng = Rng::new(6);
        let words: Vec<i32> = (0..WORDS_PER_PAGE).map(|_| rng.next_u32() as i32).collect();
        let mut bytes = Vec::with_capacity(4096);
        for w in &words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(estimate_page(&words), estimate_page_bytes(&bytes));
    }

    #[test]
    fn estimates_track_real_lz_ordering() {
        // The estimator must rank pages the same way the real LZ77 does
        // across compressibility extremes.
        let zero = [0u8; 4096];
        let periodic: Vec<u8> = (0..4096).map(|i| (i % 16) as u8).collect();
        let mut rng = Rng::new(7);
        let random: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();

        let est_z = estimate_page_bytes(&zero)[0];
        let est_p = estimate_page_bytes(&periodic)[0];
        let est_r = estimate_page_bytes(&random)[0];
        assert!(est_z < est_p && est_p < est_r, "{est_z} {est_p} {est_r}");

        let real_z = crate::compress::lz::compressed_size(&zero) as f32;
        let real_p = crate::compress::lz::compressed_size(&periodic) as f32;
        let real_r = crate::compress::lz::compressed_size(&random) as f32;
        assert!(real_z < real_p && real_p < real_r);
    }

    #[test]
    fn estimator_correlates_with_real_lz() {
        let mut rng = Rng::new(0xC0DE);
        let mut est = Vec::new();
        let mut real = Vec::new();
        for _ in 0..60 {
            let mix = rng.f64();
            let page = crate::compress::synth::gen_page(
                &mut rng,
                crate::compress::synth::Profile::uniform_mix(mix),
            );
            est.push(estimate_page_bytes(&page)[0] as f64);
            real.push(crate::compress::lz::compressed_size(&page) as f64);
        }
        let r = crate::util::stats::pearson(&est, &real);
        assert!(r > 0.85, "estimator/LZ correlation too low: {r}");
    }
}
