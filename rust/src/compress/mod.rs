//! Link compression substrate (§4.4, Fig. 12).
//!
//! Real implementations of the three algorithm families the paper
//! evaluates, a native mirror of the L1 pallas estimator, the synthetic
//! page-content generator, and a caching `Compressor` front-end the DaeMon
//! memory engine uses on the page-migration path.

pub mod bdi;
pub mod est;
pub mod fpc;
pub mod fve;
pub mod lz;
pub mod synth;

use crate::util::prng::Rng;
use std::collections::HashMap;
use std::sync::Mutex;

/// Process-global memo of compressed page sizes.  Page contents are
/// deterministic in (seed, profile, page_id), so sizes are pure values —
/// schemes and experiment cells re-compressing the same pages (LC,
/// DaeMon, writeback paths, repeated sweep configs) share one computation.
/// Keyed by a fingerprint of (seed, profile, algo, page).
static GLOBAL_SIZES: Mutex<Option<HashMap<(u64, u64), u32>>> = Mutex::new(None);

fn global_lookup(key: (u64, u64)) -> Option<u32> {
    GLOBAL_SIZES.lock().unwrap().as_ref().and_then(|m| m.get(&key).copied())
}

fn global_insert(key: (u64, u64), size: u32) {
    let mut g = GLOBAL_SIZES.lock().unwrap();
    let m = g.get_or_insert_with(HashMap::new);
    // Bound the memo (it is an optimization, not a correctness store).
    if m.len() < 4_000_000 {
        m.insert(key, size);
    }
}

/// Compression algorithm families (Fig. 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Ratio-optimized LZ77 / IBM-MXT — DaeMon's default.
    Lz,
    /// Latency-optimized FPC+BDI hybrid (4-cycle per line).
    FpcBdi,
    /// Latency-optimized frequent-value encoding (6-cycle per line).
    Fve,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Lz => "LZ",
            Algo::FpcBdi => "fpcbdi",
            Algo::Fve => "fve",
        }
    }

    /// (De)compression latency per 4KB page, in core cycles.
    /// LZ/MXT: 64 cycles per 1KB chunk x 4 chunks = 256.
    /// fpcbdi: 4 cycles per 64B line x 64 = 256/… but lines are pipelined
    /// 4-wide in the paper's estimate; we charge the serialized per-page
    /// totals consistent with §4.4 and Fig. 12's setup.
    pub fn latency_cycles(&self) -> f64 {
        match self {
            Algo::Lz => 256.0,
            Algo::FpcBdi => 64.0,
            Algo::Fve => 96.0,
        }
    }

    /// Real compressed size of a page under this algorithm.
    pub fn compressed_size(&self, page: &[u8]) -> usize {
        match self {
            Algo::Lz => lz::compressed_size(page),
            Algo::FpcBdi => fpc::compressed_size(page).min(bdi::compressed_size(page)),
            Algo::Fve => fve::compressed_size(page),
        }
    }
}

/// Caching compression front-end.
///
/// Page contents are deterministic in (seed, page_id, profile), so the
/// compressed size of a page is computed once and memoized — re-migrations
/// (evict + refault) reuse the entry.  This mirrors the hardware, where
/// size is a property of the data, and keeps the simulator fast.
pub struct Compressor {
    seed: u64,
    profile: synth::Profile,
    cache: HashMap<u64, u32>,
    algo: Algo,
    fingerprint: u64,
    /// Total (compressed, raw) bytes for ratio reporting.
    pub compressed_bytes: u64,
    pub raw_bytes: u64,
}

impl Compressor {
    pub fn new(seed: u64, profile: synth::Profile, algo: Algo) -> Self {
        let fp = Self::fingerprint(seed, &profile, algo);
        Self {
            seed,
            profile,
            cache: HashMap::new(),
            algo,
            fingerprint: fp,
            compressed_bytes: 0,
            raw_bytes: 0,
        }
    }

    fn fingerprint(seed: u64, p: &synth::Profile, algo: Algo) -> u64 {
        let mut h = seed ^ match algo {
            Algo::Lz => 0x11,
            Algo::FpcBdi => 0x22,
            Algo::Fve => 0x33,
        };
        for v in [p.zero, p.runs, p.narrow, p.pool, p.random] {
            h = h
                .rotate_left(13)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ v.to_bits();
        }
        h ^ ((p.run_len as u64) << 32) ^ p.pool_size as u64
    }

    pub fn algo(&self) -> Algo {
        self.algo
    }

    /// Generate the page contents for `page_id` (deterministic).
    pub fn page_contents(&self, page_id: u64) -> Vec<u8> {
        let mut rng = Rng::new(self.seed ^ page_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        synth::gen_page(&mut rng, self.profile)
    }

    /// Compressed size of page `page_id` in bytes (memoized locally and
    /// in the process-global store).
    pub fn size_of(&mut self, page_id: u64) -> u32 {
        if let Some(&sz) = self.cache.get(&page_id) {
            self.note(sz);
            return sz;
        }
        let key = (self.fingerprint, page_id);
        let sz = match global_lookup(key) {
            Some(sz) => sz,
            None => {
                let page = self.page_contents(page_id);
                let sz = self.algo.compressed_size(&page) as u32;
                global_insert(key, sz);
                sz
            }
        };
        self.cache.insert(page_id, sz);
        self.note(sz);
        sz
    }

    /// Install externally computed sizes (the PJRT estimator path batches
    /// pages through the AOT artifact and backfills the cache).
    pub fn install(&mut self, page_id: u64, size: u32) {
        self.cache.insert(page_id, size);
    }

    pub fn cached(&self, page_id: u64) -> Option<u32> {
        self.cache.get(&page_id).copied()
    }

    fn note(&mut self, sz: u32) {
        self.compressed_bytes += sz as u64;
        self.raw_bytes += synth::PAGE_BYTES as u64;
    }

    /// Achieved compression ratio so far.
    pub fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_and_latencies() {
        assert_eq!(Algo::Lz.name(), "LZ");
        assert!(Algo::Lz.latency_cycles() > Algo::FpcBdi.latency_cycles());
    }

    #[test]
    fn lz_beats_latency_optimized_on_structured_data() {
        // Paper: LZ achieves ~2.9x/2.7x higher ratio than fpcbdi/fve.
        let mut rng = Rng::new(100);
        let mut lz_total = 0usize;
        let mut fpc_total = 0usize;
        let mut fve_total = 0usize;
        for _ in 0..20 {
            let p = synth::gen_page(&mut rng, synth::Profile::high());
            lz_total += Algo::Lz.compressed_size(&p);
            fpc_total += Algo::FpcBdi.compressed_size(&p);
            fve_total += Algo::Fve.compressed_size(&p);
        }
        assert!(lz_total < fpc_total, "LZ {lz_total} vs fpcbdi {fpc_total}");
        assert!(lz_total < fve_total, "LZ {lz_total} vs fve {fve_total}");
    }

    #[test]
    fn compressor_memoizes_and_tracks_ratio() {
        let mut c = Compressor::new(42, synth::Profile::high(), Algo::Lz);
        let a = c.size_of(7);
        let b = c.size_of(7);
        assert_eq!(a, b);
        assert_eq!(c.raw_bytes, 2 * 4096);
        assert!(c.ratio() > 1.0);
    }

    #[test]
    fn contents_deterministic_per_page_id() {
        let c = Compressor::new(42, synth::Profile::medium(), Algo::Lz);
        assert_eq!(c.page_contents(3), c.page_contents(3));
        assert_ne!(c.page_contents(3), c.page_contents(4));
    }

    #[test]
    fn install_overrides_computation() {
        let mut c = Compressor::new(42, synth::Profile::high(), Algo::Lz);
        c.install(9, 1234);
        assert_eq!(c.size_of(9), 1234);
    }
}
