//! Link compression substrate (§4.4, Fig. 12).
//!
//! Real implementations of the three algorithm families the paper
//! evaluates, a native mirror of the L1 pallas estimator, the synthetic
//! page-content generator, and a caching `Compressor` front-end the DaeMon
//! memory engine uses on the page-migration path.

pub mod bdi;
pub mod est;
pub mod fpc;
pub mod fve;
pub mod lz;
pub mod synth;

use crate::util::hash::FxHashMap;
use crate::util::memo::{MemoStats, ShardedMemo};
use crate::util::prng::Rng;
use std::sync::OnceLock;

/// Shards of the process-global size memo.  64 ways is far past the
/// orchestrator's worker counts, so two workers only contend when their
/// key fingerprints land in the same 1/64th of the key space.
const MEMO_SHARDS: usize = 64;

/// Per-shard entry cap: 64 x 62_500 ~= the historical 4M-entry global
/// bound.  A full shard stops memoizing (counted in `memo_full`), it
/// never evicts — the memo is an optimization, not a correctness store.
const MEMO_SHARD_CAP: usize = 62_500;

/// Process-global memo of compressed page sizes.  Page contents are
/// deterministic in (seed, profile, page_id), so sizes are pure values —
/// schemes and experiment cells re-compressing the same pages (LC,
/// DaeMon, writeback paths, repeated sweep configs) share one computation.
/// Keyed by a fingerprint of (seed, profile, algo, page).  Sharded so the
/// orchestrator's `--jobs K` workers stop serializing on one global lock
/// (the seed design's `Mutex<HashMap>` was locked on every miss *and*
/// every insert).
fn global_sizes() -> &'static ShardedMemo<(u64, u64), u32> {
    static GLOBAL: OnceLock<ShardedMemo<(u64, u64), u32>> = OnceLock::new();
    GLOBAL.get_or_init(|| ShardedMemo::new(MEMO_SHARDS, MEMO_SHARD_CAP))
}

/// Occupancy/overflow counters of the process-global size memo
/// (`full_drops` is the `memo_full` count: inserts dropped because their
/// shard hit its cap).
pub fn global_memo_stats() -> MemoStats {
    global_sizes().stats()
}

/// Compression algorithm families (Fig. 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Ratio-optimized LZ77 / IBM-MXT — DaeMon's default.
    Lz,
    /// Latency-optimized FPC+BDI hybrid (4-cycle per line).
    FpcBdi,
    /// Latency-optimized frequent-value encoding (6-cycle per line).
    Fve,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Lz => "LZ",
            Algo::FpcBdi => "fpcbdi",
            Algo::Fve => "fve",
        }
    }

    /// (De)compression latency per 4KB page, in core cycles.
    /// LZ/MXT: 64 cycles per 1KB chunk x 4 chunks = 256.
    /// fpcbdi: 4 cycles per 64B line x 64 = 256/… but lines are pipelined
    /// 4-wide in the paper's estimate; we charge the serialized per-page
    /// totals consistent with §4.4 and Fig. 12's setup.
    pub fn latency_cycles(&self) -> f64 {
        match self {
            Algo::Lz => 256.0,
            Algo::FpcBdi => 64.0,
            Algo::Fve => 96.0,
        }
    }

    /// Real compressed size of a page under this algorithm.
    pub fn compressed_size(&self, page: &[u8]) -> usize {
        match self {
            Algo::Lz => lz::compressed_size(page),
            Algo::FpcBdi => fpc::compressed_size(page).min(bdi::compressed_size(page)),
            Algo::Fve => fve::compressed_size(page),
        }
    }
}

/// Caching compression front-end.
///
/// Page contents are deterministic in (seed, page_id, profile), so the
/// compressed size of a page is computed once and memoized — re-migrations
/// (evict + refault) reuse the entry.  This mirrors the hardware, where
/// size is a property of the data, and keeps the simulator fast.
pub struct Compressor {
    seed: u64,
    profile: synth::Profile,
    cache: FxHashMap<u64, u32>,
    algo: Algo,
    fingerprint: u64,
    /// Total (compressed, raw) bytes for ratio reporting.
    pub compressed_bytes: u64,
    pub raw_bytes: u64,
}

impl Compressor {
    pub fn new(seed: u64, profile: synth::Profile, algo: Algo) -> Self {
        let fp = Self::fingerprint(seed, &profile, algo);
        Self {
            seed,
            profile,
            cache: FxHashMap::default(),
            algo,
            fingerprint: fp,
            compressed_bytes: 0,
            raw_bytes: 0,
        }
    }

    fn fingerprint(seed: u64, p: &synth::Profile, algo: Algo) -> u64 {
        let mut h = seed ^ match algo {
            Algo::Lz => 0x11,
            Algo::FpcBdi => 0x22,
            Algo::Fve => 0x33,
        };
        for v in [p.zero, p.runs, p.narrow, p.pool, p.random] {
            h = h
                .rotate_left(13)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ v.to_bits();
        }
        h ^ ((p.run_len as u64) << 32) ^ p.pool_size as u64
    }

    pub fn algo(&self) -> Algo {
        self.algo
    }

    /// Generate the page contents for `page_id` (deterministic).
    pub fn page_contents(&self, page_id: u64) -> Vec<u8> {
        let mut rng = Rng::new(self.seed ^ page_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        synth::gen_page(&mut rng, self.profile)
    }

    /// Compressed size of page `page_id` in bytes (memoized locally and
    /// in the process-global store).
    pub fn size_of(&mut self, page_id: u64) -> u32 {
        if let Some(&sz) = self.cache.get(&page_id) {
            self.note(sz);
            return sz;
        }
        let key = (self.fingerprint, page_id);
        let sz = global_sizes().get_or_insert_with(key, || {
            let page = self.page_contents(page_id);
            self.algo.compressed_size(&page) as u32
        });
        self.cache.insert(page_id, sz);
        self.note(sz);
        sz
    }

    /// Process-global size-memo counters — `full_drops` is the
    /// `memo_full` count (inserts dropped on a capacity-saturated shard;
    /// sizes are then recomputed per miss instead of shared).
    pub fn memo_stats(&self) -> MemoStats {
        global_memo_stats()
    }

    /// Install externally computed sizes (the PJRT estimator path batches
    /// pages through the AOT artifact and backfills the cache).
    pub fn install(&mut self, page_id: u64, size: u32) {
        self.cache.insert(page_id, size);
    }

    pub fn cached(&self, page_id: u64) -> Option<u32> {
        self.cache.get(&page_id).copied()
    }

    fn note(&mut self, sz: u32) {
        self.compressed_bytes += sz as u64;
        self.raw_bytes += synth::PAGE_BYTES as u64;
    }

    /// Achieved compression ratio so far.
    pub fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_and_latencies() {
        assert_eq!(Algo::Lz.name(), "LZ");
        assert!(Algo::Lz.latency_cycles() > Algo::FpcBdi.latency_cycles());
    }

    #[test]
    fn lz_beats_latency_optimized_on_structured_data() {
        // Paper: LZ achieves ~2.9x/2.7x higher ratio than fpcbdi/fve.
        let mut rng = Rng::new(100);
        let mut lz_total = 0usize;
        let mut fpc_total = 0usize;
        let mut fve_total = 0usize;
        for _ in 0..20 {
            let p = synth::gen_page(&mut rng, synth::Profile::high());
            lz_total += Algo::Lz.compressed_size(&p);
            fpc_total += Algo::FpcBdi.compressed_size(&p);
            fve_total += Algo::Fve.compressed_size(&p);
        }
        assert!(lz_total < fpc_total, "LZ {lz_total} vs fpcbdi {fpc_total}");
        assert!(lz_total < fve_total, "LZ {lz_total} vs fve {fve_total}");
    }

    #[test]
    fn compressor_memoizes_and_tracks_ratio() {
        let mut c = Compressor::new(42, synth::Profile::high(), Algo::Lz);
        let a = c.size_of(7);
        let b = c.size_of(7);
        assert_eq!(a, b);
        assert_eq!(c.raw_bytes, 2 * 4096);
        assert!(c.ratio() > 1.0);
    }

    #[test]
    fn contents_deterministic_per_page_id() {
        let c = Compressor::new(42, synth::Profile::medium(), Algo::Lz);
        assert_eq!(c.page_contents(3), c.page_contents(3));
        assert_ne!(c.page_contents(3), c.page_contents(4));
    }

    #[test]
    fn install_overrides_computation() {
        let mut c = Compressor::new(42, synth::Profile::high(), Algo::Lz);
        c.install(9, 1234);
        assert_eq!(c.size_of(9), 1234);
    }

    #[test]
    fn global_memo_shares_sizes_across_compressors() {
        // Same (seed, profile, algo) => same fingerprint => the second
        // compressor must observe the first one's memoized size and both
        // must agree.  (Asserted per-key, not on global entry counts —
        // parallel tests share the process-global memo.)
        let profile = synth::Profile::medium();
        let fp = Compressor::fingerprint(4242, &profile, Algo::Lz);
        let mut a = Compressor::new(4242, profile, Algo::Lz);
        let sz = a.size_of(12345);
        assert_eq!(
            global_sizes().get(&(fp, 12345)),
            Some(sz),
            "size_of must populate the global memo under its fingerprint key"
        );
        let mut b = Compressor::new(4242, profile, Algo::Lz);
        assert_eq!(b.size_of(12345), sz);
    }

    #[test]
    fn memo_full_counter_is_surfaced_via_compressor_stats() {
        // The full-shard drop behavior itself is pinned at the ShardedMemo
        // layer (util::memo::full_shard_drops_inserts_but_stays_correct);
        // here we pin the Compressor-level surface: the stats are readable
        // and monotone, and a full memo never changes computed sizes.
        let mut c = Compressor::new(77, synth::Profile::high(), Algo::Fve);
        let s0 = c.memo_stats();
        let sz = c.size_of(4096);
        let s1 = c.memo_stats();
        assert!(s1.entries >= s0.entries);
        assert!(s1.full_drops >= s0.full_drops, "drop counter must be monotone");
        // Whatever the memo's occupancy, the local cache still answers.
        assert_eq!(c.size_of(4096), sz);
    }
}
