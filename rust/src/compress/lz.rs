//! LZ77 link compression, IBM-MXT style (§4.4).
//!
//! The paper's units follow Pinnacle/MXT [1, 93]: 4 engines, each operating
//! on a 256B sub-block of a 1KB chunk against a 256B shared dictionary,
//! 64-cycle latency per chunk.  We implement a real LZ77 encoder (greedy
//! longest-match over a sliding window, 3-byte minimum match) so compressed
//! sizes come from the actual data, and a decoder to prove losslessness.
//! Timing (the 64-cycle constant) is charged by the simulator, not here.

/// Sliding-window size — MXT engines share a 256B dictionary per sub-block;
/// we bound matches to the 1KB chunk the engines cooperate on.
const WINDOW: usize = 1024;
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 66; // 6-bit length field + MIN_MATCH

/// A decoded LZ77 token stream uses a 1-byte flag block per 8 tokens:
/// literal tokens cost 1 byte, match tokens cost 2 bytes
/// (11-bit offset within the 1KB chunk + 6-bit length - packed to 17 bits,
/// rounded to 2 bytes + flag bit amortized in the flag block).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    Match { offset: u16, len: u8 },
}

/// Encode `data` chunk-by-chunk (1KB chunks, matching the MXT engine
/// granularity).  Returns the token stream.
pub fn encode(data: &[u8]) -> Vec<Token> {
    let mut tokens = Vec::with_capacity(data.len() / 2);
    for chunk in data.chunks(WINDOW) {
        encode_chunk(chunk, &mut tokens);
    }
    tokens
}

fn encode_chunk(chunk: &[u8], tokens: &mut Vec<Token>) {
    // Hash-chain matcher over 3-byte prefixes.
    const HASH_BITS: usize = 12;
    const HASH_SIZE: usize = 1 << HASH_BITS;
    let mut head = [usize::MAX; HASH_SIZE];
    let mut prev = vec![usize::MAX; chunk.len()];

    #[inline]
    fn hash3(b: &[u8]) -> usize {
        let v = (b[0] as u32) | ((b[1] as u32) << 8) | ((b[2] as u32) << 16);
        (v.wrapping_mul(0x9E37_79B1) >> (32 - 12)) as usize
    }

    let mut i = 0;
    while i < chunk.len() {
        let mut best_len = 0usize;
        let mut best_off = 0usize;
        if i + MIN_MATCH <= chunk.len() {
            let h = hash3(&chunk[i..]);
            let mut cand = head[h];
            let mut probes = 0;
            while cand != usize::MAX && probes < 16 {
                let limit = (chunk.len() - i).min(MAX_MATCH);
                let mut l = 0;
                while l < limit && chunk[cand + l] == chunk[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_off = i - cand;
                }
                cand = prev[cand];
                probes += 1;
            }
        }
        if best_len >= MIN_MATCH {
            tokens.push(Token::Match {
                offset: best_off as u16,
                len: (best_len - MIN_MATCH) as u8,
            });
            // Insert hash entries for all covered positions.
            for j in i..(i + best_len).min(chunk.len().saturating_sub(MIN_MATCH - 1)) {
                if j + MIN_MATCH <= chunk.len() {
                    let h = hash3(&chunk[j..]);
                    prev[j] = head[h];
                    head[h] = j;
                }
            }
            i += best_len;
        } else {
            tokens.push(Token::Literal(chunk[i]));
            if i + MIN_MATCH <= chunk.len() {
                let h = hash3(&chunk[i..]);
                prev[i] = head[h];
                head[h] = i;
            }
            i += 1;
        }
    }
}

/// Decode a token stream produced by [`encode`] (chunk boundaries restored
/// implicitly: offsets never cross a chunk because the encoder resets).
pub fn decode(tokens: &[Token]) -> Vec<u8> {
    let mut out: Vec<u8> = Vec::new();
    let mut chunk_base = 0usize;
    let mut in_chunk = 0usize;
    for t in tokens {
        match t {
            Token::Literal(b) => {
                out.push(*b);
                in_chunk += 1;
            }
            Token::Match { offset, len } => {
                let len = *len as usize + MIN_MATCH;
                let start = chunk_base + in_chunk - *offset as usize;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
                in_chunk += len;
            }
        }
        if in_chunk >= WINDOW {
            chunk_base += in_chunk;
            in_chunk = 0;
        }
    }
    out
}

/// Compressed size in bytes: 1B per literal, 2B per match, plus a flag bit
/// per token (flag blocks of 8), plus a 2B chunk header per 1KB chunk.
pub fn compressed_size(data: &[u8]) -> usize {
    let tokens = encode(data);
    let payload: usize = tokens
        .iter()
        .map(|t| match t {
            Token::Literal(_) => 1,
            Token::Match { .. } => 2,
        })
        .sum();
    let flags = tokens.len().div_ceil(8);
    let headers = 2 * data.len().div_ceil(WINDOW);
    // Hardware falls back to raw when compression does not pay.
    (payload + flags + headers).min(data.len() + headers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip(data: &[u8]) {
        let tokens = encode(data);
        let back = decode(&tokens);
        assert_eq!(back, data, "roundtrip failed for {} bytes", data.len());
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2]);
        roundtrip(&[3, 3, 3]);
    }

    #[test]
    fn roundtrip_zeros_page() {
        roundtrip(&[0u8; 4096]);
        let sz = compressed_size(&[0u8; 4096]);
        assert!(sz < 300, "zero page should collapse, got {sz}");
    }

    #[test]
    fn roundtrip_repeating_pattern() {
        let data: Vec<u8> = (0..4096).map(|i| (i % 16) as u8).collect();
        roundtrip(&data);
        let sz = compressed_size(&data);
        assert!(sz < 1024, "periodic page should compress 4x+, got {sz}");
    }

    #[test]
    fn random_data_does_not_blow_up() {
        let mut rng = Rng::new(99);
        let data: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        roundtrip(&data);
        let sz = compressed_size(&data);
        // Raw fallback bound: size + chunk headers.
        assert!(sz <= 4096 + 8, "got {sz}");
    }

    #[test]
    fn roundtrip_random_lengths_property() {
        crate::util::proptest::check(0x1_2, 40, |rng| {
            let len = rng.index(5000);
            let structured = rng.chance(0.5);
            let data: Vec<u8> = if structured {
                let v = rng.next_u32() as u8;
                (0..len)
                    .map(|i| if i % 7 < 5 { v } else { rng.next_u32() as u8 })
                    .collect()
            } else {
                (0..len).map(|_| rng.next_u32() as u8).collect()
            };
            let back = decode(&encode(&data));
            assert_eq!(back, data);
        });
    }

    #[test]
    fn matches_never_cross_chunk_boundary() {
        // Two identical 1KB chunks: the second must re-encode, not point
        // back across the boundary.
        let chunk: Vec<u8> = (0..1024).map(|i| (i * 7 % 251) as u8).collect();
        let mut data = chunk.clone();
        data.extend_from_slice(&chunk);
        let tokens = encode(&data);
        let mut pos = 0usize;
        for t in &tokens {
            match t {
                Token::Literal(_) => pos += 1,
                Token::Match { offset, len } => {
                    let in_chunk = pos % WINDOW;
                    assert!(
                        (*offset as usize) <= in_chunk,
                        "match at {pos} reaches across chunk"
                    );
                    pos += *len as usize + MIN_MATCH;
                }
            }
        }
        roundtrip(&data);
    }

    #[test]
    fn compression_ratio_ordering() {
        let zeros = compressed_size(&[0u8; 4096]);
        let period: Vec<u8> = (0..4096).map(|i| (i % 32) as u8).collect();
        let periodic = compressed_size(&period);
        let mut rng = Rng::new(3);
        let random: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        let rand_sz = compressed_size(&random);
        assert!(zeros < periodic && periodic < rand_sz);
    }
}
