//! Synthetic page-content generation.
//!
//! We do not have the paper's exact input files, so page *contents* are
//! synthesized per workload from a compressibility profile (substitution
//! documented in DESIGN.md): a mixture of zero words, run-length stretches,
//! narrow integers, value-pool words (FVE-friendly) and raw random words.
//! The profile parameters are calibrated so the real LZ77 ratios match the
//! paper's reported compression ratios (avg ~4.47x across workloads,
//! ~1.42x for dr/rs — §6 "Compression Scheme").
//!
//! Contents are deterministic in (seed, page_id), so a page re-migrated
//! later compresses identically.

use crate::util::prng::Rng;

pub const PAGE_BYTES: usize = 4096;
const WORDS: usize = PAGE_BYTES / 4;

/// Mixture weights (normalized internally).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Profile {
    pub zero: f64,
    pub runs: f64,
    pub narrow: f64,
    pub pool: f64,
    pub random: f64,
    /// Average run length for the `runs` component.
    pub run_len: usize,
    /// Distinct values in the FVE-friendly pool.
    pub pool_size: usize,
}

impl Profile {
    /// A profile that linearly interpolates between fully structured
    /// (`x = 0`) and fully random (`x = 1`).  Used by calibration tests.
    pub fn uniform_mix(x: f64) -> Profile {
        let x = x.clamp(0.0, 1.0);
        Profile {
            zero: 0.3 * (1.0 - x),
            runs: 0.4 * (1.0 - x),
            narrow: 0.2 * (1.0 - x),
            pool: 0.1 * (1.0 - x),
            random: x,
            run_len: 12,
            pool_size: 24,
        }
    }

    /// Highly compressible scientific/sparse data (sp, sl, hp, pf):
    /// LZ ratio ~5-7x.
    pub fn high() -> Profile {
        Profile {
            zero: 0.35,
            runs: 0.30,
            narrow: 0.22,
            pool: 0.08,
            random: 0.05,
            run_len: 16,
            pool_size: 16,
        }
    }

    /// Moderately compressible (graphs, DP matrices, timeseries):
    /// LZ ratio ~3-4x.
    pub fn medium() -> Profile {
        Profile {
            zero: 0.15,
            runs: 0.25,
            narrow: 0.25,
            pool: 0.10,
            random: 0.25,
            run_len: 8,
            pool_size: 32,
        }
    }

    /// Poorly compressible dense float weights/activations (dr, rs):
    /// LZ ratio ~1.4x.
    pub fn low() -> Profile {
        Profile {
            zero: 0.02,
            runs: 0.04,
            narrow: 0.06,
            pool: 0.04,
            random: 0.84,
            run_len: 4,
            pool_size: 48,
        }
    }

    fn normalized(&self) -> [f64; 5] {
        let sum = self.zero + self.runs + self.narrow + self.pool + self.random;
        [
            self.zero / sum,
            self.runs / sum,
            self.narrow / sum,
            self.pool / sum,
            self.random / sum,
        ]
    }
}

/// Generate a 4KB page deterministically from `rng` (callers derive the rng
/// from (seed, page_id) via `Rng::split`).
pub fn gen_page(rng: &mut Rng, profile: Profile) -> Vec<u8> {
    let w = gen_page_words(rng, profile);
    let mut out = Vec::with_capacity(PAGE_BYTES);
    for word in w {
        out.extend_from_slice(&word.to_le_bytes());
    }
    out
}

/// Word-level generator (i32 view — the shape the L1 kernel consumes).
pub fn gen_page_words(rng: &mut Rng, profile: Profile) -> Vec<i32> {
    let weights = profile.normalized();
    let pool: Vec<i32> = (0..profile.pool_size.max(1))
        .map(|_| rng.next_u32() as i32)
        .collect();
    let mut words = Vec::with_capacity(WORDS);
    while words.len() < WORDS {
        let pick = rng.f64();
        let mut acc = 0.0;
        let mut kind = 4;
        for (k, &w) in weights.iter().enumerate() {
            acc += w;
            if pick < acc {
                kind = k;
                break;
            }
        }
        match kind {
            0 => {
                // Zero stretch.
                let n = 1 + rng.index(profile.run_len.max(1) * 2);
                for _ in 0..n.min(WORDS - words.len()) {
                    words.push(0);
                }
            }
            1 => {
                // Repeated-value run.
                let v = if rng.chance(0.5) {
                    rng.range(1, 256) as i32
                } else {
                    rng.next_u32() as i32
                };
                let n = 2 + rng.index(profile.run_len.max(1) * 2);
                for _ in 0..n.min(WORDS - words.len()) {
                    words.push(v);
                }
            }
            2 => words.push(rng.range(1, 128) as i32 * if rng.chance(0.5) { 1 } else { -1 }),
            3 => words.push(pool[rng.index(pool.len())]),
            _ => words.push(rng.next_u32() as i32),
        }
    }
    words.truncate(WORDS);
    words
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::lz;

    fn lz_ratio(profile: Profile, seed: u64, pages: usize) -> f64 {
        let mut rng = Rng::new(seed);
        let mut total_raw = 0usize;
        let mut total_cmp = 0usize;
        for _ in 0..pages {
            let p = gen_page(&mut rng, profile);
            total_raw += p.len();
            total_cmp += lz::compressed_size(&p);
        }
        total_raw as f64 / total_cmp as f64
    }

    #[test]
    fn page_is_4kb() {
        let mut rng = Rng::new(1);
        assert_eq!(gen_page(&mut rng, Profile::high()).len(), 4096);
        assert_eq!(gen_page_words(&mut rng, Profile::low()).len(), 1024);
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let a = gen_page(&mut Rng::new(9), Profile::medium());
        let b = gen_page(&mut Rng::new(9), Profile::medium());
        assert_eq!(a, b);
    }

    #[test]
    fn high_profile_ratio_matches_paper_band() {
        let r = lz_ratio(Profile::high(), 2, 30);
        assert!(r > 3.5, "high profile LZ ratio {r} too low");
    }

    #[test]
    fn low_profile_ratio_matches_dr_rs() {
        let r = lz_ratio(Profile::low(), 3, 30);
        // Paper: dr/rs compress ~1.42x.
        assert!((1.1..2.0).contains(&r), "low profile LZ ratio {r}");
    }

    #[test]
    fn profiles_are_ordered() {
        let hi = lz_ratio(Profile::high(), 4, 20);
        let med = lz_ratio(Profile::medium(), 4, 20);
        let lo = lz_ratio(Profile::low(), 4, 20);
        assert!(hi > med && med > lo, "hi={hi} med={med} lo={lo}");
    }

    #[test]
    fn mix_parameter_is_monotone() {
        let r0 = lz_ratio(Profile::uniform_mix(0.0), 5, 10);
        let r5 = lz_ratio(Profile::uniform_mix(0.5), 5, 10);
        let r1 = lz_ratio(Profile::uniform_mix(1.0), 5, 10);
        assert!(r0 > r5 && r5 > r1, "{r0} {r5} {r1}");
    }
}
