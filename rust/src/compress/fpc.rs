//! Frequent Pattern Compression (Alameldeen & Wood [8]).
//!
//! Encodes each 32-bit word with a 3-bit prefix selecting one of eight
//! patterns; unmatched words are emitted raw.  Sizes follow the original
//! paper's table (bits per pattern).

/// Bits to encode one 32-bit word (excluding the 3-bit prefix).
fn word_payload_bits(w: u32) -> u32 {
    let v = w as i32;
    if v == 0 {
        return 3; // zero run marker payload (3-bit run length here)
    }
    // 4-bit sign-extended.
    if (-8..8).contains(&v) {
        return 4;
    }
    // 8-bit sign-extended.
    if (-128..128).contains(&v) {
        return 8;
    }
    // 16-bit sign-extended.
    if (-32768..32768).contains(&v) {
        return 16;
    }
    // Halfword padded with zeros (upper half zero).
    if w & 0xFFFF_0000 == 0 {
        return 16;
    }
    // Two halfwords, each byte sign-extended.
    let lo = (w & 0xFFFF) as i16;
    let hi = (w >> 16) as i16;
    if (-128..128).contains(&(lo as i32)) && (-128..128).contains(&(hi as i32)) {
        return 16;
    }
    // Repeated bytes.
    let b = w & 0xFF;
    if w == b * 0x0101_0101 {
        return 8;
    }
    32 // uncompressed
}

/// Compressed size in bytes of a buffer treated as little-endian u32 words,
/// with zero-run folding (up to 8 consecutive zero words share one token).
pub fn compressed_size(data: &[u8]) -> usize {
    let mut bits: u64 = 0;
    let mut zero_run = 0u32;
    for chunk in data.chunks(4) {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        let word = u32::from_le_bytes(w);
        if word == 0 {
            zero_run += 1;
            if zero_run == 8 {
                bits += 3 + 3;
                zero_run = 0;
            }
        } else {
            if zero_run > 0 {
                bits += 3 + 3;
                zero_run = 0;
            }
            bits += 3 + word_payload_bits(word) as u64;
        }
    }
    if zero_run > 0 {
        bits += 3 + 3;
    }
    (bits.div_ceil(8) as usize).min(data.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_page_compresses_hard() {
        let sz = compressed_size(&[0u8; 4096]);
        // 1024 zero words = 128 run tokens x 6 bits = 96 bytes.
        assert!(sz <= 100, "got {sz}");
    }

    #[test]
    fn narrow_values_compress() {
        let mut page = Vec::new();
        for i in 0..1024u32 {
            page.extend_from_slice(&((i % 7) as u32).to_le_bytes());
        }
        let sz = compressed_size(&page);
        assert!(sz < 1400, "got {sz}");
    }

    #[test]
    fn random_words_near_raw() {
        let mut rng = Rng::new(8);
        let page: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        let sz = compressed_size(&page);
        assert!(sz > 3500, "got {sz}");
        assert!(sz <= 4096);
    }

    #[test]
    fn pattern_bit_table() {
        assert_eq!(word_payload_bits(0), 3);
        assert_eq!(word_payload_bits(5), 4);
        assert_eq!(word_payload_bits(0xFFFF_FFFF), 4); // -1
        assert_eq!(word_payload_bits(100), 8);
        assert_eq!(word_payload_bits(20_000), 16);
        assert_eq!(word_payload_bits(0x0000_ABCD), 16);
        assert_eq!(word_payload_bits(0x4141_4141), 8); // repeated byte
        assert_eq!(word_payload_bits(0xDEAD_BEEF), 32);
    }

    #[test]
    fn size_bounded_by_raw() {
        crate::util::proptest::check(0xF9C, 30, |rng| {
            let len = 4 * (1 + rng.index(1024));
            let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            assert!(compressed_size(&data) <= len);
        });
    }
}
