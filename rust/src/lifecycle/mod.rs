//! Generic transition-table lifecycle core.
//!
//! Every documented lifecycle in the simulator (compute-engine page and
//! line entries, fabric ports, cluster tenants) is executed by the same
//! zero-dependency machinery: a [`Lifecycle`] impl declares its states,
//! events and `from --event--> to` table as associated consts, and a
//! [`StateMachine`] holds the current state with **`transition(event)` as
//! the only mutation path** — the state field is private to this module,
//! so "a terminal state never reverts" is enforced by the type system
//! rather than by asserts at call sites.
//!
//! Undeclared `(state, event)` pairs hit the machine's [`OnInvalid`]
//! policy: `Panic` (an invalid edge is a simulator bug — the default
//! posture for every production machine) or `Ignore` (the transition is
//! refused and `transition` returns `false`).  `transition_with` invokes
//! a hook with the `(from, event, to)` triple after a successful edge,
//! which is how lifecycle edges feed the `obs` event ring (e.g. the
//! cluster stamps `TenantKill` events at the exact `Running -> Killed`
//! transition).
//!
//! The DESIGN.md §"Lifecycles and state machines" tables are the
//! documentation of record; [`doc_table_edges`] parses them back out of
//! the markdown and [`assert_graph_matches_doc`] pins table and code to
//! each other (edge-set equality).  [`check_declaration`] and
//! [`exercise_graph`] are the shared property-test drivers used by
//! `rust/tests/lifecycle_graphs.rs`.

use crate::util::prng::Rng;
use crate::util::proptest;

/// What a [`StateMachine`] does with an undeclared `(state, event)` pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OnInvalid {
    /// Panic with the machine, state and event names (simulator bug).
    Panic,
    /// Refuse the edge: the state is unchanged and `transition` returns
    /// `false`.
    Ignore,
}

/// One declared edge of a lifecycle graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transition<S: 'static, E: 'static> {
    pub from: S,
    pub event: E,
    pub to: S,
}

/// A lifecycle: a closed set of states and events plus the declared
/// transition table.  Implementors are plain fieldless `Copy` enums; the
/// table lives in a `const`, so a [`StateMachine`] is exactly the size
/// of the bare state enum and transitions compile to a scan over a
/// handful of const entries.
pub trait Lifecycle: Copy + Eq + Sized + 'static {
    /// The event alphabet driving this machine.
    type Event: Copy + Eq + 'static;

    /// Short machine name used in panic messages and doc headings.
    const NAME: &'static str;
    /// Every declared state (exhaustive).
    const STATES: &'static [Self];
    /// Every declared event (exhaustive).
    const EVENTS: &'static [Self::Event];
    /// The declared transition table — the single source of truth that
    /// DESIGN.md documents and the property tests pin.
    const TABLE: &'static [Transition<Self, Self::Event>];
    /// Policy for undeclared `(state, event)` pairs.
    const ON_INVALID: OnInvalid = OnInvalid::Panic;

    /// Display name of a state (matches the DESIGN.md table spelling).
    fn state_name(self) -> &'static str;
    /// Display name of an event (matches the DESIGN.md table spelling).
    fn event_name(event: Self::Event) -> &'static str;
}

/// The declared target of `event` in state `from`, if any.
#[inline]
pub fn target<L: Lifecycle>(from: L, event: L::Event) -> Option<L> {
    L::TABLE
        .iter()
        .find(|t| t.from == from && t.event == event)
        .map(|t| t.to)
}

/// A state with no outgoing edges (self-loops count as outgoing): once
/// entered, no event is declared, so the machine can never leave it.
pub fn is_terminal<L: Lifecycle>(state: L) -> bool {
    !L::TABLE.iter().any(|t| t.from == state)
}

/// A running lifecycle instance.  The current state is private: the only
/// way to change it is [`StateMachine::transition`], which consults the
/// declared table and applies the lifecycle's [`OnInvalid`] policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StateMachine<L: Lifecycle> {
    state: L,
}

impl<L: Lifecycle> StateMachine<L> {
    pub fn new(initial: L) -> Self {
        Self { state: initial }
    }

    #[inline]
    pub fn state(&self) -> L {
        self.state
    }

    /// Drive one event.  Returns `true` iff a declared edge was taken;
    /// an undeclared pair panics or is refused per `L::ON_INVALID`.
    #[inline]
    pub fn transition(&mut self, event: L::Event) -> bool {
        match target(self.state, event) {
            Some(to) => {
                self.state = to;
                true
            }
            None => match L::ON_INVALID {
                OnInvalid::Panic => panic!(
                    "invalid {} transition: event {} in state {}",
                    L::NAME,
                    L::event_name(event),
                    self.state.state_name()
                ),
                OnInvalid::Ignore => false,
            },
        }
    }

    /// [`StateMachine::transition`] plus a log hook: after a declared
    /// edge is taken, `hook(from, event, to)` fires — the seam through
    /// which lifecycle edges emit `obs` events.
    #[inline]
    pub fn transition_with(
        &mut self,
        event: L::Event,
        mut hook: impl FnMut(L, L::Event, L),
    ) -> bool {
        let from = self.state;
        let taken = self.transition(event);
        if taken {
            hook(from, event, self.state);
        }
        taken
    }
}

/// Parse the `| from | event | to |` transition table under `heading` in
/// a markdown document.  `heading` is matched as a line prefix; the scan
/// stops at the next heading of any level.  Rows qualify when all three
/// leading columns are single backticked identifiers (the header and
/// `|---|` separator rows are skipped by that filter).
pub fn doc_table_edges(text: &str, heading: &str) -> Vec<(String, String, String)> {
    fn backticked(cell: &str) -> Option<&str> {
        let c = cell.trim();
        let inner = c.strip_prefix('`')?.strip_suffix('`')?;
        (!inner.is_empty() && !inner.contains('`')).then_some(inner)
    }
    let mut out = Vec::new();
    let mut inside = false;
    for line in text.lines() {
        if line.starts_with(heading) {
            inside = true;
            continue;
        }
        if !inside {
            continue;
        }
        if line.starts_with('#') {
            break;
        }
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let mut cols = t.split('|').skip(1);
        let (Some(a), Some(b), Some(c)) = (cols.next(), cols.next(), cols.next()) else {
            continue;
        };
        if let (Some(from), Some(event), Some(to)) =
            (backticked(a), backticked(b), backticked(c))
        {
            out.push((from.to_string(), event.to_string(), to.to_string()));
        }
    }
    out
}

/// Pin `L::TABLE` to the DESIGN.md table under `heading`: same edge set,
/// no duplicates on either side.
pub fn assert_graph_matches_doc<L: Lifecycle>(text: &str, heading: &str) {
    let doc = doc_table_edges(text, heading);
    assert!(
        !doc.is_empty(),
        "{}: no transition table found under heading {heading:?}",
        L::NAME
    );
    for (i, row) in doc.iter().enumerate() {
        assert!(
            !doc[..i].contains(row),
            "{}: duplicate documented edge {row:?}",
            L::NAME
        );
    }
    let code: Vec<(String, String, String)> = L::TABLE
        .iter()
        .map(|t| {
            (
                t.from.state_name().to_string(),
                L::event_name(t.event).to_string(),
                t.to.state_name().to_string(),
            )
        })
        .collect();
    for row in &doc {
        assert!(
            code.contains(row),
            "{}: DESIGN.md documents edge {row:?} but L::TABLE does not declare it",
            L::NAME
        );
    }
    for row in &code {
        assert!(
            doc.contains(row),
            "{}: L::TABLE declares edge {row:?} but DESIGN.md does not document it",
            L::NAME
        );
    }
}

/// Static sanity of a lifecycle declaration: states/events/names unique,
/// every table endpoint declared, no duplicate `(from, event)` pair (the
/// machine is deterministic), and terminal states absorbing by
/// construction (zero outgoing edges).
pub fn check_declaration<L: Lifecycle>() {
    for (i, s) in L::STATES.iter().enumerate() {
        assert!(
            !L::STATES[..i].contains(s),
            "{}: duplicate state {}",
            L::NAME,
            s.state_name()
        );
        assert!(
            !L::STATES[..i].iter().any(|p| p.state_name() == s.state_name()),
            "{}: duplicate state name {}",
            L::NAME,
            s.state_name()
        );
    }
    for (i, e) in L::EVENTS.iter().enumerate() {
        assert!(
            !L::EVENTS[..i].contains(e),
            "{}: duplicate event {}",
            L::NAME,
            L::event_name(*e)
        );
        assert!(
            !L::EVENTS[..i]
                .iter()
                .any(|p| L::event_name(*p) == L::event_name(*e)),
            "{}: duplicate event name {}",
            L::NAME,
            L::event_name(*e)
        );
    }
    for (i, t) in L::TABLE.iter().enumerate() {
        assert!(
            L::STATES.contains(&t.from) && L::STATES.contains(&t.to),
            "{}: table edge {} --{}--> {} uses an undeclared state",
            L::NAME,
            t.from.state_name(),
            L::event_name(t.event),
            t.to.state_name()
        );
        assert!(
            L::EVENTS.contains(&t.event),
            "{}: table edge from {} uses an undeclared event",
            L::NAME,
            t.from.state_name()
        );
        assert!(
            !L::TABLE[..i]
                .iter()
                .any(|p| p.from == t.from && p.event == t.event),
            "{}: nondeterministic table — two edges for ({}, {})",
            L::NAME,
            t.from.state_name(),
            L::event_name(t.event)
        );
    }
}

/// Property-drive a lifecycle graph with random event traces from
/// `initial`: every trace only ever takes declared edges (undeclared
/// pairs are refused without mutating the shadow state), terminal states
/// absorb every event, and — across all cases — every edge reachable
/// from `initial` is exercised at least once.
pub fn exercise_graph<L: Lifecycle>(seed: u64, initial: L) {
    let mut hit = vec![false; L::TABLE.len()];
    {
        let hit = &mut hit;
        proptest::check(seed, 200, |rng: &mut Rng| {
            let mut m = StateMachine::new(initial);
            for _ in 0..64 {
                let event = L::EVENTS[rng.index(L::EVENTS.len())];
                let before = m.state();
                match target(before, event) {
                    Some(to) => {
                        assert!(m.transition(event));
                        assert!(
                            m.state() == to,
                            "{}: transition from {} on {} landed in {}, table says {}",
                            L::NAME,
                            before.state_name(),
                            L::event_name(event),
                            m.state().state_name(),
                            to.state_name()
                        );
                        for (i, t) in L::TABLE.iter().enumerate() {
                            if t.from == before && t.event == event {
                                hit[i] = true;
                            }
                        }
                    }
                    // Undeclared pair: don't drive the machine (the Panic
                    // posture would abort the trace).  Terminal states
                    // absorb by construction — zero outgoing edges means
                    // every event of the alphabet lands here.
                    None => {}
                }
            }
        });
    }
    // Every edge reachable from `initial` must have been exercised.
    let mut reachable = vec![initial];
    let mut frontier = vec![initial];
    while let Some(s) = frontier.pop() {
        for t in L::TABLE {
            if t.from == s && !reachable.contains(&t.to) {
                reachable.push(t.to);
                frontier.push(t.to);
            }
        }
    }
    for (i, t) in L::TABLE.iter().enumerate() {
        if reachable.contains(&t.from) {
            assert!(
                hit[i],
                "{}: reachable edge {} --{}--> {} never exercised by any generated trace",
                L::NAME,
                t.from.state_name(),
                L::event_name(t.event),
                t.to.state_name()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Door {
        Open,
        Shut,
        Locked,
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum DoorEvent {
        Close,
        Lock,
    }

    impl Lifecycle for Door {
        type Event = DoorEvent;
        const NAME: &'static str = "door";
        const STATES: &'static [Door] = &[Door::Open, Door::Shut, Door::Locked];
        const EVENTS: &'static [DoorEvent] = &[DoorEvent::Close, DoorEvent::Lock];
        const TABLE: &'static [Transition<Door, DoorEvent>] = &[
            Transition { from: Door::Open, event: DoorEvent::Close, to: Door::Shut },
            Transition { from: Door::Shut, event: DoorEvent::Lock, to: Door::Locked },
        ];

        fn state_name(self) -> &'static str {
            match self {
                Door::Open => "Open",
                Door::Shut => "Shut",
                Door::Locked => "Locked",
            }
        }
        fn event_name(event: DoorEvent) -> &'static str {
            match event {
                DoorEvent::Close => "Close",
                DoorEvent::Lock => "Lock",
            }
        }
    }

    /// Same graph, `Ignore` posture, for the refusal paths.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    struct Lax(Door);

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    struct LaxEvent(DoorEvent);

    impl Lifecycle for Lax {
        type Event = LaxEvent;
        const NAME: &'static str = "door-lax";
        const STATES: &'static [Lax] =
            &[Lax(Door::Open), Lax(Door::Shut), Lax(Door::Locked)];
        const EVENTS: &'static [LaxEvent] =
            &[LaxEvent(DoorEvent::Close), LaxEvent(DoorEvent::Lock)];
        const TABLE: &'static [Transition<Lax, LaxEvent>] = &[
            Transition {
                from: Lax(Door::Open),
                event: LaxEvent(DoorEvent::Close),
                to: Lax(Door::Shut),
            },
            Transition {
                from: Lax(Door::Shut),
                event: LaxEvent(DoorEvent::Lock),
                to: Lax(Door::Locked),
            },
        ];
        const ON_INVALID: OnInvalid = OnInvalid::Ignore;

        fn state_name(self) -> &'static str {
            self.0.state_name()
        }
        fn event_name(event: LaxEvent) -> &'static str {
            Door::event_name(event.0)
        }
    }

    #[test]
    fn declared_edges_transition_and_fire_the_hook() {
        let mut m = StateMachine::new(Door::Open);
        let mut seen = Vec::new();
        assert!(m.transition_with(DoorEvent::Close, |from, ev, to| {
            seen.push((from, ev, to));
        }));
        assert_eq!(m.state(), Door::Shut);
        assert_eq!(seen, vec![(Door::Open, DoorEvent::Close, Door::Shut)]);
        assert!(m.transition(DoorEvent::Lock));
        assert_eq!(m.state(), Door::Locked);
    }

    #[test]
    #[should_panic(expected = "invalid door transition: event Lock in state Open")]
    fn undeclared_edge_panics_under_panic_policy() {
        let mut m = StateMachine::new(Door::Open);
        m.transition(DoorEvent::Lock);
    }

    #[test]
    fn undeclared_edge_is_refused_under_ignore_policy() {
        let mut m = StateMachine::new(Lax(Door::Open));
        let mut fired = false;
        assert!(!m.transition_with(LaxEvent(DoorEvent::Lock), |_, _, _| fired = true));
        assert_eq!(m.state(), Lax(Door::Open));
        assert!(!fired);
    }

    #[test]
    fn target_and_terminal_follow_the_table() {
        assert_eq!(target(Door::Open, DoorEvent::Close), Some(Door::Shut));
        assert_eq!(target(Door::Open, DoorEvent::Lock), None);
        assert!(!is_terminal(Door::Open));
        assert!(!is_terminal(Door::Shut));
        assert!(is_terminal(Door::Locked));
    }

    #[test]
    fn doc_table_parser_reads_edges_and_skips_headers() {
        let doc = "\
# sample

### door lifecycle

| from | event | to |
|---|---|---|
| `Open` | `Close` | `Shut` |
| `Shut` | `Lock` | `Locked` |

prose after the table

### next heading

| `Bogus` | `Row` | `Ignored` |
";
        let edges = doc_table_edges(doc, "### door lifecycle");
        assert_eq!(
            edges,
            vec![
                ("Open".into(), "Close".into(), "Shut".into()),
                ("Shut".into(), "Lock".into(), "Locked".into()),
            ]
        );
        assert_graph_matches_doc::<Door>(doc, "### door lifecycle");
    }

    #[test]
    #[should_panic(expected = "does not document it")]
    fn doc_mismatch_is_reported() {
        let doc = "### door lifecycle\n| `Open` | `Close` | `Shut` |\n";
        assert_graph_matches_doc::<Door>(doc, "### door lifecycle");
    }

    #[test]
    fn declaration_and_graph_properties_hold_for_the_sample() {
        check_declaration::<Door>();
        exercise_graph::<Door>(0xD00_12, Door::Open);
        check_declaration::<Lax>();
        exercise_graph::<Lax>(0xD00_13, Lax(Door::Open));
    }
}
