//! Memory-side DaeMon engine (§4, §6.7): the per-memory-module half of
//! the paper's "specialized hardware engine in each compute and memory
//! unit".
//!
//! The compute-side engine (`daemon::engine`) decides *what* moves; the
//! memory engine provides the *service*: hardware address translation and
//! DRAM reads/writes on per-tenant bandwidth partitions.  Partitioning is
//! §4.1-style and two-level — across tenants by weight, then across
//! line/page classes within a partitioned tenant's share — realizing the
//! per-tenant page and cache-line queue controllers.  Under
//! [`SharingMode::Strict`] a share is reserved even while other tenants
//! idle (the historical behavior, byte-identical); under
//! [`SharingMode::WorkConserving`] an access also draws on bus-queue
//! capacity idle at request time (peer tenants' queues, the sibling
//! class queue of a partitioned share), split proportionally by rate
//! with borrowed bytes charged to the lending queue's timeline.  The
//! engine also accounts egress traffic per tenant (raw vs
//! link-compressed bytes), the memory-side view of §4.4's link
//! compression.

//! Under a module-crash [`FaultTimeline`] the engine is a
//! failure-isolated component: work issued while the module is down is
//! deferred to the recovery edge, and work whose service interval
//! overlaps a crash is lost and replayed after it (requeued) — an empty
//! timeline takes the exact historical code path.

use crate::config::{SharingMode, TenantShare};
use crate::mem::DramBus;
use crate::net::{work_conserving_issue, work_conserving_plan, Class};
use crate::system::fault::{FaultCounters, FaultTimeline};

/// Per-tenant memory-side compression statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EgressStats {
    /// Uncompressed bytes the module served toward compute components.
    pub raw_bytes: u64,
    /// Bytes actually sent on the link after compression.
    pub sent_bytes: u64,
}

impl EgressStats {
    /// Achieved link-compression ratio (1.0 when nothing was sent).
    pub fn ratio(&self) -> f64 {
        if self.sent_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.sent_bytes as f64
        }
    }

    pub fn merge(&mut self, other: EgressStats) {
        self.raw_bytes += other.raw_bytes;
        self.sent_bytes += other.sent_bytes;
    }
}

/// One tenant's queue controllers on the module's DRAM bandwidth.
struct TenantQueues {
    bus: DramBus,
    stats: EgressStats,
    /// Bytes this tenant served on borrowed (idle peer / sibling-class)
    /// queue capacity — work-conserving mode only.
    reclaimed_bytes: u64,
    /// Aborted/deferred access counts under module-crash windows.
    counters: FaultCounters,
}

/// One memory module's engine: per-tenant page/line queue controllers
/// over the module's DRAM bandwidth — see the module docs for the
/// partitioning, sharing and failure models.
pub struct MemoryEngine {
    sharing: SharingMode,
    ports: Vec<TenantQueues>,
    /// Module-crash windows (empty = the exact no-fault code path).
    faults: FaultTimeline,
}

impl MemoryEngine {
    /// Build the engine from the module's DRAM rate/latency and the
    /// per-tenant shares (same splitting rule as the fabric ports).
    pub fn new(
        dram_bytes_per_cycle: f64,
        latency_cycles: f64,
        shares: &[TenantShare],
        interval: f64,
        sharing: SharingMode,
    ) -> MemoryEngine {
        let ports = shares
            .iter()
            .zip(TenantShare::rates(shares, dram_bytes_per_cycle))
            .map(|(s, rate)| {
                let bus = if s.partitioned {
                    DramBus::partitioned(rate, latency_cycles, s.line_ratio, interval)
                } else {
                    DramBus::shared(rate, latency_cycles, interval)
                };
                TenantQueues {
                    bus,
                    stats: EgressStats::default(),
                    reclaimed_bytes: 0,
                    counters: FaultCounters::default(),
                }
            })
            .collect();
        MemoryEngine { sharing, ports, faults: FaultTimeline::default() }
    }

    /// Number of tenant queue-controller sets on this module.
    pub fn tenants(&self) -> usize {
        self.ports.len()
    }

    /// DRAM access on tenant `t`'s bandwidth partition; returns
    /// completion.  Work-conserving mode additionally draws on queue
    /// capacity idle at `now`.
    pub fn access(&mut self, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        match self.sharing {
            SharingMode::Strict => {
                if self.faults.is_empty() {
                    self.ports[t].bus.access(now, bytes, class)
                } else {
                    self.faulted_access(t, now, bytes, class)
                }
            }
            SharingMode::WorkConserving => self.access_wc(t, now, bytes, class),
        }
    }

    /// DRAM access on a crashed/crashing module through the shared
    /// [`FaultTimeline::replay`] discipline: issue while down defers to
    /// the recovery edge; a service interval overlapping a crash is
    /// requeued — the occupied queue time is wasted and the access
    /// replays from the recovery edge.
    fn faulted_access(&mut self, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        let TenantQueues { bus, counters, .. } = &mut self.ports[t];
        let (done, _) = self.faults.replay(now, counters, |at| bus.access(at, bytes, class));
        done
    }

    /// Install the module's crash windows (strict sharing only — see
    /// `Fabric::set_faults` for why borrowing and faults don't compose).
    pub fn set_faults(&mut self, faults: FaultTimeline) {
        assert!(
            self.sharing == SharingMode::Strict,
            "fault injection requires strict sharing (SharingMode::Strict)"
        );
        self.faults = faults;
    }

    /// `(aborted, deferred)` access counts for tenant `t` — both zero
    /// unless crash windows are installed.
    pub fn fault_counts(&self, t: usize) -> (u64, u64) {
        let c = self.ports[t].counters;
        (c.aborted, c.deferred)
    }

    /// Work-conserving DRAM access: split `bytes` across tenant `t`'s
    /// own `class` queue plus every queue idle at `now` (sibling class
    /// inside a partitioned share, peer tenants' queues), proportionally
    /// to the queues' service rates; completion is when the slowest
    /// chunk finishes.
    fn access_wc(&mut self, t: usize, now: f64, bytes: u64, class: Class) -> f64 {
        let (cands, chunks) = {
            let ports = &self.ports;
            work_conserving_plan(
                t,
                class,
                ports.len(),
                bytes,
                |u| ports[u].bus.is_partitioned(),
                |u, c| ports[u].bus.idle(now, c),
                |u, c| ports[u].bus.rate(c),
            )
        };
        let ports = &mut self.ports;
        let (done, borrowed) = work_conserving_issue(&cands, &chunks, |u, c, chunk| {
            ports[u].bus.access(now, chunk, c)
        });
        ports[t].reclaimed_bytes += borrowed;
        done
    }

    /// Bytes tenant `t` served on borrowed queue capacity.
    pub fn reclaimed_bytes(&self, t: usize) -> u64 {
        self.ports[t].reclaimed_bytes
    }

    /// Queue occupancy ahead of tenant `t`'s `class` controller (cycles).
    pub fn backlog(&self, t: usize, now: f64, class: Class) -> f64 {
        self.ports[t].bus.backlog(now, class)
    }

    /// Service rate of tenant `t`'s `class` queue, bytes/cycle.
    pub fn rate(&self, t: usize, class: Class) -> f64 {
        self.ports[t].bus.rate(class)
    }

    /// Fixed DRAM processing latency per access, cycles.
    pub fn latency_cycles(&self, t: usize) -> f64 {
        self.ports[t].bus.latency_cycles
    }

    /// Record an egress transfer for tenant `t`: `raw` uncompressed bytes
    /// served as `sent` bytes on the link (equal when compression is off).
    pub fn note_egress(&mut self, t: usize, raw: u64, sent: u64) {
        self.ports[t].stats.raw_bytes += raw;
        self.ports[t].stats.sent_bytes += sent;
    }

    pub fn egress_stats(&self, t: usize) -> EgressStats {
        self.ports[t].stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shares(n: usize, partitioned: bool) -> Vec<TenantShare> {
        vec![TenantShare { weight: 1.0, partitioned, line_ratio: 0.25 }; n]
    }

    fn strict(
        bpc: f64,
        latency: f64,
        shares: &[TenantShare],
        interval: f64,
    ) -> MemoryEngine {
        MemoryEngine::new(bpc, latency, shares, interval, SharingMode::Strict)
    }

    #[test]
    fn single_tenant_matches_plain_bus() {
        let mut e = strict(4.0, 54.0, &shares(1, false), 1000.0);
        let mut d = DramBus::shared(4.0, 54.0, 1000.0);
        for (now, bytes) in [(0.0, 8u64), (0.0, 4096), (900.0, 64)] {
            let a = e.access(0, now, bytes, Class::Page);
            let b = d.access(now, bytes, Class::Page);
            assert_eq!(a.to_bits(), b.to_bits(), "engine must degrade exactly");
        }
    }

    #[test]
    fn tenant_partitions_are_strict() {
        let mut e = strict(4.0, 0.0, &shares(2, false), 1000.0);
        assert!((e.rate(0, Class::Line) - 2.0).abs() < 1e-12);
        // Tenant 0 floods its partition; tenant 1 is untouched.
        e.access(0, 0.0, 10_000, Class::Page);
        assert!(e.backlog(0, 0.0, Class::Page) > 1000.0);
        let t1 = e.access(1, 0.0, 64, Class::Line);
        assert!(t1 < 100.0, "tenant 1 delayed by tenant 0: {t1}");
        assert_eq!(e.reclaimed_bytes(0), 0, "strict mode never borrows");
    }

    #[test]
    fn per_tenant_class_partitioning_nests_inside_share() {
        let e = strict(8.0, 0.0, &shares(2, true), 1000.0);
        // 4 B/cyc per tenant, 25% of that for lines.
        assert!((e.rate(0, Class::Line) - 1.0).abs() < 1e-12);
        assert!((e.rate(0, Class::Page) - 3.0).abs() < 1e-12);
        assert_eq!(e.tenants(), 2);
    }

    #[test]
    fn work_conserving_borrows_idle_queue_capacity() {
        let mut e =
            MemoryEngine::new(4.0, 0.0, &shares(2, false), 1e6, SharingMode::WorkConserving);
        // Tenant 1 idle: tenant 0's 1000-byte read runs at the full
        // 4 B/cyc bus rate (500 bytes on each 2 B/cyc queue).
        let t = e.access(0, 0.0, 1000, Class::Page);
        assert!((t - 250.0).abs() < 1e-9, "idle queue capacity not reclaimed: {t}");
        assert_eq!(e.reclaimed_bytes(0), 500);
        // The lender queues behind what it lent.
        let t1 = e.access(1, 0.0, 100, Class::Page);
        assert!((t1 - 300.0).abs() < 1e-9, "{t1}");
    }

    #[test]
    fn work_conserving_single_tenant_matches_strict_bitwise() {
        let mut a = strict(4.0, 54.0, &shares(1, false), 1000.0);
        let mut b =
            MemoryEngine::new(4.0, 54.0, &shares(1, false), 1000.0, SharingMode::WorkConserving);
        for (now, bytes) in [(0.0, 8u64), (0.0, 4096), (900.0, 64)] {
            let x = a.access(0, now, bytes, Class::Page);
            let y = b.access(0, now, bytes, Class::Page);
            assert_eq!(x.to_bits(), y.to_bits(), "WC with no idle candidates must be strict");
        }
        assert_eq!(b.reclaimed_bytes(0), 0);
    }

    #[test]
    fn module_crash_defers_and_requeues_work() {
        let mut e = strict(4.0, 0.0, &shares(2, false), 1e6);
        e.set_faults(FaultTimeline::new(vec![(100.0, 500.0)]));
        // In service at the crash: 800 bytes on tenant 0's 2 B/cyc queue
        // span [0, 400) — lost, replayed from the recovery edge (the
        // wasted queue time stays on the timeline): 500 + 400 = 900.
        let a = e.access(0, 0.0, 800, Class::Page);
        assert!((a - 900.0).abs() < 1e-9, "{a}");
        // Issued during the outage: deferred to recovery on its own
        // (independent) queue.
        let b = e.access(1, 200.0, 100, Class::Line);
        assert!((b - 550.0).abs() < 1e-9, "{b}");
        assert_eq!(e.fault_counts(0), (1, 0));
        assert_eq!(e.fault_counts(1), (0, 1));
        // Post-recovery accesses are clean.
        let c = e.access(1, 2000.0, 100, Class::Line);
        assert!((c - 2050.0).abs() < 1e-9, "{c}");
    }

    #[test]
    fn empty_fault_timeline_degrades_exactly() {
        let mut a = strict(4.0, 54.0, &shares(1, false), 1000.0);
        let mut b = strict(4.0, 54.0, &shares(1, false), 1000.0);
        b.set_faults(FaultTimeline::default());
        for (now, bytes) in [(0.0, 8u64), (0.0, 4096), (900.0, 64)] {
            let x = a.access(0, now, bytes, Class::Page);
            let y = b.access(0, now, bytes, Class::Page);
            assert_eq!(x.to_bits(), y.to_bits(), "empty timeline must be the no-fault path");
        }
        assert_eq!(b.fault_counts(0), (0, 0));
    }

    #[test]
    #[should_panic(expected = "strict sharing")]
    fn engine_fault_injection_requires_strict_sharing() {
        let mut e =
            MemoryEngine::new(4.0, 0.0, &shares(1, false), 1e6, SharingMode::WorkConserving);
        e.set_faults(FaultTimeline::new(vec![(0.0, 10.0)]));
    }

    #[test]
    fn egress_stats_track_compression() {
        let mut e = strict(4.0, 0.0, &shares(2, false), 1000.0);
        e.note_egress(0, 4096, 1024);
        e.note_egress(0, 4096, 1024);
        e.note_egress(1, 64, 64);
        assert!((e.egress_stats(0).ratio() - 4.0).abs() < 1e-12);
        assert!((e.egress_stats(1).ratio() - 1.0).abs() < 1e-12);
        let mut total = e.egress_stats(0);
        total.merge(e.egress_stats(1));
        assert_eq!(total.raw_bytes, 8256);
        assert_eq!(EgressStats::default().ratio(), 1.0);
    }
}
