//! DaeMon compute-engine state machine (§4.2, §4.3; Figs. 6–7).
//!
//! Tracks inflight data migrations at both granularities and implements
//! the selection-granularity unit and the dirty unit.  This is a pure
//! state machine — all *timing* (queue controller service, link
//! serialization) lives in the machine driver; the engine decides *what*
//! to request and guarantees the coherence invariants of §4.3:
//!
//!   * a page and line for the same data may be inflight simultaneously;
//!     when the page arrives first, stale line arrivals are ignored
//!     (their inflight entries are removed on page arrival);
//!   * dirty LLC evictions that miss local memory while their page is
//!     inflight are parked in the dirty buffer and flushed to local
//!     memory on page arrival;
//!   * when parked dirty lines for a page exceed the flush threshold,
//!     all are flushed to remote and the inflight page is marked
//!     *throttled* — its arrival is ignored and the page re-requested.

use crate::config::DaemonParams;
use crate::lifecycle::{is_terminal, Lifecycle, StateMachine, Transition};
use crate::util::hash::FxHashMap;

/// Inflight page buffer entry lifecycle (Fig. 7b) — see the DESIGN.md
/// §"Lifecycles and state machines" table this graph is pinned against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageLifecycle {
    /// In the page queue, transfer not yet started.
    Scheduled,
    /// Transfer issued (being migrated).
    Moved,
    /// Dirty-threshold exceeded: arrival must be ignored + re-requested.
    Throttled,
    /// Terminal: arrived clean — installed in local memory (the entry is
    /// removed from the buffer as soon as this state is reached).
    Installed,
    /// Terminal: arrived stale after a throttle — data discarded and the
    /// page re-requested (entry likewise removed immediately).
    Rerequested,
}

/// Events driving [`PageLifecycle`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageEvent {
    /// The link transfer enters service (`start <= now`).
    Start,
    /// A dirty evicted line parks in the dirty buffer.
    Park,
    /// Dirty flush threshold exceeded / dirty buffer full.
    Overflow,
    /// The page data arrives at the compute component.
    Arrive,
}

impl Lifecycle for PageLifecycle {
    type Event = PageEvent;
    const NAME: &'static str = "engine page";
    const STATES: &'static [PageLifecycle] = &[
        PageLifecycle::Scheduled,
        PageLifecycle::Moved,
        PageLifecycle::Throttled,
        PageLifecycle::Installed,
        PageLifecycle::Rerequested,
    ];
    const EVENTS: &'static [PageEvent] =
        &[PageEvent::Start, PageEvent::Park, PageEvent::Overflow, PageEvent::Arrive];
    const TABLE: &'static [Transition<PageLifecycle, PageEvent>] = &[
        Transition { from: PageLifecycle::Scheduled, event: PageEvent::Start, to: PageLifecycle::Moved },
        Transition { from: PageLifecycle::Scheduled, event: PageEvent::Park, to: PageLifecycle::Scheduled },
        Transition { from: PageLifecycle::Moved, event: PageEvent::Park, to: PageLifecycle::Moved },
        Transition { from: PageLifecycle::Scheduled, event: PageEvent::Overflow, to: PageLifecycle::Throttled },
        Transition { from: PageLifecycle::Moved, event: PageEvent::Overflow, to: PageLifecycle::Throttled },
        Transition { from: PageLifecycle::Scheduled, event: PageEvent::Arrive, to: PageLifecycle::Installed },
        Transition { from: PageLifecycle::Moved, event: PageEvent::Arrive, to: PageLifecycle::Installed },
        Transition { from: PageLifecycle::Throttled, event: PageEvent::Arrive, to: PageLifecycle::Rerequested },
    ];

    fn state_name(self) -> &'static str {
        match self {
            PageLifecycle::Scheduled => "Scheduled",
            PageLifecycle::Moved => "Moved",
            PageLifecycle::Throttled => "Throttled",
            PageLifecycle::Installed => "Installed",
            PageLifecycle::Rerequested => "Rerequested",
        }
    }
    fn event_name(event: PageEvent) -> &'static str {
        match event {
            PageEvent::Start => "Start",
            PageEvent::Park => "Park",
            PageEvent::Overflow => "Overflow",
            PageEvent::Arrive => "Arrive",
        }
    }
}

/// Per-line lifecycle of an inflight sub-block request.  The engine
/// stores up to 64 of these machines per page as a dense bitmap (a set
/// bit is a machine in `Inflight`; cleared bits have reached a terminal
/// state and left the buffer), so the enum itself is the documentation
/// and type-checking surface while the hot path stays bit arithmetic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineLifecycle {
    /// Line movement issued, data not yet arrived.
    Inflight,
    /// Terminal: the line data arrived and was handed to the LLC.
    Delivered,
    /// Terminal: the whole page arrived first — any later packet for
    /// this line is stale and ignored (§4.3 scenario i).
    Stale,
}

/// Events driving [`LineLifecycle`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// The line data packet arrives.
    Arrive,
    /// The page containing this line arrives first.
    Supersede,
}

impl Lifecycle for LineLifecycle {
    type Event = LineEvent;
    const NAME: &'static str = "engine line";
    const STATES: &'static [LineLifecycle] =
        &[LineLifecycle::Inflight, LineLifecycle::Delivered, LineLifecycle::Stale];
    const EVENTS: &'static [LineEvent] = &[LineEvent::Arrive, LineEvent::Supersede];
    const TABLE: &'static [Transition<LineLifecycle, LineEvent>] = &[
        Transition { from: LineLifecycle::Inflight, event: LineEvent::Arrive, to: LineLifecycle::Delivered },
        Transition { from: LineLifecycle::Inflight, event: LineEvent::Supersede, to: LineLifecycle::Stale },
    ];

    fn state_name(self) -> &'static str {
        match self {
            LineLifecycle::Inflight => "Inflight",
            LineLifecycle::Delivered => "Delivered",
            LineLifecycle::Stale => "Stale",
        }
    }
    fn event_name(event: LineEvent) -> &'static str {
        match event {
            LineEvent::Arrive => "Arrive",
            LineEvent::Supersede => "Supersede",
        }
    }
}

#[derive(Clone, Debug)]
pub struct PageEntry {
    /// The entry's lifecycle machine — mutable only via `transition`.
    pub lifecycle: StateMachine<PageLifecycle>,
    /// Cycle at which the link transfer starts (enters service).
    pub start: f64,
    /// Cycle at which the page arrives at the compute component.
    pub arrive: f64,
    /// Offsets (64-bit bitmap) of dirty lines parked in the dirty buffer.
    pub dirty_mask: u64,
}

impl PageEntry {
    /// Current lifecycle state.
    pub fn state(&self) -> PageLifecycle {
        self.lifecycle.state()
    }
}

/// Inflight sub-block buffer entry (Fig. 7a): page-indexed, 64-bit offset
/// bitmap of inflight line requests, plus each line's arrival time.
#[derive(Clone, Debug)]
pub struct LineEntry {
    pub mask: u64,
    pub arrive: [f64; 64],
}

impl Default for LineEntry {
    fn default() -> Self {
        Self { mask: 0, arrive: [0.0; 64] }
    }
}

/// What the selection unit decided for one demand miss (§4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    /// Issue a page-granularity migration.
    pub send_page: bool,
    /// Issue a cache-line-granularity movement.
    pub send_line: bool,
    /// The request can be served by an already-inflight page/line.
    pub wait_inflight: bool,
}

pub struct ComputeEngine {
    pub params: DaemonParams,
    // Fx-hashed: probed on every LLC miss (decide / inflight checks) and
    // every arrival.  Never iterated — map order must not feed metrics
    // (DESIGN.md §"Simulator performance model").
    pages: FxHashMap<u64, PageEntry>,
    lines: FxHashMap<u64, LineEntry>,
    line_count: usize,
    dirty_count: usize,
    // Statistics for the experiment harness.
    pub pages_requested: u64,
    pub pages_throttled_by_full_buffer: u64,
    pub pages_rerequested: u64,
    pub lines_requested: u64,
    pub lines_suppressed: u64,
    pub dirty_parked: u64,
    pub dirty_flushed_threshold: u64,
}

impl ComputeEngine {
    pub fn new(params: DaemonParams) -> Self {
        Self {
            params,
            pages: FxHashMap::default(),
            lines: FxHashMap::default(),
            line_count: 0,
            dirty_count: 0,
            pages_requested: 0,
            pages_throttled_by_full_buffer: 0,
            pages_rerequested: 0,
            lines_requested: 0,
            lines_suppressed: 0,
            dirty_parked: 0,
            dirty_flushed_threshold: 0,
        }
    }

    pub fn page_util(&self) -> f64 {
        self.pages.len() as f64 / self.params.inflight_page_buf as f64
    }

    pub fn line_util(&self) -> f64 {
        self.line_count as f64 / self.params.inflight_subblock_buf as f64
    }

    pub fn inflight_page(&self, page: u64) -> Option<&PageEntry> {
        self.pages.get(&page)
    }

    pub fn inflight_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn inflight_lines(&self) -> usize {
        self.line_count
    }

    pub fn dirty_buffered(&self) -> usize {
        self.dirty_count
    }

    /// Is this specific line already inflight? Returns its arrival time.
    pub fn inflight_line(&self, page: u64, offset: u8) -> Option<f64> {
        self.lines.get(&page).and_then(|e| {
            if e.mask & (1u64 << offset) != 0 {
                Some(e.arrive[offset as usize])
            } else {
                None
            }
        })
    }

    /// §4.2 selection logic for a demand miss at (`page`, `offset`),
    /// issued at `now`.  `line_eta` is the estimated arrival time a line
    /// request issued now would achieve (computed by the driver from the
    /// sub-block queue backlog — the quantity the hardware's queue
    /// occupancies proxy).  `selection_enabled=false` degrades to the BP
    /// policy (always both, bounded only by dedup and buffer capacity).
    ///
    /// The paper's §4.2 rule for a miss whose page is already inflight —
    /// "send the cache line only if the sub-block buffer has lower
    /// utilization than the page buffer and the page is not already in
    /// the process of migration … this avoids unnecessarily sending cache
    /// lines when the corresponding page is likely to arrive faster and
    /// when the sub-block queue is likely to be slow due to
    /// oversaturation" — is implemented by its stated intent: the line is
    /// sent iff it is expected to arrive *before* the inflight page.  The
    /// two queue occupancies are the hardware's estimator of exactly this
    /// comparison; the simulator computes it directly.
    pub fn decide(
        &self,
        page: u64,
        offset: u8,
        now: f64,
        selection_enabled: bool,
        line_eta: f64,
    ) -> Decision {
        let _ = now;
        let page_inflight = self.pages.get(&page);
        let line_inflight = self.inflight_line(page, offset).is_some();

        // Page side: request unless already inflight or buffer full.
        let page_buf_full = self.pages.len() >= self.params.inflight_page_buf;
        let send_page = page_inflight.is_none() && !page_buf_full;

        // Line side.
        let line_buf_full = self.line_count >= self.params.inflight_subblock_buf;
        let send_line = if line_inflight || line_buf_full {
            false
        } else if !selection_enabled {
            true
        } else {
            match page_inflight {
                // Page not scheduled (and possibly not schedulable):
                // always move the line.
                None => true,
                // Page inflight: send the line only if it beats the page.
                Some(e) => line_eta < e.arrive,
            }
        };

        Decision {
            send_page,
            send_line,
            wait_inflight: page_inflight.is_some() || line_inflight,
        }
    }

    /// Record an issued page migration (after the driver scheduled the
    /// transfer on the page channel).
    pub fn note_page_scheduled(&mut self, page: u64, start: f64, arrive: f64) {
        debug_assert!(self.pages.len() < self.params.inflight_page_buf);
        self.pages.insert(
            page,
            PageEntry {
                lifecycle: StateMachine::new(PageLifecycle::Scheduled),
                start,
                arrive,
                dirty_mask: 0,
            },
        );
        self.pages_requested += 1;
    }

    /// Record an issued line movement.
    pub fn note_line_scheduled(&mut self, page: u64, offset: u8, arrive: f64) {
        let e = self.lines.entry(page).or_default();
        let bit = 1u64 << offset;
        debug_assert_eq!(e.mask & bit, 0, "line double-scheduled");
        e.mask |= bit;
        e.arrive[offset as usize] = arrive;
        self.line_count += 1;
        self.lines_requested += 1;
    }

    /// Advance one page's state Scheduled -> Moved when its transfer has
    /// entered service.  (Per-page, not a full-buffer scan: the full scan
    /// was the top profile entry of the dirty-eviction path — see
    /// EXPERIMENTS.md §Perf.)
    #[inline]
    fn promote_moved_one(&mut self, page: u64, now: f64) {
        if let Some(e) = self.pages.get_mut(&page) {
            if e.lifecycle.state() == PageLifecycle::Scheduled && e.start <= now {
                e.lifecycle.transition(PageEvent::Start);
            }
        }
    }

    /// Retire `mask`'s set bits — each a [`LineLifecycle`] machine in
    /// `Inflight` — through `event`.  All set bits take the same edge,
    /// so one machine drive covers the batch; the edge must land in a
    /// terminal state (the bits leave the buffer).  Returns how many
    /// lines retired.
    #[inline]
    fn retire_lines(mask: u64, event: LineEvent) -> usize {
        let n = mask.count_ones() as usize;
        if n > 0 {
            let mut line = StateMachine::new(LineLifecycle::Inflight);
            line.transition(event);
            debug_assert!(is_terminal(line.state()));
        }
        n
    }

    /// Line arrival: release its inflight entry.  Returns false if the
    /// line had already been superseded by its page's arrival (stale data
    /// packet — ignored per §4.3 scenario (i)).
    pub fn line_arrived(&mut self, page: u64, offset: u8) -> bool {
        if let Some(e) = self.lines.get_mut(&page) {
            let bit = 1u64 << offset;
            if e.mask & bit != 0 {
                self.line_count -= Self::retire_lines(bit, LineEvent::Arrive);
                e.mask &= !bit;
                if e.mask == 0 {
                    self.lines.remove(&page);
                }
                return true;
            }
        }
        false
    }

    /// Outcome of a page arrival.
    #[must_use]
    pub fn page_arrived(&mut self, page: u64) -> PageArrival {
        let Some(mut entry) = self.pages.remove(&page) else {
            return PageArrival::Unknown;
        };
        // §4.3 scenario (i): every inflight line of this page takes the
        // Inflight -> Stale edge at once — any later line packets are
        // stale and will be ignored.
        if let Some(le) = self.lines.remove(&page) {
            self.line_count -= Self::retire_lines(le.mask, LineEvent::Supersede);
        }
        entry.lifecycle.transition(PageEvent::Arrive);
        if entry.lifecycle.state() == PageLifecycle::Rerequested {
            self.pages_rerequested += 1;
            return PageArrival::ThrottledRerequest;
        }
        debug_assert_eq!(entry.lifecycle.state(), PageLifecycle::Installed);
        let parked = entry.dirty_mask.count_ones() as usize;
        self.dirty_count -= parked;
        PageArrival::Install { parked_dirty_lines: parked as u32 }
    }

    /// §4.3 scenario (ii): a dirty LLC line evicted, missing local memory.
    /// Returns what the driver must do with it.
    pub fn dirty_evict(&mut self, page: u64, offset: u8, now: f64) -> DirtyOutcome {
        self.promote_moved_one(page, now);
        let threshold = self.params.dirty_flush_threshold;
        let buf_full = self.dirty_count >= self.params.dirty_data_buf;
        match self.pages.get_mut(&page) {
            None => DirtyOutcome::WriteRemote,
            Some(e) if e.lifecycle.state() == PageLifecycle::Throttled => {
                DirtyOutcome::WriteRemote
            }
            Some(e) => {
                let bit = 1u64 << offset;
                let newly = e.dirty_mask & bit == 0;
                let would_have = e.dirty_mask.count_ones() as usize + usize::from(newly);
                if buf_full || would_have > threshold {
                    // Flush everything parked for this page + this line to
                    // remote; the Overflow edge marks the entry throttled
                    // so the arriving page (with stale data) is discarded
                    // and re-requested.
                    let flushed = e.dirty_mask.count_ones() as usize;
                    self.dirty_count -= flushed;
                    e.dirty_mask = 0;
                    e.lifecycle.transition(PageEvent::Overflow);
                    self.dirty_flushed_threshold += 1;
                    DirtyOutcome::FlushAllAndThrottle { parked_flushed: flushed as u32 }
                } else {
                    if newly {
                        // Park is a self-edge: the entry stays where it is
                        // while the dirty buffer accumulates this line.
                        e.lifecycle.transition(PageEvent::Park);
                        e.dirty_mask |= bit;
                        self.dirty_count += 1;
                        self.dirty_parked += 1;
                    }
                    DirtyOutcome::Parked
                }
            }
        }
    }

    /// Bookkeeping noted by the driver when selection suppressed a line.
    pub fn note_line_suppressed(&mut self) {
        self.lines_suppressed += 1;
    }

    pub fn note_page_buffer_full(&mut self) {
        self.pages_throttled_by_full_buffer += 1;
    }
}

/// Result of [`ComputeEngine::page_arrived`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageArrival {
    /// Install in local memory; flush this many parked dirty lines into it.
    Install { parked_dirty_lines: u32 },
    /// Entry was throttled: discard the data and re-request the page.
    ThrottledRerequest,
    /// No inflight entry (e.g. duplicate arrival after throttle handling).
    Unknown,
}

/// Result of [`ComputeEngine::dirty_evict`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DirtyOutcome {
    /// No inflight page: write the line directly to remote memory.
    WriteRemote,
    /// Parked in the dirty buffer until the page arrives.
    Parked,
    /// Threshold exceeded: all parked lines (count returned) plus this one
    /// go to remote now; page marked throttled.
    FlushAllAndThrottle { parked_flushed: u32 },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DaemonParams;

    fn engine() -> ComputeEngine {
        ComputeEngine::new(DaemonParams::default())
    }

    fn small_engine() -> ComputeEngine {
        ComputeEngine::new(DaemonParams {
            inflight_page_buf: 4,
            inflight_subblock_buf: 4,
            dirty_data_buf: 8,
            dirty_flush_threshold: 3,
            ..DaemonParams::default()
        })
    }

    #[test]
    fn first_miss_requests_both() {
        let e = engine();
        let d = e.decide(7, 3, 0.0, true, 100.0);
        assert!(d.send_page && d.send_line && !d.wait_inflight);
    }

    #[test]
    fn duplicate_page_not_rerequested() {
        let mut e = engine();
        e.note_page_scheduled(7, 10.0, 100.0);
        let d = e.decide(7, 4, 0.0, true, 50.0);
        assert!(!d.send_page);
        assert!(d.wait_inflight);
    }

    #[test]
    fn line_sent_when_it_beats_the_inflight_page() {
        let mut e = engine();
        e.note_page_scheduled(7, 50.0, 1000.0); // page arrives late
        let d = e.decide(7, 4, 0.0, true, 400.0); // line ETA beats it
        assert!(d.send_line, "line should be sent when it arrives first");
    }

    #[test]
    fn line_suppressed_when_page_arrives_first() {
        let mut e = engine();
        e.note_page_scheduled(7, 50.0, 100.0); // page arrives soon
        let d = e.decide(7, 4, 60.0, true, 900.0); // line would be slower
        assert!(!d.send_line, "line must not be sent when page wins");
        assert!(d.wait_inflight);
    }

    #[test]
    fn line_suppressed_when_subblock_buffer_full() {
        let mut e = small_engine(); // 4-entry sub-block buffer
        for p in 1..=4 {
            e.note_line_scheduled(p, 0, 10.0);
        }
        let d = e.decide(9, 4, 0.0, true, 1.0);
        assert!(!d.send_line, "sub-block buffer full");
    }

    #[test]
    fn bp_mode_ignores_arrival_estimate() {
        let mut e = small_engine();
        e.note_page_scheduled(7, 50.0, 100.0);
        let d = e.decide(7, 4, 60.0, false, 1e12);
        assert!(d.send_line, "BP always sends the line (dedup aside)");
    }

    #[test]
    fn page_buffer_full_throttles_page_requests() {
        let mut e = small_engine();
        for p in 0..4 {
            e.note_page_scheduled(p, 0.0, 100.0);
        }
        let d = e.decide(99, 0, 0.0, true, 50.0);
        assert!(!d.send_page, "page buffer full");
        assert!(d.send_line, "line must still be movable");
    }

    #[test]
    fn line_dedup_within_page_entry() {
        let mut e = engine();
        e.note_line_scheduled(7, 4, 100.0);
        let d = e.decide(7, 4, 0.0, true, 50.0);
        assert!(!d.send_line);
        assert!(d.wait_inflight);
        assert_eq!(e.inflight_line(7, 4), Some(100.0));
        assert_eq!(e.inflight_line(7, 5), None);
        // A different offset in the same page is a fresh line request.
        let d2 = e.decide(7, 5, 0.0, true, 50.0);
        assert!(d2.send_line);
    }

    #[test]
    fn page_arrival_installs_and_clears_lines() {
        let mut e = engine();
        e.note_page_scheduled(7, 0.0, 100.0);
        e.note_line_scheduled(7, 3, 120.0);
        e.note_line_scheduled(7, 9, 130.0);
        assert_eq!(e.inflight_lines(), 2);
        let out = e.page_arrived(7);
        assert_eq!(out, PageArrival::Install { parked_dirty_lines: 0 });
        assert_eq!(e.inflight_lines(), 0, "line entries cleared on page arrival");
        // Stale line packet later: ignored.
        assert!(!e.line_arrived(7, 3));
    }

    #[test]
    fn line_arrival_releases_entry() {
        let mut e = engine();
        e.note_line_scheduled(7, 3, 50.0);
        assert!(e.line_arrived(7, 3));
        assert_eq!(e.inflight_lines(), 0);
        assert!(!e.line_arrived(7, 3), "double arrival ignored");
    }

    #[test]
    fn page_entry_walks_the_declared_lifecycle() {
        let mut e = small_engine(); // threshold 3
        e.note_page_scheduled(7, 10.0, 100.0);
        assert_eq!(e.inflight_page(7).unwrap().state(), PageLifecycle::Scheduled);
        // A dirty eviction before `start` parks without promoting.
        assert_eq!(e.dirty_evict(7, 1, 5.0), DirtyOutcome::Parked);
        assert_eq!(e.inflight_page(7).unwrap().state(), PageLifecycle::Scheduled);
        // After `start` the transfer is in service: Scheduled -> Moved.
        assert_eq!(e.dirty_evict(7, 2, 20.0), DirtyOutcome::Parked);
        assert_eq!(e.inflight_page(7).unwrap().state(), PageLifecycle::Moved);
        // Exceeding the threshold takes the Overflow edge.
        let _ = e.dirty_evict(7, 3, 21.0);
        let out = e.dirty_evict(7, 4, 22.0);
        assert_eq!(out, DirtyOutcome::FlushAllAndThrottle { parked_flushed: 3 });
        assert_eq!(e.inflight_page(7).unwrap().state(), PageLifecycle::Throttled);
        // Arrival from Throttled is the terminal Rerequested state; the
        // entry leaves the buffer.
        assert_eq!(e.page_arrived(7), PageArrival::ThrottledRerequest);
        assert!(e.inflight_page(7).is_none());
    }

    #[test]
    fn dirty_without_inflight_page_goes_remote() {
        let mut e = engine();
        assert_eq!(e.dirty_evict(7, 0, 0.0), DirtyOutcome::WriteRemote);
    }

    #[test]
    fn dirty_parks_then_flushes_on_arrival() {
        let mut e = engine();
        e.note_page_scheduled(7, 0.0, 100.0);
        assert_eq!(e.dirty_evict(7, 1, 10.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_evict(7, 2, 11.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_buffered(), 2);
        let out = e.page_arrived(7);
        assert_eq!(out, PageArrival::Install { parked_dirty_lines: 2 });
        assert_eq!(e.dirty_buffered(), 0);
    }

    #[test]
    fn dirty_threshold_flushes_and_throttles() {
        let mut e = small_engine(); // threshold 3
        e.note_page_scheduled(7, 0.0, 100.0);
        assert_eq!(e.dirty_evict(7, 1, 1.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_evict(7, 2, 2.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_evict(7, 3, 3.0), DirtyOutcome::Parked);
        // Fourth distinct dirty line exceeds threshold 3.
        let out = e.dirty_evict(7, 4, 4.0);
        assert_eq!(out, DirtyOutcome::FlushAllAndThrottle { parked_flushed: 3 });
        assert_eq!(e.dirty_buffered(), 0);
        // Arrival of the (stale) page data must be discarded + re-request.
        assert_eq!(e.page_arrived(7), PageArrival::ThrottledRerequest);
        // Further dirty evictions while throttled go straight to remote.
    }

    #[test]
    fn dirty_buffer_full_throttles_page_with_zero_parked_lines() {
        // The *global* dirty buffer is full, but the evicting page has no
        // parked lines of its own: the eviction must still flush (just
        // this line) and throttle that page — parking would overflow the
        // buffer — while other pages' parked lines stay put.
        let mut e = ComputeEngine::new(DaemonParams {
            inflight_page_buf: 8,
            inflight_subblock_buf: 8,
            dirty_data_buf: 2,
            dirty_flush_threshold: 8, // threshold alone would allow parking
            ..DaemonParams::default()
        });
        e.note_page_scheduled(1, 0.0, 100.0);
        e.note_page_scheduled(2, 0.0, 100.0);
        assert_eq!(e.dirty_evict(1, 0, 1.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_evict(1, 1, 2.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_buffered(), 2, "buffer now full");
        let out = e.dirty_evict(2, 0, 3.0);
        assert_eq!(out, DirtyOutcome::FlushAllAndThrottle { parked_flushed: 0 });
        assert_eq!(e.dirty_buffered(), 2, "page 1's parked lines untouched");
        // Page 2 arrives stale and is re-requested; page 1 installs its
        // parked lines normally.
        assert_eq!(e.page_arrived(2), PageArrival::ThrottledRerequest);
        assert_eq!(e.page_arrived(1), PageArrival::Install { parked_dirty_lines: 2 });
        assert_eq!(e.dirty_buffered(), 0);
    }

    #[test]
    fn dirty_same_offset_rewrites_dont_double_count() {
        let mut e = small_engine();
        e.note_page_scheduled(7, 0.0, 100.0);
        assert_eq!(e.dirty_evict(7, 1, 1.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_evict(7, 1, 2.0), DirtyOutcome::Parked);
        assert_eq!(e.dirty_buffered(), 1);
    }

    #[test]
    fn throttled_page_dirty_goes_remote() {
        let mut e = small_engine();
        e.note_page_scheduled(7, 0.0, 100.0);
        for o in 1..=4 {
            let _ = e.dirty_evict(7, o, o as f64);
        }
        assert_eq!(e.dirty_evict(7, 9, 9.0), DirtyOutcome::WriteRemote);
    }

    #[test]
    fn no_lost_dirty_lines_property() {
        // Invariant: every dirty eviction is either written remote
        // (immediately or via flush) or flushed to local on page arrival.
        crate::util::proptest::check(0xD1271, 25, |rng| {
            let mut e = ComputeEngine::new(DaemonParams {
                inflight_page_buf: 8,
                inflight_subblock_buf: 8,
                dirty_data_buf: 16,
                dirty_flush_threshold: 4,
                ..DaemonParams::default()
            });
            let mut written_remote = 0u64;
            let mut flushed_local = 0u64;
            let mut evicted = 0u64;
            let mut inflight: Vec<u64> = Vec::new();
            for step in 0..300u64 {
                let now = step as f64;
                match rng.below(4) {
                    0 => {
                        let page = rng.below(16);
                        if e.inflight_page(page).is_none()
                            && e.inflight_pages() < 8
                        {
                            e.note_page_scheduled(page, now, now + 50.0);
                            inflight.push(page);
                        }
                    }
                    1 => {
                        let page = rng.below(16);
                        evicted += 1;
                        match e.dirty_evict(page, (rng.below(64)) as u8, now) {
                            DirtyOutcome::WriteRemote => written_remote += 1,
                            DirtyOutcome::Parked => {}
                            DirtyOutcome::FlushAllAndThrottle { parked_flushed } => {
                                written_remote += parked_flushed as u64 + 1;
                            }
                        }
                    }
                    _ => {
                        if let Some(i) = (!inflight.is_empty())
                            .then(|| rng.index(inflight.len()))
                        {
                            let page = inflight.swap_remove(i);
                            match e.page_arrived(page) {
                                PageArrival::Install { parked_dirty_lines } => {
                                    flushed_local += parked_dirty_lines as u64;
                                }
                                PageArrival::ThrottledRerequest => {
                                    // Re-request immediately.
                                    e.note_page_scheduled(page, now, now + 50.0);
                                    inflight.push(page);
                                }
                                PageArrival::Unknown => panic!("unknown arrival"),
                            }
                        }
                    }
                }
            }
            // Drain: all remaining inflight pages arrive.
            for page in inflight {
                if let PageArrival::Install { parked_dirty_lines } = e.page_arrived(page) {
                    flushed_local += parked_dirty_lines as u64;
                }
            }
            assert_eq!(e.dirty_buffered(), 0, "dirty lines left parked");
            // Parked duplicates collapse (same offset), so accounted
            // lines never exceed evictions but all parked were resolved.
            assert!(written_remote + flushed_local <= evicted);
        });
    }
}
