//! Hardware cost model for DaeMon's structures (§4.5, Table 1).
//!
//! The paper sizes each SRAM/CAM with CACTI 6.0 on a 64-core-class node.
//! CACTI itself is not available offline, so we reproduce Table 1 with a
//! calibrated analytic model of the same form CACTI uses: access time,
//! area and energy scale with capacity and port structure; CAMs pay a
//! match-line overhead.  The constants are fit to the paper's own Table 1
//! values (this is the paper's *reported estimate*, which is the artifact
//! being reproduced — see DESIGN.md substitutions).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    Sram,
    Cam,
}

#[derive(Clone, Debug)]
pub struct Structure {
    pub name: &'static str,
    /// C = compute engine, M = memory engine.
    pub engine: char,
    pub kind: MemKind,
    pub entries: Option<u32>,
    pub size_kb: f64,
}

#[derive(Clone, Debug)]
pub struct CostRow {
    pub structure: Structure,
    pub access_ns: f64,
    pub area_mm2: f64,
    pub energy_nj: f64,
}

/// Analytic CACTI-like model: t = a + b*sqrt(KB), area = c*KB^0.9 + d,
/// energy = e + f*KB; CAM multipliers on time/energy.
fn model(kind: MemKind, size_kb: f64) -> (f64, f64, f64) {
    let (t, a, e) = match kind {
        MemKind::Sram => (
            0.30 + 0.115 * size_kb.sqrt(),
            0.080 + 0.0105 * size_kb.powf(0.9),
            0.037 + 0.0006 * size_kb,
        ),
        MemKind::Cam => (
            0.25 + 0.26 * size_kb.sqrt(),
            0.015 * size_kb.powf(1.25),
            0.018 + 0.024 * size_kb,
        ),
    };
    (t, a, e)
}

/// DaeMon's hardware structures (Table 1 rows).
pub fn structures() -> Vec<Structure> {
    use MemKind::*;
    let s = |name: &'static str, engine, kind, entries, size_kb| Structure {
        name,
        engine,
        kind,
        entries,
        size_kb,
    };
    vec![
        s("Sub-block Queue (C)", 'C', Sram, Some(128), 0.5),
        s("Sub-block Queue (M)", 'M', Sram, Some(512), 2.0),
        s("Page Queue (C)", 'C', Sram, Some(256), 1.0),
        s("Page Queue (M)", 'M', Sram, Some(1024), 4.0),
        s("Inflight Sub-block Buffer (C)", 'C', Cam, Some(128), 1.625),
        s("Inflight Page Buffer (C)", 'C', Cam, Some(256), 3.25),
        s("Dirty Data Buffer (C)", 'C', Sram, Some(256), 17.0),
        s("Packet Buffer (C)", 'C', Sram, None, 8.0),
        s("Packet Buffer (M)", 'M', Sram, None, 32.0),
        s("2 x Dictionary Table (C,M)", 'B', Cam, Some(1024), 1.0),
    ]
}

/// Paper Table 1 reference values (access ns, area mm², energy nJ) in the
/// same row order — used by tests to bound the model error.
pub const PAPER_TABLE1: [(f64, f64, f64); 10] = [
    (0.34, 0.084, 0.038),
    (0.38, 0.093, 0.039),
    (0.35, 0.087, 0.038),
    (0.40, 0.105, 0.041),
    (0.56, 0.041, 0.046),
    (0.77, 0.089, 0.096),
    (0.62, 0.168, 0.046),
    (0.538, 0.137, 0.044),
    (1.032, 0.263, 0.047),
    (0.28, 0.015, 0.020),
];

pub fn table1() -> Vec<CostRow> {
    structures()
        .into_iter()
        .map(|s| {
            let (access_ns, area_mm2, energy_nj) = model(s.kind, s.size_kb);
            CostRow { structure: s, access_ns, area_mm2, energy_nj }
        })
        .collect()
}

/// Total SRAM+CAM capacity of the compute / memory engine in KB
/// (paper: ~34KB compute, ~40KB memory — "similar to a small L1").
pub fn total_kb(engine: char) -> f64 {
    structures()
        .iter()
        .filter(|s| s.engine == engine || s.engine == 'B')
        .map(|s| s.size_kb)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_count_matches_paper() {
        assert_eq!(structures().len(), 10);
        assert_eq!(PAPER_TABLE1.len(), 10);
    }

    #[test]
    fn model_tracks_paper_values() {
        // The analytic fit must stay within 2x of every Table 1 value and
        // within 35% on average — it is an estimate of an estimate.
        let rows = table1();
        let mut rel_sum = 0.0;
        let mut n = 0.0;
        for (row, &(t, a, e)) in rows.iter().zip(PAPER_TABLE1.iter()) {
            for (got, want) in [(row.access_ns, t), (row.area_mm2, a), (row.energy_nj, e)] {
                let rel = (got - want).abs() / want;
                assert!(rel < 1.2, "{}: got {got}, paper {want}", row.structure.name);
                rel_sum += rel;
                n += 1.0;
            }
        }
        assert!(rel_sum / n < 0.35, "mean relative error {}", rel_sum / n);
    }

    #[test]
    fn totals_match_paper_claim() {
        let c = total_kb('C');
        let m = total_kb('M');
        assert!((30.0..40.0).contains(&c), "compute engine {c} KB");
        assert!((35.0..45.0).contains(&m), "memory engine {m} KB");
    }

    #[test]
    fn bigger_is_slower_and_larger() {
        let (t1, a1, _) = model(MemKind::Sram, 1.0);
        let (t2, a2, _) = model(MemKind::Sram, 32.0);
        assert!(t2 > t1 && a2 > a1);
    }

    #[test]
    fn cam_costs_more_energy_than_sram() {
        let (_, _, es) = model(MemKind::Sram, 2.0);
        let (_, _, ec) = model(MemKind::Cam, 2.0);
        assert!(ec > es);
    }
}
