//! The DaeMon engines (§3–§4): the paper's architectural contribution.
//!
//! `engine` is the compute-engine state machine (inflight buffers,
//! selection granularity unit, dirty unit); the memory-engine's queues and
//! bandwidth partitioning are realized by the partitioned link/bus
//! timelines in `net`/`mem`; `hw_cost` reproduces Table 1.

pub mod engine;
pub mod hw_cost;

pub use engine::{ComputeEngine, Decision, DirtyOutcome, PageArrival, PageState};
