//! The DaeMon engines (§3–§4): the paper's architectural contribution.
//!
//! `engine` is the compute-engine state machine (inflight buffers,
//! selection granularity unit, dirty unit); `mem_engine` is the
//! memory-side engine — per-tenant page/line queue controllers over each
//! memory module's DRAM bandwidth plus memory-side link-compression
//! statistics; `hw_cost` reproduces Table 1.

pub mod engine;
pub mod hw_cost;
pub mod mem_engine;

pub use engine::{
    ComputeEngine, Decision, DirtyOutcome, LineEvent, LineLifecycle, PageArrival, PageEvent,
    PageLifecycle,
};
pub use mem_engine::{EgressStats, MemoryEngine};
