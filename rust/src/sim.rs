//! Discrete-event plumbing: a time-ordered event queue and a k-way-merge
//! queue for the stepping drivers.
//!
//! The simulator is hybrid: bandwidth resources are *timelines*
//! (`net::BwChannel` reserves intervals analytically), while asynchronous
//! completions — page/line arrivals, dirty-ack timeouts — are events popped
//! from this queue as each core's clock advances past them.  The
//! [`MergeQueue`] drives "advance the earliest clock" loops — cores within
//! a [`crate::system::Machine`], tenants within a
//! [`crate::system::Cluster`] — in O(log k) per step instead of the seed
//! design's O(k) rescan per simulated access.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Clone, Debug)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: invert; ties broken by insertion order for determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, at: f64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Earliest pending timestamp.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the next event if it is due at or before `now`.
    pub fn pop_due(&mut self, now: f64) -> Option<(f64, T)> {
        if self.heap.peek().map(|s| s.at <= now).unwrap_or(false) {
            let s = self.heap.pop().unwrap();
            Some((s.at, s.payload))
        } else {
            None
        }
    }

    /// Pop unconditionally (drain at end of simulation).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|s| (s.at, s.payload))
    }
}

/// One (time, index) entry of a [`MergeQueue`].
#[derive(Clone, Copy, Debug)]
struct TimeIdx {
    at: f64,
    idx: usize,
}

impl PartialEq for TimeIdx {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.idx == other.idx
    }
}
impl Eq for TimeIdx {}

impl Ord for TimeIdx {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: invert; ties broken by the *lowest* index — exactly
        // the order a `for i in 0..k` scan with a strict `<` comparison
        // selects, which is the tie-break every driver loop historically
        // used (and the identity tests pin).
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.idx.cmp(&self.idx))
    }
}
impl PartialOrd for TimeIdx {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// K-way merge over per-source clocks: a min-queue of `(time, index)`
/// keyed by time, ties to the lowest index.  Each live source keeps
/// exactly one entry; the driver pops the minimum, advances that source,
/// and pushes its new clock back (or drops it when drained).
#[derive(Default)]
pub struct MergeQueue {
    heap: BinaryHeap<TimeIdx>,
}

impl MergeQueue {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new() }
    }

    pub fn with_capacity(k: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(k) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, at: f64, idx: usize) {
        self.heap.push(TimeIdx { at, idx });
    }

    /// Earliest `(index, time)` without removing it.
    pub fn peek(&self) -> Option<(usize, f64)> {
        self.heap.peek().map(|e| (e.idx, e.at))
    }

    /// Pop the earliest `(index, time)`.
    pub fn pop(&mut self) -> Option<(usize, f64)> {
        self.heap.pop().map(|e| (e.idx, e.at))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "c");
        q.push(1.0, "a");
        q.push(3.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (3.0, "b"));
        assert_eq!(q.pop().unwrap(), (5.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(10.0, "later");
        q.push(2.0, "soon");
        assert_eq!(q.pop_due(5.0).unwrap().1, "soon");
        assert!(q.pop_due(5.0).is_none());
        assert_eq!(q.peek_time(), Some(10.0));
        assert_eq!(q.pop_due(10.0).unwrap().1, "later");
    }

    #[test]
    fn merge_queue_orders_by_time_then_lowest_index() {
        let mut q = MergeQueue::new();
        q.push(5.0, 0);
        q.push(1.0, 2);
        q.push(1.0, 1);
        q.push(3.0, 3);
        assert_eq!(q.peek(), Some((1, 1.0)), "tie at t=1 goes to the lowest index");
        assert_eq!(q.pop(), Some((1, 1.0)));
        assert_eq!(q.pop(), Some((2, 1.0)));
        assert_eq!(q.pop(), Some((3, 3.0)));
        assert_eq!(q.pop(), Some((0, 5.0)));
        assert!(q.pop().is_none() && q.is_empty());
    }

    #[test]
    fn merge_queue_matches_linear_scan_property() {
        // The heap must select exactly what the historical `for i in 0..k`
        // strict-`<` scan selects, across random re-push sequences.
        crate::util::proptest::check(0x3E46E, 30, |rng| {
            let k = 2 + rng.index(6);
            let mut clocks: Vec<Option<f64>> =
                (0..k).map(|_| Some((rng.below(5)) as f64)).collect();
            let mut q = MergeQueue::with_capacity(k);
            for (i, c) in clocks.iter().enumerate() {
                q.push(c.unwrap(), i);
            }
            for _ in 0..200 {
                // Reference: first index with the strictly smallest clock.
                let mut best: Option<(usize, f64)> = None;
                for (i, c) in clocks.iter().enumerate() {
                    if let Some(t) = c {
                        if best.map(|(_, bt)| *t < bt).unwrap_or(true) {
                            best = Some((i, *t));
                        }
                    }
                }
                assert_eq!(q.peek(), best);
                let Some((i, t)) = q.pop() else { break };
                if rng.chance(0.1) {
                    clocks[i] = None; // source drained
                } else {
                    let nt = t + (rng.below(4)) as f64; // may stay equal
                    clocks[i] = Some(nt);
                    q.push(nt, i);
                }
            }
        });
    }

    #[test]
    fn time_order_property() {
        crate::util::proptest::check(0xE7E47, 30, |rng| {
            let mut q = EventQueue::new();
            for _ in 0..100 {
                q.push(rng.f64() * 1000.0, ());
            }
            let mut last = f64::NEG_INFINITY;
            while let Some((t, ())) = q.pop() {
                assert!(t >= last);
                last = t;
            }
        });
    }
}
