//! Discrete-event plumbing: a time-ordered event queue.
//!
//! The simulator is hybrid: bandwidth resources are *timelines*
//! (`net::BwChannel` reserves intervals analytically), while asynchronous
//! completions — page/line arrivals, dirty-ack timeouts — are events popped
//! from this queue as each core's clock advances past them.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Clone, Debug)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: invert; ties broken by insertion order for determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, at: f64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Earliest pending timestamp.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the next event if it is due at or before `now`.
    pub fn pop_due(&mut self, now: f64) -> Option<(f64, T)> {
        if self.heap.peek().map(|s| s.at <= now).unwrap_or(false) {
            let s = self.heap.pop().unwrap();
            Some((s.at, s.payload))
        } else {
            None
        }
    }

    /// Pop unconditionally (drain at end of simulation).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|s| (s.at, s.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "c");
        q.push(1.0, "a");
        q.push(3.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (3.0, "b"));
        assert_eq!(q.pop().unwrap(), (5.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(10.0, "later");
        q.push(2.0, "soon");
        assert_eq!(q.pop_due(5.0).unwrap().1, "soon");
        assert!(q.pop_due(5.0).is_none());
        assert_eq!(q.peek_time(), Some(10.0));
        assert_eq!(q.pop_due(10.0).unwrap().1, "later");
    }

    #[test]
    fn time_order_property() {
        crate::util::proptest::check(0xE7E47, 30, |rng| {
            let mut q = EventQueue::new();
            for _ in 0..100 {
                q.push(rng.f64() * 1000.0, ());
            }
            let mut last = f64::NEG_INFINITY;
            while let Some((t, ())) = q.pop() {
                assert!(t >= last);
                last = t;
            }
        });
    }
}
