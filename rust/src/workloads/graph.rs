//! Graph-processing workloads (Table 3: kc, tr, pr, bf, bc — Ligra [88]).
//!
//! A synthetic power-law directed graph is generated in CSR form; each
//! workload is the real algorithm running over the CSR arrays through the
//! trace recorder.  Locality structure is genuine: CSR edge scans are
//! sequential (within-page), while per-neighbor gathers on vertex-state
//! arrays are effectively random — exactly the mix that puts pr/kc/tr in
//! the paper's poor-locality class and bf/bc in the medium class (frontier
//! ordering preserves some structure).

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

/// CSR graph.
pub struct Graph {
    pub n: usize,
    pub offsets: Vec<u32>,
    pub edges: Vec<u32>,
}

impl Graph {
    /// Power-law graph: out-degrees ~ Zipf, endpoints Zipf-popular.
    pub fn powerlaw(n: usize, avg_deg: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let mut degrees: Vec<u32> = (0..n)
            .map(|_| {
                let d = 1 + rng.zipf(4 * avg_deg, 1.3);
                d as u32
            })
            .collect();
        // Normalize total edge count to ~n*avg_deg.
        let total: u64 = degrees.iter().map(|&d| d as u64).sum();
        let target = (n * avg_deg) as u64;
        if total > 0 {
            for d in degrees.iter_mut() {
                *d = (((*d as u64) * target / total) as u32).max(1);
            }
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        for &d in &degrees {
            offsets.push(offsets.last().unwrap() + d);
        }
        let m = *offsets.last().unwrap() as usize;
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            // Popular endpoints (preferential attachment flavour).
            edges.push(rng.zipf(n, 0.8) as u32);
        }
        Graph { n, offsets, edges }
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.edges[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }
}

/// Graph size per scale.
fn graph_params(scale: Scale) -> (usize, usize) {
    match scale {
        Scale::Test => (16_384, 12),
        // Scaled from the paper's 1M x 10M keeping the invariant that
        // matters: vertex-state arrays exceed the LLC (Table 2: 4MB), so
        // gathers cannot become cache-resident.  393216 x 8B = 3MB per
        // state array, two arrays + 12MB edges + offsets ≈ 20MB footprint.
        Scale::Paper => (393_216, 8),
    }
}

/// Addresses of the graph arrays inside a recorder.
struct GraphMem {
    offsets: u64,
    edges: u64,
    // Two vertex-state arrays (ranks/depths + scratch).
    state_a: u64,
    state_b: u64,
}

fn alloc_graph(r: &mut Recorder, g: &Graph) -> GraphMem {
    GraphMem {
        offsets: r.alloc(4 * (g.n as u64 + 1)),
        edges: r.alloc(4 * g.m() as u64),
        state_a: r.alloc(8 * g.n as u64),
        state_b: r.alloc(8 * g.n as u64),
    }
}

#[inline]
fn touch_offsets(r: &mut Recorder, mem: &GraphMem, v: usize) {
    r.load(mem.offsets + 4 * v as u64);
    r.load(mem.offsets + 4 * (v as u64 + 1));
}

/// ---------------- PageRank (pr) ----------------
pub struct PageRank {
    pub iterations: usize,
}

impl Default for PageRank {
    fn default() -> Self {
        Self { iterations: 2 }
    }
}

impl Workload for PageRank {
    fn name(&self) -> &'static str {
        "pr"
    }
    fn domain(&self) -> &'static str {
        "Graph Processing"
    }
    fn locality(&self) -> Locality {
        Locality::Low
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, deg) = graph_params(scale);
        let g = Graph::powerlaw(n, deg, seed);
        let mut r = Recorder::new();
        let mut mem = alloc_graph(&mut r, &g);
        for _ in 0..self.iterations {
            for v in 0..g.n {
                touch_offsets(&mut r, &mem, v);
                let mut acc = 0.0f64;
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    // Sequential edge scan + random gather on ranks.
                    r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                    r.load(mem.state_a + 8 * u as u64);
                    r.compute(4); // fma + degree divide
                    acc += u as f64;
                }
                let _ = acc;
                r.compute(6); // damping
                r.store(mem.state_b + 8 * v as u64);
            }
            // Rank arrays are pointer-swapped between iterations (the
            // standard implementation) — no copy traffic.
            std::mem::swap(&mut mem.state_a, &mut mem.state_b);
        }
        r.finish()
    }
}

/// ---------------- BFS (bf) ----------------
pub struct Bfs;

impl Workload for Bfs {
    fn name(&self) -> &'static str {
        "bf"
    }
    fn domain(&self) -> &'static str {
        "Graph Processing"
    }
    fn locality(&self) -> Locality {
        Locality::Medium
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, deg) = graph_params(scale);
        let g = Graph::powerlaw(n, deg, seed);
        let mut r = Recorder::new();
        let mem = alloc_graph(&mut r, &g);
        let mut depth = vec![u32::MAX; g.n];
        // Several sources to cover the graph (power-law graphs fragment).
        let mut rng = Rng::new(seed ^ 0xBF5);
        let sources: Vec<usize> = (0..8).map(|_| rng.index(g.n)).collect();
        for &s in &sources {
            if depth[s] != u32::MAX {
                continue;
            }
            depth[s] = 0;
            r.store(mem.state_a + 8 * s as u64);
            let mut frontier = vec![s];
            while !frontier.is_empty() {
                let mut next = Vec::new();
                for &v in &frontier {
                    touch_offsets(&mut r, &mem, v);
                    for (i, &u) in g.neighbors(v).iter().enumerate() {
                        r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                        r.load(mem.state_a + 8 * u as u64); // depth check
                        r.compute(2);
                        if depth[u as usize] == u32::MAX {
                            depth[u as usize] = depth[v] + 1;
                            r.store(mem.state_a + 8 * u as u64);
                            next.push(u as usize);
                        }
                    }
                }
                // Sorted frontier (the standard direction-optimizing BFS
                // layout trick): neighbouring vertices' state words share
                // pages, giving BFS its medium locality class.
                next.sort_unstable();
                next.dedup();
                frontier = next;
            }
        }
        r.finish()
    }
}

/// ---------------- K-Core decomposition (kc) ----------------
pub struct KCore;

impl Workload for KCore {
    fn name(&self) -> &'static str {
        "kc"
    }
    fn domain(&self) -> &'static str {
        "Graph Processing"
    }
    fn locality(&self) -> Locality {
        Locality::Low
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, deg) = graph_params(scale);
        let g = Graph::powerlaw(n, deg, seed);
        let mut r = Recorder::new();
        let mem = alloc_graph(&mut r, &g);
        // Worklist-based peeling (Ligra-style frontiers): vertices whose
        // degree drops below k enter the worklist; no full rescans.  The
        // neighbour-degree decrements are random gathers — kc's
        // poor-locality signature.
        let mut degree: Vec<u32> = (0..g.n)
            .map(|v| (g.offsets[v + 1] - g.offsets[v]))
            .collect();
        let mut removed = vec![false; g.n];
        let mut remaining = g.n;
        let mut k = 1u32;
        let max_k = 24;
        while remaining > 0 && k < max_k {
            // Seed the worklist for this k (one streamed degree scan).
            let mut work: Vec<usize> = Vec::new();
            for v in 0..g.n {
                if !removed[v] {
                    r.load(mem.state_a + 8 * v as u64);
                    r.compute(1);
                    if degree[v] < k {
                        work.push(v);
                    }
                }
            }
            while let Some(v) = work.pop() {
                if removed[v] {
                    continue;
                }
                removed[v] = true;
                remaining -= 1;
                r.store(mem.state_a + 8 * v as u64);
                touch_offsets(&mut r, &mem, v);
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                    // Random decrement on neighbour degree.
                    r.load(mem.state_a + 8 * u as u64);
                    r.store(mem.state_a + 8 * u as u64);
                    r.compute(2);
                    let u = u as usize;
                    degree[u] = degree[u].saturating_sub(1);
                    if !removed[u] && degree[u] < k {
                        work.push(u);
                    }
                }
            }
            k += 1;
        }
        r.finish()
    }
}

/// ---------------- Triangle Counting (tr) ----------------
pub struct Triangles;

impl Workload for Triangles {
    fn name(&self) -> &'static str {
        "tr"
    }
    fn domain(&self) -> &'static str {
        "Graph Processing"
    }
    fn locality(&self) -> Locality {
        Locality::Low
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, deg) = graph_params(scale);
        let g = Graph::powerlaw(n, deg, seed);
        let mut r = Recorder::new();
        let mem = alloc_graph(&mut r, &g);
        // For each edge (v,u): intersect adj(v) with adj(u) — the u-list
        // walk jumps to a random CSR region per edge: poor locality.
        let mut count = 0u64;
        let stride = if matches!(scale, Scale::Test) { 1 } else { 4 };
        for v in (0..g.n).step_by(stride) {
            touch_offsets(&mut r, &mem, v);
            let nv = g.neighbors(v);
            for (i, &u) in nv.iter().enumerate().take(8) {
                r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                let u = u as usize;
                touch_offsets(&mut r, &mem, u);
                let nu = g.neighbors(u);
                // Merge-intersect first segments of both lists.
                let (mut a, mut b) = (0usize, 0usize);
                while a < nv.len().min(16) && b < nu.len().min(16) {
                    r.load(mem.edges + 4 * (g.offsets[v] as u64 + a as u64));
                    r.load(mem.edges + 4 * (g.offsets[u] as u64 + b as u64));
                    r.compute(3);
                    match nv[a].cmp(&nu[b]) {
                        std::cmp::Ordering::Less => a += 1,
                        std::cmp::Ordering::Greater => b += 1,
                        std::cmp::Ordering::Equal => {
                            count += 1;
                            a += 1;
                            b += 1;
                        }
                    }
                }
            }
        }
        let _ = count;
        r.finish()
    }
}

/// ---------------- Betweenness Centrality (bc) ----------------
pub struct Betweenness;

impl Workload for Betweenness {
    fn name(&self) -> &'static str {
        "bc"
    }
    fn domain(&self) -> &'static str {
        "Graph Processing"
    }
    fn locality(&self) -> Locality {
        Locality::Medium
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, deg) = graph_params(scale);
        let g = Graph::powerlaw(n, deg, seed);
        let mut r = Recorder::new();
        let mem = alloc_graph(&mut r, &g);
        let mut rng = Rng::new(seed ^ 0xBC);
        // Brandes from a few sampled sources: forward BFS + backward
        // dependency accumulation (stream over visit order).
        let sources = if matches!(scale, Scale::Test) { 2 } else { 4 };
        for _ in 0..sources {
            let s = rng.index(g.n);
            let mut depth = vec![u32::MAX; g.n];
            let mut order: Vec<usize> = Vec::new();
            depth[s] = 0;
            let mut frontier = vec![s];
            r.store(mem.state_a + 8 * s as u64);
            while !frontier.is_empty() {
                let mut next = Vec::new();
                for &v in &frontier {
                    order.push(v);
                    touch_offsets(&mut r, &mem, v);
                    for (i, &u) in g.neighbors(v).iter().enumerate() {
                        r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                        r.load(mem.state_a + 8 * u as u64); // sigma read
                        r.compute(3);
                        if depth[u as usize] == u32::MAX {
                            depth[u as usize] = depth[v] + 1;
                            r.store(mem.state_a + 8 * u as u64);
                            next.push(u as usize);
                        }
                    }
                }
                next.sort_unstable();
                next.dedup();
                frontier = next;
            }
            // Backward pass in reverse visit order (streaming-ish).
            for &v in order.iter().rev() {
                r.load(mem.state_b + 8 * v as u64);
                touch_offsets(&mut r, &mem, v);
                for (i, &u) in g.neighbors(v).iter().enumerate().take(8) {
                    r.load(mem.edges + 4 * (g.offsets[v] as u64 + i as u64));
                    r.load(mem.state_b + 8 * u as u64);
                    r.compute(4); // dependency update
                }
                r.store(mem.state_b + 8 * v as u64);
            }
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn powerlaw_graph_is_wellformed() {
        let g = Graph::powerlaw(1000, 8, 1);
        assert_eq!(g.offsets.len(), 1001);
        assert_eq!(*g.offsets.last().unwrap() as usize, g.m());
        assert!(g.m() >= 1000, "m = {}", g.m());
        for &e in &g.edges {
            assert!((e as usize) < g.n);
        }
        // Deterministic.
        let g2 = Graph::powerlaw(1000, 8, 1);
        assert_eq!(g.edges, g2.edges);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        let g = Graph::powerlaw(5000, 10, 2);
        let mut degs: Vec<u32> = (0..g.n).map(|v| g.offsets[v + 1] - g.offsets[v]).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let top: u64 = degs[..50].iter().map(|&d| d as u64).sum();
        let total: u64 = degs.iter().map(|&d| d as u64).sum();
        assert!(top as f64 / total as f64 > 0.03, "not skewed enough");
    }

    #[test]
    fn traces_are_deterministic() {
        let t1 = PageRank::default().generate(3, Scale::Test);
        let t2 = PageRank::default().generate(3, Scale::Test);
        assert_eq!(t1.accesses.len(), t2.accesses.len());
        assert_eq!(t1.accesses[..100], t2.accesses[..100]);
    }

    #[test]
    fn all_graph_workloads_produce_nonempty_traces() {
        let workloads: Vec<Box<dyn Workload>> = vec![
            Box::new(PageRank::default()),
            Box::new(Bfs),
            Box::new(KCore),
            Box::new(Triangles),
            Box::new(Betweenness),
        ];
        for w in &workloads {
            let t = w.generate(5, Scale::Test);
            assert!(t.accesses.len() > 10_000, "{} too small: {}", w.name(), t.accesses.len());
            assert!(t.footprint_pages > 50, "{} footprint {}", w.name(), t.footprint_pages);
        }
    }

    #[test]
    fn pagerank_has_poor_page_locality() {
        let t = PageRank::default().generate(13, Scale::Test);
        let s = locality_score(&t);
        // Gathers dominate: few lines used per page residency.
        assert!(s < 13.0, "pr locality score {s} too high");
    }

    #[test]
    fn triangle_counting_is_the_least_local() {
        let tr = locality_score(&Triangles.generate(13, Scale::Test));
        let bf = locality_score(&Bfs.generate(13, Scale::Test));
        assert!(tr < bf, "tr {tr} vs bf {bf}");
    }

    #[test]
    fn workload_metadata() {
        assert_eq!(PageRank::default().name(), "pr");
        assert_eq!(PageRank::default().locality(), Locality::Low);
        assert_eq!(Bfs.locality(), Locality::Medium);
        assert_eq!(KCore.locality(), Locality::Low);
        assert_eq!(Triangles.locality(), Locality::Low);
        assert_eq!(Betweenness.locality(), Locality::Medium);
    }
}
