//! Request-serving scenario layer (ROADMAP item 2): deterministic
//! open-loop request arrivals — Poisson with piecewise-constant rate
//! phases (steady / bursty / diurnal, mirroring the
//! [`NetSchedule`](crate::net::NetSchedule) machinery on the network
//! side) — where each request fans out into a cache/graph/dnn access
//! burst cut from the corresponding base workload trace.  Everything is
//! a pure function of the [`ServiceSpec`]: arrivals, class mix, burst
//! windows and retry jitter all come from the zero-dep
//! [`SplitMix`](crate::util::rng::SplitMix) stream, so replays are
//! byte-identical and independent of the sim PRNG.

use crate::config::{ArrivalPattern, ServiceSpec};
use crate::util::rng::SplitMix;
use crate::workloads::{Access, Trace};

/// What a request asks for, mapped onto the existing workload suite: a
/// key-value / embedding lookup (`sl`), a graph traversal slice (`pr`),
/// or a DNN inference slice (`dr`).  Each class's addresses are offset
/// into a disjoint region so one server machine serves all three
/// without page collisions (offsets stay far below the per-core tag
/// shift at bit 40).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    Cache,
    Graph,
    Dnn,
}

/// The fixed class roster, in deterministic draw order.
pub const CLASSES: [RequestClass; 3] =
    [RequestClass::Cache, RequestClass::Graph, RequestClass::Dnn];

impl RequestClass {
    /// Table 3 short name of the base workload this class's bursts are
    /// cut from.
    pub fn base_workload(self) -> &'static str {
        match self {
            RequestClass::Cache => "sl",
            RequestClass::Graph => "pr",
            RequestClass::Dnn => "dr",
        }
    }

    /// Per-class address-region offset OR'd onto every burst address.
    pub fn address_offset(self) -> u64 {
        (self as u64) << 34
    }

    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Cache => "cache",
            RequestClass::Graph => "graph",
            RequestClass::Dnn => "dnn",
        }
    }
}

/// One arrival-rate phase: from `from_cycle` (until the next phase) the
/// base Poisson rate is multiplied by `rate_scale`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArrivalPhase {
    pub from_cycle: f64,
    pub rate_scale: f64,
}

/// Piecewise-constant arrival-rate schedule, the request-side analogue
/// of [`NetSchedule`](crate::net::NetSchedule): phases sorted by start
/// cycle, nominal rate (scale 1.0) before the first phase.
#[derive(Clone, Debug)]
pub struct ArrivalSchedule {
    phases: Vec<ArrivalPhase>,
}

impl ArrivalSchedule {
    pub fn new(phases: Vec<ArrivalPhase>) -> ArrivalSchedule {
        assert!(
            phases.windows(2).all(|w| w[0].from_cycle <= w[1].from_cycle),
            "arrival phases must be sorted by start cycle"
        );
        for p in &phases {
            assert!(
                p.rate_scale.is_finite() && p.rate_scale > 0.0,
                "arrival rate scale must be positive and finite, got {}",
                p.rate_scale
            );
        }
        ArrivalSchedule { phases }
    }

    /// Constant nominal rate.
    pub fn steady() -> ArrivalSchedule {
        ArrivalSchedule::new(vec![ArrivalPhase { from_cycle: 0.0, rate_scale: 1.0 }])
    }

    /// Alternating high/low phases of `period_cycles` each (high first),
    /// until `horizon_cycles`; nominal after.
    pub fn square_wave(
        period_cycles: f64,
        hi: f64,
        lo: f64,
        horizon_cycles: f64,
    ) -> ArrivalSchedule {
        assert!(period_cycles > 0.0 && horizon_cycles > 0.0);
        let mut phases = Vec::new();
        let mut t = 0.0;
        let mut high = true;
        while t < horizon_cycles {
            phases.push(ArrivalPhase {
                from_cycle: t,
                rate_scale: if high { hi } else { lo },
            });
            high = !high;
            t += period_cycles;
        }
        phases.push(ArrivalPhase { from_cycle: horizon_cycles, rate_scale: 1.0 });
        ArrivalSchedule::new(phases)
    }

    /// Repeat the `scales` staircase in steps of `step_cycles` until
    /// `horizon_cycles`; nominal after.
    pub fn staircase(
        step_cycles: f64,
        scales: &[f64],
        horizon_cycles: f64,
    ) -> ArrivalSchedule {
        assert!(step_cycles > 0.0 && !scales.is_empty());
        let mut phases = Vec::new();
        let mut t = 0.0;
        let mut i = 0;
        while t < horizon_cycles {
            phases.push(ArrivalPhase { from_cycle: t, rate_scale: scales[i % scales.len()] });
            i += 1;
            t += step_cycles;
        }
        phases.push(ArrivalPhase { from_cycle: horizon_cycles, rate_scale: 1.0 });
        ArrivalSchedule::new(phases)
    }

    /// Materialize a [`ArrivalPattern`] over the expected run horizon.
    pub fn from_pattern(pattern: ArrivalPattern, horizon_cycles: f64) -> ArrivalSchedule {
        match pattern {
            ArrivalPattern::Steady => ArrivalSchedule::steady(),
            // Three bursts at 1.6x the nominal rate separated by 0.4x
            // lulls — mean rate stays ~nominal, pressure concentrates.
            ArrivalPattern::Bursty => {
                ArrivalSchedule::square_wave(horizon_cycles / 6.0, 1.6, 0.4, horizon_cycles)
            }
            // Eight-step day/night staircase: trough, ramp, peak, ramp.
            ArrivalPattern::Diurnal => ArrivalSchedule::staircase(
                horizon_cycles / 8.0,
                &[0.4, 0.7, 1.0, 1.5, 1.9, 1.5, 1.0, 0.7],
                horizon_cycles,
            ),
        }
    }

    /// Rate multiplier in effect at cycle `t` (1.0 before any phase).
    pub fn rate_scale_at(&self, t: f64) -> f64 {
        let idx = self.phases.partition_point(|p| p.from_cycle <= t);
        if idx == 0 { 1.0 } else { self.phases[idx - 1].rate_scale }
    }
}

/// One generated request: arrival cycle plus the class whose trace its
/// burst is cut from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: usize,
    pub at: f64,
    pub class: RequestClass,
}

/// Expected arrival horizon for a spec — what the rate schedule spans.
pub fn horizon_cycles(spec: &ServiceSpec) -> f64 {
    (spec.requests as f64 * spec.base_gap_cycles / spec.load).max(1.0)
}

/// Generate the full open-loop arrival sequence: exponential gaps with
/// the mean scaled by the phase in effect at the previous arrival, and
/// a uniform class draw per request — both from forked `SplitMix`
/// streams, so arrivals and class mix never perturb each other.
pub fn gen_requests(spec: &ServiceSpec) -> Vec<Request> {
    assert!(spec.base_gap_cycles > 0.0 && spec.load > 0.0);
    let sched = ArrivalSchedule::from_pattern(spec.pattern, horizon_cycles(spec));
    let root = SplitMix::new(spec.seed);
    let mut gaps = root.split(1);
    let mut classes = root.split(2);
    let mut t = 0.0;
    (0..spec.requests)
        .map(|id| {
            let mean = spec.base_gap_cycles / (spec.load * sched.rate_scale_at(t));
            t += gaps.exp(mean);
            Request { id, at: t, class: CLASSES[classes.index(CLASSES.len())] }
        })
        .collect()
}

/// A class's full serving trace: the base workload trace with the class
/// address offset applied (footprint is unchanged — the offset shifts
/// the region, it does not add pages).
pub fn class_trace(base: &Trace, class: RequestClass) -> Trace {
    Trace {
        accesses: base
            .accesses
            .iter()
            .map(|a| Access {
                addr: a.addr | class.address_offset(),
                write: a.write,
                gap: a.gap,
            })
            .collect(),
        footprint_pages: base.footprint_pages,
    }
}

/// One request's access burst: `burst` accesses of the class trace
/// starting at `start`, wrapping at the end — so every window is the
/// same length regardless of where it lands.
pub fn burst_trace(class_tr: &Trace, start: usize, burst: usize) -> Trace {
    let n = class_tr.accesses.len();
    assert!(n > 0 && burst > 0);
    Trace {
        accesses: (0..burst).map(|i| class_tr.accesses[(start + i) % n]).collect(),
        footprint_pages: class_tr.footprint_pages,
    }
}

/// Retry backoff for 0-based retry `attempt`: deterministic exponential
/// part `min(base * 2^attempt, cap)` plus jitter drawn from `rng` in
/// `[0, jitter_frac)` of the capped delay.  Pure in `(args, rng state)`
/// — the property tests replay it bit-for-bit.
pub fn backoff_delay(
    base: f64,
    cap: f64,
    jitter_frac: f64,
    attempt: u32,
    rng: &mut SplitMix,
) -> f64 {
    let det = (base * 2f64.powi(attempt.min(60) as i32)).min(cap);
    det + det * jitter_frac * rng.f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pattern: ArrivalPattern) -> ServiceSpec {
        ServiceSpec::naive(pattern, 400, 100, 1000.0, 1.0, 50_000.0)
    }

    #[test]
    fn backoff_schedule_is_monotone_bounded_with_jitter_in_range() {
        // Properties over random (base, cap, jitter_frac, rng seed):
        // bit-exact replay, a monotone non-decreasing deterministic part
        // clamped at the cap, and jitter confined to
        // [0, jitter_frac) of the capped delay.
        crate::util::proptest::check(0xDAE0_52, 200, |pt| {
            let base = 1.0 + pt.f64() * 1e5;
            let cap = base * (1.0 + pt.f64() * 64.0);
            let jitter = pt.f64();
            let seed = pt.next_u64();
            let (mut ra, mut rb) = (SplitMix::new(seed), SplitMix::new(seed));
            let mut prev_det = 0.0;
            for attempt in 0..32u32 {
                let d = backoff_delay(base, cap, jitter, attempt, &mut ra);
                let d2 = backoff_delay(base, cap, jitter, attempt, &mut rb);
                assert_eq!(d.to_bits(), d2.to_bits(), "backoff replay diverged");
                assert!(d.is_finite(), "delay must stay finite at high attempt counts");
                let det = (base * 2f64.powi(attempt.min(60) as i32)).min(cap);
                assert!(det >= prev_det, "deterministic part must be monotone");
                prev_det = det;
                let j = d - det;
                assert!(
                    j >= 0.0 && j <= det * jitter * (1.0 + 1e-9) + 1e-9,
                    "jitter {j} outside [0, {jitter} x {det})"
                );
                assert!(d <= cap * (1.0 + jitter) * (1.0 + 1e-9), "delay above cap band");
            }
        });
    }

    #[test]
    fn arrivals_are_strictly_increasing_and_deterministic() {
        for pattern in
            [ArrivalPattern::Steady, ArrivalPattern::Bursty, ArrivalPattern::Diurnal]
        {
            let s = spec(pattern);
            let a = gen_requests(&s);
            let b = gen_requests(&s);
            assert_eq!(a, b, "{pattern:?}: replay diverged");
            assert_eq!(a.len(), s.requests);
            for w in a.windows(2) {
                assert!(w[0].at < w[1].at, "{pattern:?}: arrivals not increasing");
            }
            assert!(a[0].at > 0.0);
        }
    }

    #[test]
    fn class_mix_covers_all_classes() {
        let a = gen_requests(&spec(ArrivalPattern::Steady));
        for c in CLASSES {
            assert!(
                a.iter().filter(|r| r.class == c).count() > 50,
                "{c:?} underrepresented"
            );
        }
    }

    #[test]
    fn bursty_concentrates_arrivals_into_high_phases() {
        let s = spec(ArrivalPattern::Bursty);
        let h = horizon_cycles(&s);
        let sched = ArrivalSchedule::from_pattern(ArrivalPattern::Bursty, h);
        let a = gen_requests(&s);
        let in_high =
            a.iter().filter(|r| r.at < h && sched.rate_scale_at(r.at) > 1.0).count();
        let in_run = a.iter().filter(|r| r.at < h).count();
        // High phases cover half the horizon but carry 1.6/(1.6+0.4) =
        // 80% of the rate mass; leave slack for sampling noise.
        assert!(
            in_high as f64 > 0.65 * in_run as f64,
            "only {in_high}/{in_run} arrivals in high phases"
        );
    }

    #[test]
    fn rate_schedule_lookup_matches_phases() {
        let s = ArrivalSchedule::square_wave(100.0, 2.0, 0.5, 250.0);
        assert_eq!(s.rate_scale_at(0.0), 2.0);
        assert_eq!(s.rate_scale_at(99.9), 2.0);
        assert_eq!(s.rate_scale_at(100.0), 0.5);
        assert_eq!(s.rate_scale_at(200.0), 2.0);
        assert_eq!(s.rate_scale_at(250.0), 1.0, "nominal after horizon");
        assert_eq!(s.rate_scale_at(-1.0), 1.0, "nominal before first phase");
    }

    #[test]
    fn class_traces_are_disjoint_regions() {
        let base = Trace {
            accesses: vec![Access { addr: 0x1000_0000, write: false, gap: 1 }],
            footprint_pages: 1,
        };
        let mut pages: Vec<u64> = CLASSES
            .iter()
            .map(|&c| class_trace(&base, c).accesses[0].addr >> 12)
            .collect();
        pages.dedup();
        assert_eq!(pages.len(), CLASSES.len(), "class regions collide");
        // Offsets stay below the per-core tag shift (bit 40).
        for c in CLASSES {
            assert!(c.address_offset() < 1 << 40);
        }
    }

    #[test]
    fn burst_windows_wrap_and_have_fixed_length() {
        let base = Trace {
            accesses: (0..10)
                .map(|i| Access { addr: 0x1000_0000 + i * 64, write: false, gap: 1 })
                .collect(),
            footprint_pages: 1,
        };
        let b = burst_trace(&base, 8, 5);
        assert_eq!(b.accesses.len(), 5);
        assert_eq!(b.accesses[0].addr, base.accesses[8].addr);
        assert_eq!(b.accesses[2].addr, base.accesses[0].addr, "window wraps");
    }

    #[test]
    fn backoff_is_monotone_then_capped() {
        let mut rng = SplitMix::new(3);
        let mut prev = 0.0;
        for k in 0..20 {
            let d = backoff_delay(100.0, 1600.0, 0.0, k, &mut rng);
            assert!(d >= prev, "deterministic backoff must be monotone");
            assert!(d <= 1600.0, "backoff exceeded cap: {d}");
            prev = d;
        }
        assert_eq!(prev, 1600.0);
    }
}
