//! Workload suite (Table 3): 13 capacity-intensive workloads from graph
//! processing, bioinformatics, data analytics, linear algebra, machine
//! learning, and HPC — each the real algorithm, scaled down, instrumented
//! to emit a virtual-address trace.

pub mod cache;
pub mod dnn;
pub mod graph;
pub mod hpcg;
pub mod nw;
pub mod pf;
pub mod service;
pub mod sls;
pub mod spmv;
pub mod trace;
pub mod ts;

pub use trace::{Access, Locality, Recorder, Scale, Trace, Workload};

/// The paper's workload order (Table 3 / Fig. 8).
pub const ALL: [&str; 13] = [
    "kc", "tr", "pr", "nw", "bf", "bc", "ts", "sp", "sl", "hp", "pf", "dr", "rs",
];

/// Representative subset used by the paper's space-limited plots
/// (Figs. 9–12): one per locality/compressibility class.
pub const SUBSET: [&str; 8] = ["pr", "nw", "bf", "ts", "sp", "hp", "dr", "rs"];

/// Instantiate a workload by its Table 3 short name.
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    Some(match name {
        "kc" => Box::new(graph::KCore),
        "tr" => Box::new(graph::Triangles),
        "pr" => Box::new(graph::PageRank::default()),
        "nw" => Box::new(nw::NeedlemanWunsch),
        "bf" => Box::new(graph::Bfs),
        "bc" => Box::new(graph::Betweenness),
        "ts" => Box::new(ts::Timeseries),
        "sp" => Box::new(spmv::Spmv),
        "sl" => Box::new(sls::SparseLengthsSum),
        "hp" => Box::new(hpcg::Hpcg),
        "pf" => Box::new(pf::ParticleFilter),
        "dr" => Box::new(dnn::Darknet19),
        "rs" => Box::new(dnn::Resnet50),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve() {
        for name in ALL {
            let w = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(w.name(), name);
        }
        assert!(by_name("zz").is_none());
    }

    #[test]
    fn subset_is_within_all() {
        for name in SUBSET {
            assert!(ALL.contains(&name));
        }
    }

    #[test]
    fn locality_classes_match_paper() {
        use Locality::*;
        let expect = [
            ("kc", Low), ("tr", Low), ("pr", Low), ("nw", Low),
            ("bf", Medium), ("bc", Medium), ("ts", Medium),
            ("sp", High), ("sl", High), ("hp", High), ("pf", High),
            ("dr", High), ("rs", High),
        ];
        for (name, loc) in expect {
            assert_eq!(by_name(name).unwrap().locality(), loc, "{name}");
        }
    }

    #[test]
    fn every_workload_generates_at_test_scale() {
        for name in ALL {
            let w = by_name(name).unwrap();
            let t = w.generate(11, Scale::Test);
            assert!(
                t.accesses.len() > 5_000,
                "{name}: only {} accesses",
                t.accesses.len()
            );
            assert!(t.footprint_pages > 16, "{name}: {} pages", t.footprint_pages);
            // Addresses must be above the heap base and line-addressable.
            for a in t.accesses.iter().take(1000) {
                assert!(a.addr >= 0x1000_0000);
            }
        }
    }

    #[test]
    fn measured_locality_ordering_matches_classes() {
        use crate::workloads::trace::locality_score;
        let mut by_class: [Vec<f64>; 3] = [vec![], vec![], vec![]];
        for name in ALL {
            let w = by_name(name).unwrap();
            let t = w.generate(13, Scale::Test);
            let pl = locality_score(&t);
            let idx = match w.locality() {
                Locality::Low => 0,
                Locality::Medium => 1,
                Locality::High => 2,
            };
            by_class[idx].push(pl);
        }
        let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let (lo, med, hi) = (avg(&by_class[0]), avg(&by_class[1]), avg(&by_class[2]));
        assert!(lo < med && med < hi, "locality ordering broken: {lo} {med} {hi}");
    }
}
