//! Memory-trace recording: the instrumentation layer under every workload.
//!
//! Each workload (Table 3) is the real algorithm, scaled down, running over
//! *virtual arrays* allocated from a recorder.  Loads/stores append to the
//! trace; `compute(n)` records `n` non-memory instructions as a gap on the
//! next access (the core model converts gaps to cycles via the base CPI).
//!
//! Traces are the simulator's input: the locality structure is genuine —
//! it comes from the algorithm's actual access order — while page
//! *contents* are synthesized per workload profile (see `compress::synth`).

use crate::compress::synth::Profile;
use crate::util::hash::{FxHashMap, FxHashSet};

/// One memory reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub addr: u64,
    pub write: bool,
    /// Non-memory instructions executed since the previous access.
    pub gap: u32,
}

/// A recorded workload execution.
#[derive(Clone, Debug)]
pub struct Trace {
    pub accesses: Vec<Access>,
    /// Distinct 4KB pages touched.
    pub footprint_pages: usize,
}

impl Trace {
    pub fn footprint_bytes(&self) -> u64 {
        self.footprint_pages as u64 * 4096
    }

    /// Total instructions (memory + gap).
    pub fn instructions(&self) -> u64 {
        self.accesses.len() as u64
            + self.accesses.iter().map(|a| a.gap as u64).sum::<u64>()
    }

    /// Cap the trace at `max_accesses` (used by the experiment harness to
    /// bound simulation time; the footprint is recomputed over the kept
    /// prefix so local-memory sizing stays consistent).
    pub fn truncated(mut self, max_accesses: usize) -> Trace {
        if self.accesses.len() > max_accesses {
            self.accesses.truncate(max_accesses);
            let pages: FxHashSet<u64> =
                self.accesses.iter().map(|a| a.addr >> 12).collect();
            self.footprint_pages = pages.len();
        }
        self
    }
}

/// Base of the simulated heap — nonzero so address 0 stays invalid.
const HEAP_BASE: u64 = 0x1000_0000;

pub struct Recorder {
    accesses: Vec<Access>,
    next_addr: u64,
    pending_gap: u32,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Self { accesses: Vec::new(), next_addr: HEAP_BASE, pending_gap: 0 }
    }

    /// Allocate `bytes` page-aligned; returns the base address.
    pub fn alloc(&mut self, bytes: u64) -> u64 {
        let base = self.next_addr;
        self.next_addr += bytes.div_ceil(4096) * 4096;
        base
    }

    #[inline]
    pub fn load(&mut self, addr: u64) {
        self.accesses.push(Access { addr, write: false, gap: self.pending_gap });
        self.pending_gap = 0;
    }

    #[inline]
    pub fn store(&mut self, addr: u64) {
        self.accesses.push(Access { addr, write: true, gap: self.pending_gap });
        self.pending_gap = 0;
    }

    /// Record `n` non-memory instructions.
    #[inline]
    pub fn compute(&mut self, n: u32) {
        self.pending_gap = self.pending_gap.saturating_add(n);
    }

    pub fn len(&self) -> usize {
        self.accesses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accesses.is_empty()
    }

    pub fn finish(self) -> Trace {
        let pages: FxHashSet<u64> = self.accesses.iter().map(|a| a.addr >> 12).collect();
        Trace { accesses: self.accesses, footprint_pages: pages.len() }
    }
}

/// Spatial-locality class the paper groups workloads into (§6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    Low,
    Medium,
    High,
}

/// Input scale: `Test` keeps unit tests fast; `Paper` is the experiment
/// size (working sets tens of MB, ~1M+ accesses).  `Hash` so the scale can
/// be part of a [`crate::workloads::cache::TraceCache`] key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scale {
    Test,
    Paper,
}

/// A workload from Table 3.
pub trait Workload {
    fn name(&self) -> &'static str;
    fn domain(&self) -> &'static str;
    /// Paper's spatial-locality class (validated by tests).
    fn locality(&self) -> Locality;
    /// Page-content compressibility profile.
    fn profile(&self) -> Profile;
    fn generate(&self, seed: u64, scale: Scale) -> Trace;
}

/// Measure page-level spatial locality of a trace: mean fraction of
/// consecutive-access pairs that stay within the same page.  NOTE: this is
/// a *stream-sensitive* metric — workloads interleaving several sequential
/// streams look "low" here even though each stream is sequential; prefer
/// [`window_hit_rate`] for classifying workloads the way page migration
/// sees them.
pub fn page_locality(trace: &Trace) -> f64 {
    if trace.accesses.len() < 2 {
        return 1.0;
    }
    let mut same = 0u64;
    for w in trace.accesses.windows(2) {
        if w[0].addr >> 12 == w[1].addr >> 12 {
            same += 1;
        }
    }
    same as f64 / (trace.accesses.len() - 1) as f64
}

/// Locality as page migration experiences it: hit rate of an LRU page
/// cache holding `window_pages` pages.  High-spatial-locality workloads
/// reuse migrated pages heavily even at small windows; poor-locality
/// workloads touch a line or two per page and move on.
pub fn window_hit_rate(trace: &Trace, window_pages: usize) -> f64 {
    use std::collections::VecDeque;
    let mut stamp: FxHashMap<u64, u64> = FxHashMap::default();
    let mut queue: VecDeque<(u64, u64)> = VecDeque::new();
    let mut tick = 0u64;
    let mut hits = 0u64;
    for a in &trace.accesses {
        tick += 1;
        let page = a.addr >> 12;
        if stamp.contains_key(&page) {
            hits += 1;
        }
        stamp.insert(page, tick);
        queue.push_back((tick, page));
        while stamp.len() > window_pages {
            let (t, p) = queue.pop_front().unwrap();
            if stamp.get(&p) == Some(&t) {
                stamp.remove(&p);
            }
        }
    }
    if trace.accesses.is_empty() {
        0.0
    } else {
        hits as f64 / trace.accesses.len() as f64
    }
}

/// Distinct 64B lines touched per page *residency*: simulate an LRU page
/// cache of `window_pages`; when a page is evicted (or the trace ends),
/// record how many distinct lines were touched while it was resident.
/// This is the quantity page migration monetizes — a migrated page that
/// serves 40 line accesses paid off; one that serves 1 did not — and it is
/// robust to stream interleaving (unlike [`page_locality`]).
pub fn lines_per_residency(trace: &Trace, window_pages: usize) -> f64 {
    struct Res {
        lines: FxHashSet<u64>,
        stamp: u64,
    }
    let mut resident: FxHashMap<u64, Res> = FxHashMap::default();
    let mut tick = 0u64;
    let mut episodes = 0u64;
    let mut total_lines = 0u64;
    for a in &trace.accesses {
        tick += 1;
        let page = a.addr >> 12;
        let line = a.addr >> 6;
        match resident.get_mut(&page) {
            Some(r) => {
                r.lines.insert(line);
                r.stamp = tick;
            }
            None => {
                if resident.len() >= window_pages {
                    // Evict LRU (linear scan is fine at test sizes).
                    // Stamps are unique, so the min is a total order and
                    // map iteration order cannot change the victim.
                    let victim = *resident
                        .iter()
                        .min_by_key(|(_, r)| r.stamp)
                        .map(|(p, _)| p)
                        .unwrap();
                    let r = resident.remove(&victim).unwrap();
                    episodes += 1;
                    total_lines += r.lines.len() as u64;
                }
                let mut lines = FxHashSet::default();
                lines.insert(line);
                resident.insert(page, Res { lines, stamp: tick });
            }
        }
    }
    for (_, r) in resident {
        episodes += 1;
        total_lines += r.lines.len() as u64;
    }
    if episodes == 0 {
        0.0
    } else {
        total_lines as f64 / episodes as f64
    }
}

/// Standard locality score used by the workload-classification tests:
/// lines used per residency with a window of 5% of the footprint
/// (min 32 pages) — i.e. local memory far smaller than the working set,
/// the regime the paper evaluates.
pub fn locality_score(trace: &Trace) -> f64 {
    let w = (trace.footprint_pages / 20).max(32);
    lines_per_residency(trace, w)
}

/// Mean distinct 64B lines referenced per page *episode* (consecutive
/// run of accesses to one page) — a second locality measure, closer to
/// what page migration exploits.
pub fn lines_per_episode(trace: &Trace) -> f64 {
    if trace.accesses.is_empty() {
        return 0.0;
    }
    let mut episodes = 0u64;
    let mut total_lines = 0u64;
    let mut cur_page = u64::MAX;
    let mut lines: FxHashSet<u64> = FxHashSet::default();
    for a in &trace.accesses {
        let p = a.addr >> 12;
        if p != cur_page {
            if cur_page != u64::MAX {
                episodes += 1;
                total_lines += lines.len() as u64;
            }
            cur_page = p;
            lines.clear();
        }
        lines.insert(a.addr >> 6);
    }
    episodes += 1;
    total_lines += lines.len() as u64;
    total_lines as f64 / episodes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn recorder_allocates_page_aligned() {
        let mut r = Recorder::new();
        let a = r.alloc(100);
        let b = r.alloc(5000);
        let c = r.alloc(1);
        assert_eq!(a % 4096, 0);
        assert_eq!(b, a + 4096);
        assert_eq!(c, b + 8192);
    }

    #[test]
    fn gaps_attach_to_next_access() {
        let mut r = Recorder::new();
        let a = r.alloc(4096);
        r.compute(5);
        r.compute(3);
        r.load(a);
        r.store(a + 8);
        let t = r.finish();
        assert_eq!(t.accesses[0].gap, 8);
        assert_eq!(t.accesses[1].gap, 0);
        assert_eq!(t.instructions(), 2 + 8);
    }

    #[test]
    fn footprint_counts_distinct_pages() {
        let mut r = Recorder::new();
        let a = r.alloc(3 * 4096);
        r.load(a);
        r.load(a + 4096);
        r.load(a + 100); // same page as first
        let t = r.finish();
        assert_eq!(t.footprint_pages, 2);
        assert_eq!(t.footprint_bytes(), 8192);
    }

    #[test]
    fn locality_metrics_extremes() {
        // Sequential: high page locality.
        let mut r = Recorder::new();
        let a = r.alloc(1 << 20);
        for i in 0..4096u64 {
            r.load(a + i * 8);
        }
        let seq = r.finish();
        assert!(page_locality(&seq) > 0.95);
        assert!(lines_per_episode(&seq) > 30.0);

        // Page-strided: zero page locality.
        let mut r = Recorder::new();
        let a = r.alloc(1 << 20);
        for i in 0..256u64 {
            r.load(a + i * 4096);
        }
        let strided = r.finish();
        assert_eq!(page_locality(&strided), 0.0);
        assert!((lines_per_episode(&strided) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rng_is_reachable_from_workload_seeds() {
        // Smoke: Rng used by generators is deterministic (covered deeper in
        // each workload's tests).
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
