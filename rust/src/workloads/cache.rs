//! Global trace cache — the orchestrator's first pillar.
//!
//! Traces are pure functions of `(workload, scale, seed, max_accesses)`,
//! but the seed harness regenerated them once per experiment: a full
//! figure sweep paid the (expensive) workload generation dozens of times
//! per workload.  The cache memoizes generation behind an `Arc`, so every
//! experiment that needs a trace shares one read-only copy, and concurrent
//! requests for the same key block on a single in-flight generation
//! instead of duplicating it.
//!
//! Hit/miss counters make the "generated at most once per key" invariant
//! testable (see the orchestrator's `flat_sweep_generates_each_trace_once`).

use super::{by_name, Scale, Trace};
use crate::compress::synth::Profile;
use crate::util::hash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Everything a trace is a function of.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TraceKey {
    pub workload: String,
    pub scale: Scale,
    pub seed: u64,
    /// Trace cap; 0 = unlimited.
    pub max_accesses: usize,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

type Slot = Arc<OnceLock<(Arc<Trace>, Profile)>>;

pub struct TraceCache {
    // Fx-hashed (keys are simulator-internal, never iterated into
    // results); the lock is held only for the slot lookup.
    map: Mutex<FxHashMap<TraceKey, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TraceCache {
    pub fn new() -> Self {
        Self {
            map: Mutex::new(FxHashMap::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The process-wide cache every `Runner` and sweep shares by default.
    pub fn global() -> &'static TraceCache {
        static GLOBAL: OnceLock<TraceCache> = OnceLock::new();
        GLOBAL.get_or_init(TraceCache::new)
    }

    /// Fetch the trace + content profile for a key, generating exactly once
    /// per key even under concurrent callers.  The map lock is held only
    /// for the slot lookup; generation runs outside it, so distinct keys
    /// generate in parallel while same-key callers wait on the slot.
    pub fn get(
        &self,
        workload: &str,
        scale: Scale,
        seed: u64,
        max_accesses: usize,
    ) -> (Arc<Trace>, Profile) {
        let key = TraceKey { workload: workload.to_string(), scale, seed, max_accesses };
        let slot: Slot = {
            let mut map = self.map.lock().unwrap();
            map.entry(key).or_insert_with(|| Arc::new(OnceLock::new())).clone()
        };
        let mut generated = false;
        let (trace, profile) = slot.get_or_init(|| {
            generated = true;
            let w = by_name(workload)
                .unwrap_or_else(|| panic!("unknown workload {workload}"));
            let mut t = w.generate(seed, scale);
            if max_accesses > 0 {
                t = t.truncated(max_accesses);
            }
            (Arc::new(t), w.profile())
        });
        if generated {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        (trace.clone(), *profile)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Distinct keys currently cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached trace and reset the counters (frees the memory of
    /// a finished paper-scale sweep).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

impl Default for TraceCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_and_misses_are_counted() {
        let c = TraceCache::new();
        let (t1, _) = c.get("pr", Scale::Test, 1, 1000);
        let (t2, _) = c.get("pr", Scale::Test, 1, 1000);
        assert!(Arc::ptr_eq(&t1, &t2), "same key must share one trace");
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1 });
        let _ = c.get("pr", Scale::Test, 2, 1000); // different seed
        let _ = c.get("pr", Scale::Test, 1, 2000); // different cap
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 3 });
        assert_eq!(c.len(), 3);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn cached_trace_matches_fresh_generation() {
        let c = TraceCache::new();
        let (t, p) = c.get("bf", Scale::Test, 7, 500);
        let w = by_name("bf").unwrap();
        let fresh = w.generate(7, Scale::Test).truncated(500);
        assert_eq!(t.accesses, fresh.accesses);
        assert_eq!(t.footprint_pages, fresh.footprint_pages);
        assert_eq!(p, w.profile());
    }

    #[test]
    fn concurrent_same_key_generates_once() {
        let c = TraceCache::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let _ = c.get("pr", Scale::Test, 3, 800);
                });
            }
        });
        let st = c.stats();
        assert_eq!(st.misses, 1, "one generation for 4 concurrent gets");
        assert_eq!(st.hits, 3);
    }

    #[test]
    fn racing_workers_over_mixed_keys_count_exactly_and_share_traces() {
        // 8 workers x 3 keys x 5 rounds: every key generates exactly once
        // (misses == distinct keys), every other access is a hit, and all
        // workers observe the same Arc per key.
        use crate::util::hash::FxHashMap;
        use std::sync::Mutex;
        let c = TraceCache::new();
        let keys: [(&str, u64); 3] = [("pr", 1), ("bf", 1), ("pr", 2)];
        let seen: Mutex<FxHashMap<(String, u64), Arc<Trace>>> =
            Mutex::new(FxHashMap::default());
        std::thread::scope(|s| {
            for w in 0..8 {
                let seen = &seen;
                let c = &c;
                s.spawn(move || {
                    for round in 0..5 {
                        // Vary the visit order per worker/round to race
                        // generation against lookup on every key.
                        let (wl, seed) = keys[(w + round) % keys.len()];
                        let (t, _) = c.get(wl, Scale::Test, seed, 600);
                        let mut map = seen.lock().unwrap();
                        let prev = map
                            .entry((wl.to_string(), seed))
                            .or_insert_with(|| t.clone());
                        assert!(
                            Arc::ptr_eq(prev, &t),
                            "{wl}/{seed}: workers saw distinct trace copies"
                        );
                    }
                });
            }
        });
        let st = c.stats();
        assert_eq!(st.misses, keys.len() as u64, "each key generated exactly once");
        assert_eq!(
            st.hits + st.misses,
            8 * 5,
            "every access is counted exactly once"
        );
        assert_eq!(c.len(), keys.len());
    }
}
