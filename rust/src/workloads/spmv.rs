//! Sparse matrix-vector multiplication (Table 3: sp — TACO [51],
//! pkustk14).
//!
//! y = A*x over a banded symmetric-structure CSR matrix shaped like
//! pkustk14 (structural engineering: dense blocks along a band).  CSR
//! values/colidx stream sequentially and x-gathers stay within the band —
//! high spatial locality, highly compressible FEM data.

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

pub struct Spmv;

fn matrix_params(scale: Scale) -> (usize, usize, usize) {
    // (rows, nnz_per_row, half_bandwidth)
    match scale {
        Scale::Test => (8_192, 18, 600),
        // pkustk14: n=151926, ~14.8M nnz (~97/row, block-banded).  We keep
        // the shape (banded, blocked) at reduced size.
        Scale::Paper => (131_072, 40, 2_000),
    }
}

impl Workload for Spmv {
    fn name(&self) -> &'static str {
        "sp"
    }
    fn domain(&self) -> &'static str {
        "Linear Algebra"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::high()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (n, nnz_row, half_bw) = matrix_params(scale);
        let mut rng = Rng::new(seed);
        let mut r = Recorder::new();
        let values = r.alloc(8 * (n * nnz_row) as u64);
        let colidx = r.alloc(4 * (n * nnz_row) as u64);
        let rowptr = r.alloc(4 * (n + 1) as u64);
        let x = r.alloc(8 * n as u64);
        let y = r.alloc(8 * n as u64);

        let iters = if matches!(scale, Scale::Test) { 2 } else { 1 };
        for _ in 0..iters {
            let mut nz = 0u64;
            for row in 0..n {
                r.load(rowptr + 4 * row as u64);
                r.load(rowptr + 4 * (row as u64 + 1));
                let mut acc = 0.0f64;
                // Dense 6-blocks within the band (pkustk14 has 6-DOF
                // blocks), so column indices come in consecutive runs.
                let mut col = row.saturating_sub(rng.index(half_bw));
                let mut k = 0;
                while k < nnz_row {
                    let block = 6.min(nnz_row - k);
                    for b in 0..block {
                        r.load(values + 8 * nz);
                        r.load(colidx + 4 * nz);
                        let c = (col + b).min(n - 1);
                        r.load(x + 8 * c as u64);
                        r.compute(2); // fma
                        acc += c as f64;
                        nz += 1;
                    }
                    col = (col + 6 + rng.index(half_bw / 4)).min(n - 1);
                    k += block;
                }
                let _ = acc;
                r.compute(2);
                r.store(y + 8 * row as u64);
            }
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn high_spatial_locality() {
        let t = Spmv.generate(13, Scale::Test);
        let s = locality_score(&t);
        assert!(s > 30.0, "sp locality score {s}");
    }

    #[test]
    fn footprint_matches_arrays() {
        let (n, nnz, _) = matrix_params(Scale::Test);
        let t = Spmv.generate(2, Scale::Test);
        let bytes = 8 * n * nnz + 4 * n * nnz;
        assert!(t.footprint_bytes() as usize > bytes / 2);
    }

    #[test]
    fn deterministic() {
        let a = Spmv.generate(3, Scale::Test);
        let b = Spmv.generate(3, Scale::Test);
        assert_eq!(a.accesses.len(), b.accesses.len());
    }
}
