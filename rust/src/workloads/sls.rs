//! SparseLengthsSum (Table 3: sl — DLRM [67], Criteo).
//!
//! Embedding-bag lookup: gather rows from a large embedding table by
//! Zipf-distributed ids and reduce per bag.  Row reads are sequential
//! (256B rows ⇒ 4 consecutive lines), which is the paper's high-locality
//! class even though rows themselves are randomly placed.

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

pub struct SparseLengthsSum;

fn table_params(scale: Scale) -> (usize, usize, usize) {
    // (rows, floats_per_row, lookups)
    match scale {
        Scale::Test => (20_000, 256, 30_000),
        // Criteo-scale tables shrunk to tens of MB; 256-float rows (1KB)
        // as in DLRM's larger embedding configurations.
        Scale::Paper => (100_000, 256, 250_000),
    }
}

impl Workload for SparseLengthsSum {
    fn name(&self) -> &'static str {
        "sl"
    }
    fn domain(&self) -> &'static str {
        "Machine Learning"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::high()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (rows, dim, lookups) = table_params(scale);
        let row_bytes = (dim * 4) as u64;
        let mut rng = Rng::new(seed);
        let mut r = Recorder::new();
        let table = r.alloc(rows as u64 * row_bytes);
        let indices = r.alloc(8 * lookups as u64);
        let out = r.alloc(4 * dim as u64 * 1024);

        let mut bag = 0usize;
        let mut i = 0usize;
        while i < lookups {
            let bag_size = 4 + rng.index(28); // Criteo-ish multi-hot sizes
            for _ in 0..bag_size.min(lookups - i) {
                r.load(indices + 8 * i as u64);
                let row = rng.zipf(rows, 1.05); // hot embedding rows
                let base = table + row as u64 * row_bytes;
                // Sequential read of the whole row (dim floats, stride 16B
                // vector loads).
                let mut off = 0;
                while off < row_bytes {
                    r.load(base + off);
                    r.compute(1); // accumulate
                    off += 16;
                }
                i += 1;
            }
            // Write the pooled bag output.
            let out_base = out + ((bag % 1024) * dim * 4) as u64;
            let mut off = 0;
            while off < row_bytes {
                r.store(out_base + off);
                off += 16;
            }
            bag += 1;
            r.compute(8);
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn row_reads_give_high_locality() {
        let t = SparseLengthsSum.generate(13, Scale::Test);
        let s = locality_score(&t);
        // 1KB rows read whole: well above the medium class.
        assert!(s > 15.0, "sl locality score {s}");
    }

    #[test]
    fn zipf_reuse_creates_hot_pages() {
        let t = SparseLengthsSum.generate(2, Scale::Test);
        let mut counts = crate::util::hash::FxHashMap::default();
        for a in &t.accesses {
            *counts.entry(a.addr >> 12).or_insert(0u64) += 1;
        }
        let mut v: Vec<u64> = counts.values().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = v.iter().sum();
        let top10: u64 = v.iter().take(v.len() / 10).sum();
        assert!(top10 as f64 / total as f64 > 0.3, "no hot pages");
    }

    #[test]
    fn deterministic() {
        let a = SparseLengthsSum.generate(4, Scale::Test);
        let b = SparseLengthsSum.generate(4, Scale::Test);
        assert_eq!(a.accesses.len(), b.accesses.len());
    }
}
