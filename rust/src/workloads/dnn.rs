//! CNN inference (Table 3: dr = Darknet19, rs = Resnet50 — Darknet [81]).
//!
//! Layer-by-layer inference walk: each conv layer streams its weight
//! tensor and input activations (im2col-style row reads) and writes output
//! activations.  Access patterns are almost perfectly sequential ⇒ the
//! paper's high-locality class; trained float weights are nearly
//! incompressible ⇒ low compressibility profile (paper: 1.42x vs 4.47x
//! average).

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;

/// (in_ch, out_ch, spatial) per conv layer — shapes follow the published
/// architectures, downscaled uniformly for `Scale::Test`.
fn darknet19_layers(scale: Scale) -> Vec<(usize, usize, usize)> {
    let s = if matches!(scale, Scale::Test) { 4 } else { 1 };
    vec![
        (3, 32 / s, 224 / s),
        (32 / s, 64 / s, 112 / s),
        (64 / s, 128 / s, 56 / s),
        (128 / s, 64 / s, 56 / s),
        (64 / s, 128 / s, 56 / s),
        (128 / s, 256 / s, 28 / s),
        (256 / s, 128 / s, 28 / s),
        (128 / s, 256 / s, 28 / s),
        (256 / s, 512 / s, 14 / s),
        (512 / s, 256 / s, 14 / s),
        (256 / s, 512 / s, 14 / s),
        (512 / s, 1024 / s, 7),
        (1024 / s, 1024 / s, 7),
    ]
}

fn resnet50_layers(scale: Scale) -> Vec<(usize, usize, usize)> {
    let s = if matches!(scale, Scale::Test) { 4 } else { 1 };
    let mut layers = vec![(3, 64 / s, 112 / s)];
    // Bottleneck stages: (stage_channels, blocks, spatial).
    for &(ch, blocks, sp) in &[(256, 3, 56), (512, 4, 28), (1024, 6, 14), (2048, 3, 7)] {
        for _ in 0..blocks {
            layers.push((ch / 4 / s, ch / 4 / s, (sp / s).max(7)));
            layers.push((ch / 4 / s, ch / s, (sp / s).max(7)));
        }
    }
    layers
}

fn conv_walk(r: &mut Recorder, layers: &[(usize, usize, usize)]) {
    for &(cin, cout, sp) in layers {
        let cin = cin.max(1);
        let cout = cout.max(1);
        let sp = sp.max(4);
        let k = 3usize;
        let weights = r.alloc((cout * cin * k * k * 4) as u64);
        let input = r.alloc((cin * sp * sp * 4) as u64);
        let output = r.alloc((cout * sp * sp * 4) as u64);
        // GEMM tiling: for each output channel, stream the weight row and
        // the im2col'd input; sample the spatial positions so trace size
        // stays bounded while preserving the streaming pattern.
        let spatial_samples = (sp * sp / 4).max(16);
        for oc in 0..cout {
            let wrow = weights + (oc * cin * k * k * 4) as u64;
            // Weight row reused across positions — stream once per 8
            // positions (cache-resident in between).
            for pos in 0..spatial_samples {
                if pos % 8 == 0 {
                    let mut off = 0u64;
                    while off < (cin * k * k * 4) as u64 {
                        r.load(wrow + off);
                        off += 16;
                    }
                }
                // Input patch: k*k rows of cin values, contiguous per row.
                let base = input + ((pos * 16) % (cin * sp * sp)) as u64 * 4;
                for row in 0..k as u64 {
                    r.load(base + row * (sp * 4) as u64);
                    r.compute(2 * cin as u32); // fma over channels
                }
                r.store(output + ((oc * spatial_samples + pos) * 4) as u64);
            }
        }
    }
}

pub struct Darknet19;

impl Workload for Darknet19 {
    fn name(&self) -> &'static str {
        "dr"
    }
    fn domain(&self) -> &'static str {
        "Machine Learning"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::low()
    }
    fn generate(&self, _seed: u64, scale: Scale) -> Trace {
        let mut r = Recorder::new();
        conv_walk(&mut r, &darknet19_layers(scale));
        r.finish()
    }
}

pub struct Resnet50;

impl Workload for Resnet50 {
    fn name(&self) -> &'static str {
        "rs"
    }
    fn domain(&self) -> &'static str {
        "Machine Learning"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::low()
    }
    fn generate(&self, _seed: u64, scale: Scale) -> Trace {
        let mut r = Recorder::new();
        conv_walk(&mut r, &resnet50_layers(scale));
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn both_nets_have_high_locality() {
        for t in [Darknet19.generate(1, Scale::Test), Resnet50.generate(1, Scale::Test)] {
            let s = locality_score(&t);
            assert!(s > 30.0, "dnn locality score {s}");
        }
    }

    #[test]
    fn low_compressibility_profile() {
        // Paper: dr/rs compress only ~1.42x.
        let p = Darknet19.profile();
        assert!(p.random > 0.5, "dnn profile must be mostly random data");
    }

    #[test]
    fn resnet_is_deeper_than_darknet() {
        assert!(resnet50_layers(Scale::Paper).len() > darknet19_layers(Scale::Paper).len());
    }

    #[test]
    fn traces_nonempty_and_reasonable() {
        let t = Darknet19.generate(1, Scale::Test);
        assert!(t.accesses.len() > 50_000, "{}", t.accesses.len());
        assert!(t.footprint_pages > 100);
    }
}
