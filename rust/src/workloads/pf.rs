//! Particle Filter (Table 3: pf — Rodinia [20]).
//!
//! Sequential Monte-Carlo object tracking: per frame, update all particle
//! positions (stream), compute likelihoods against an image region
//! (strided window reads), normalize weights (stream), and resample
//! (mostly-monotone gather).  Streaming phases dominate ⇒ high locality.

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

pub struct ParticleFilter;

fn params(scale: Scale) -> (usize, usize, usize) {
    // (particles, image_dim, frames)
    match scale {
        Scale::Test => (10_000, 512, 3),
        // Paper: 4096x4096 image, 30000 particles.
        Scale::Paper => (30_000, 2_048, 6),
    }
}

impl Workload for ParticleFilter {
    fn name(&self) -> &'static str {
        "pf"
    }
    fn domain(&self) -> &'static str {
        "HPC"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::high()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let (np, dim, frames) = params(scale);
        let mut rng = Rng::new(seed);
        let mut r = Recorder::new();
        let xs = r.alloc(8 * np as u64);
        let ys = r.alloc(8 * np as u64);
        let weights = r.alloc(8 * np as u64);
        let cdf = r.alloc(8 * np as u64);
        let image = r.alloc((dim * dim) as u64);

        // Tracked-object position: particles concentrate around it (the
        // defining behaviour of a particle filter), so likelihood reads
        // cluster on a small image region per frame — pf's high-locality
        // signature.
        let mut obj_x = (dim / 2) as f64;
        let mut obj_y = (dim / 2) as f64;
        for _ in 0..frames {
            obj_x = (obj_x + rng.gaussian() * 16.0).clamp(64.0, (dim - 64) as f64);
            obj_y = (obj_y + rng.gaussian() * 16.0).clamp(64.0, (dim - 64) as f64);
            // Frame ingestion: the new video frame is streamed in (this is
            // the bulk of pf's footprint and gives it its high-locality
            // class — Rodinia's videoSequence/setIf phase).
            let px_per_line = 64u64;
            let mut off = 0u64;
            while off < (dim * dim) as u64 {
                r.load(image + off);
                r.compute(2); // threshold / dilate
                if off % (px_per_line * 8) == 0 {
                    r.store(image + off);
                }
                off += px_per_line;
            }
            // Motion update: stream particles.
            for i in 0..np as u64 {
                r.load(xs + 8 * i);
                r.load(ys + 8 * i);
                r.compute(6); // gaussian propagate
                r.store(xs + 8 * i);
                r.store(ys + 8 * i);
            }
            // Likelihood: read an 8x8 window around each particle.
            for i in 0..np as u64 {
                r.load(xs + 8 * i);
                r.load(ys + 8 * i);
                let px = ((obj_x + rng.gaussian() * 24.0) as usize).min(dim - 9);
                let py = ((obj_y + rng.gaussian() * 24.0) as usize).min(dim - 9);
                for wy in 0..8u64 {
                    let rowbase = image + ((py as u64 + wy) * dim as u64 + px as u64);
                    // Window row: one line's worth of pixels.
                    r.load(rowbase);
                    r.compute(8);
                }
                r.store(weights + 8 * i);
            }
            // Normalize + CDF: two streaming passes.
            for i in 0..np as u64 {
                r.load(weights + 8 * i);
                r.compute(1);
            }
            for i in 0..np as u64 {
                r.load(weights + 8 * i);
                r.compute(2);
                r.store(cdf + 8 * i);
            }
            // Systematic resampling: monotone scan of the CDF.
            let mut pos = 0u64;
            for _ in 0..np {
                pos = (pos + rng.below(4)).min(np as u64 - 1);
                r.load(cdf + 8 * pos);
                r.compute(3);
                r.load(xs + 8 * pos);
                r.load(ys + 8 * pos);
            }
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn streaming_gives_high_locality() {
        let t = ParticleFilter.generate(13, Scale::Test);
        let s = locality_score(&t);
        assert!(s > 25.0, "pf locality score {s}");
    }

    #[test]
    fn footprint_includes_image() {
        let (_, dim, _) = params(Scale::Test);
        let t = ParticleFilter.generate(2, Scale::Test);
        // Image pages actually touched (likelihood windows).
        assert!(t.footprint_pages > dim * dim / 4096 / 8);
    }

    #[test]
    fn deterministic() {
        let a = ParticleFilter.generate(3, Scale::Test);
        let b = ParticleFilter.generate(3, Scale::Test);
        assert_eq!(a.accesses.len(), b.accesses.len());
    }
}
