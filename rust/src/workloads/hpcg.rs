//! High Performance Conjugate Gradient (Table 3: hp — HPCG [39]).
//!
//! CG iterations over a 27-point stencil on a 3D grid: SpMV with
//! structured neighbours (z/y/x plane offsets), dot products, and AXPYs —
//! streaming-dominated, high locality, highly compressible.

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;

pub struct Hpcg;

fn grid(scale: Scale) -> usize {
    match scale {
        Scale::Test => 24,
        // Paper: 104^3.  Scaled to keep footprint tens of MB: 88^3 x 8B x
        // several vectors ≈ 27MB.
        Scale::Paper => 88,
    }
}

impl Workload for Hpcg {
    fn name(&self) -> &'static str {
        "hp"
    }
    fn domain(&self) -> &'static str {
        "HPC"
    }
    fn locality(&self) -> Locality {
        Locality::High
    }
    fn profile(&self) -> Profile {
        Profile::high()
    }
    fn generate(&self, _seed: u64, scale: Scale) -> Trace {
        let n = grid(scale);
        let nn = (n * n * n) as u64;
        let mut r = Recorder::new();
        let x = r.alloc(8 * nn);
        let b = r.alloc(8 * nn);
        let p = r.alloc(8 * nn);
        let ap = r.alloc(8 * nn);
        let resid = r.alloc(8 * nn);

        let idx = |i: usize, j: usize, k: usize| ((i * n + j) * n + k) as u64;
        let iters = if matches!(scale, Scale::Test) { 2 } else { 2 };
        for _ in 0..iters {
            // Ap = A*p  (27-point stencil; we touch the 7 axis neighbours
            // plus the row's matrix coefficients streamingly).
            for i in 1..n - 1 {
                for j in 1..n - 1 {
                    for k in 1..n - 1 {
                        r.load(p + 8 * idx(i, j, k));
                        r.load(p + 8 * idx(i, j, k.wrapping_sub(1)));
                        r.load(p + 8 * idx(i, j, k + 1));
                        r.load(p + 8 * idx(i, j - 1, k));
                        r.load(p + 8 * idx(i, j + 1, k));
                        r.load(p + 8 * idx(i - 1, j, k));
                        r.load(p + 8 * idx(i + 1, j, k));
                        r.compute(27 * 2); // stencil fma
                        r.store(ap + 8 * idx(i, j, k));
                    }
                }
            }
            // alpha = (r,r)/(p,Ap): two streaming dots.
            for v in 0..nn {
                r.load(resid + 8 * v);
                r.compute(2);
            }
            for v in 0..nn {
                r.load(p + 8 * v);
                r.load(ap + 8 * v);
                r.compute(2);
            }
            // x += alpha p; r -= alpha Ap  (AXPYs).
            for v in 0..nn {
                r.load(x + 8 * v);
                r.load(p + 8 * v);
                r.compute(2);
                r.store(x + 8 * v);
            }
            for v in 0..nn {
                r.load(resid + 8 * v);
                r.load(ap + 8 * v);
                r.compute(2);
                r.store(resid + 8 * v);
            }
            // One b read per iteration for the convergence check.
            for v in (0..nn).step_by(8) {
                r.load(b + 8 * v);
                r.compute(1);
            }
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn streaming_dominates() {
        let t = Hpcg.generate(1, Scale::Test);
        let s = locality_score(&t);
        assert!(s > 30.0, "hp locality score {s}");
    }

    #[test]
    fn footprint_is_vectors_times_grid() {
        let t = Hpcg.generate(1, Scale::Test);
        let n = grid(Scale::Test);
        let expected = 5 * 8 * n * n * n / 4096;
        assert!(t.footprint_pages >= expected, "{} < {expected}", t.footprint_pages);
    }

    #[test]
    fn compute_intensity_is_high() {
        // Stencil fma gaps: instructions per access should exceed 2.
        let t = Hpcg.generate(1, Scale::Test);
        let ipa = t.instructions() as f64 / t.accesses.len() as f64;
        assert!(ipa > 2.0, "instructions/access {ipa}");
    }
}
