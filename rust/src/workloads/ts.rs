//! Timeseries analysis (Table 3: ts — Matrix Profile / SCRIMP [106]).
//!
//! All-pairs similarity join: for each diagonal of the distance matrix,
//! stream the series computing running dot products and updating the
//! profile.  Two interleaved streams (`series[i]`, `series[i+lag]`) plus
//! profile updates give medium spatial locality — sequential runs broken
//! by the lag-offset stream and profile writes.

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

pub struct Timeseries;

fn series_len(scale: Scale) -> usize {
    match scale {
        Scale::Test => 16_384,
        // Paper: 262144 elements.
        Scale::Paper => 262_144,
    }
}

impl Workload for Timeseries {
    fn name(&self) -> &'static str {
        "ts"
    }
    fn domain(&self) -> &'static str {
        "Data Analytics"
    }
    fn locality(&self) -> Locality {
        Locality::Medium
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let n = series_len(scale);
        let mut rng = Rng::new(seed);
        let mut r = Recorder::new();
        let series = r.alloc(8 * n as u64);
        let profile = r.alloc(8 * n as u64);
        let index = r.alloc(4 * n as u64);
        let window = 64usize;
        // SCRIMP-style: random diagonal order.
        let diags: usize = match scale {
            Scale::Test => 120,
            Scale::Paper => 160,
        };
        for _ in 0..diags {
            let lag = window + rng.index(n - 2 * window);
            // PreSCRIMP-style sampled diagonal: stride `step` elements and
            // interpolate between samples — every other cache line is
            // touched, which is what lands ts in the medium class.
            let len = n - lag - window;
            let step = 32usize; // 256B: every fourth 64B line
            let mut i = 0usize;
            while i < len {
                r.load(series + 8 * i as u64);
                r.load(series + 8 * (i + lag) as u64);
                r.compute(4 * step as u32); // dot across the sampled window
                // Profile check/update at the diagonal's anchor.
                r.load(profile + 8 * i as u64);
                r.compute(2);
                if rng.chance(0.2) {
                    r.store(profile + 8 * i as u64);
                    r.store(index + 4 * i as u64);
                }
                i += step;
            }
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn trace_is_nonempty_and_deterministic() {
        let a = Timeseries.generate(1, Scale::Test);
        let b = Timeseries.generate(1, Scale::Test);
        assert!(a.accesses.len() > 100_000);
        assert_eq!(a.accesses.len(), b.accesses.len());
    }

    #[test]
    fn locality_is_medium() {
        let t = Timeseries.generate(13, Scale::Test);
        let s = locality_score(&t);
        // Sampled diagonals touch every fourth line: medium class.
        assert!((6.0..30.0).contains(&s), "ts locality score {s}");
    }

    #[test]
    fn footprint_scales_with_series() {
        let t = Timeseries.generate(3, Scale::Test);
        let expected = (8 * series_len(Scale::Test)) / 4096;
        assert!(t.footprint_pages >= expected, "{} < {expected}", t.footprint_pages);
    }
}
