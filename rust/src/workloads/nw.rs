//! Needleman-Wunsch sequence alignment (Table 3: nw — Rodinia [20]).
//!
//! Global-alignment dynamic programming over an (n+1)x(n+1) score matrix.
//! Like the Rodinia implementation, the matrix is processed in
//! anti-diagonal wavefronts: consecutive cells of a diagonal are a full
//! row apart in memory, so consecutive accesses stride by the row size —
//! the access pattern that puts nw in the paper's poor-locality class
//! despite the algorithm being "dense".

use super::trace::{Locality, Recorder, Scale, Trace, Workload};
use crate::compress::synth::Profile;
use crate::util::prng::Rng;

pub struct NeedlemanWunsch;

fn seq_len(scale: Scale) -> usize {
    match scale {
        Scale::Test => 256,
        // Paper: 4096 base pairs; 1025^2 x 4B ≈ 4.2MB per matrix block,
        // processed over multiple sequence pairs for a larger footprint.
        Scale::Paper => 1024,
    }
}

fn pairs(scale: Scale) -> usize {
    match scale {
        Scale::Test => 1,
        Scale::Paper => 6,
    }
}

impl Workload for NeedlemanWunsch {
    fn name(&self) -> &'static str {
        "nw"
    }
    fn domain(&self) -> &'static str {
        "Bioinformatics"
    }
    fn locality(&self) -> Locality {
        Locality::Low
    }
    fn profile(&self) -> Profile {
        Profile::medium()
    }
    fn generate(&self, seed: u64, scale: Scale) -> Trace {
        let n = seq_len(scale);
        let mut rng = Rng::new(seed);
        let mut r = Recorder::new();
        for _pair in 0..pairs(scale) {
            let rows = n + 1;
            let matrix = r.alloc((rows * rows * 4) as u64);
            let seq_a = r.alloc(n as u64);
            let seq_b = r.alloc(n as u64);
            let reference = r.alloc((rows * rows * 4) as u64); // BLOSUM-ish
            let at = |i: usize, j: usize| matrix + (i * rows + j) as u64 * 4;

            // Initialize borders (sequential).
            for i in 0..rows {
                r.store(at(i, 0));
                r.compute(1);
            }
            for j in 0..rows {
                r.store(at(0, j));
                r.compute(1);
            }
            // Anti-diagonal wavefront fill.
            let mut score = 0i64;
            for d in 2..(2 * rows - 1) {
                let i_lo = d.saturating_sub(rows - 1).max(1);
                let i_hi = (d - 1).min(rows - 1);
                for i in i_lo..=i_hi {
                    let j = d - i;
                    // Sequence characters + reference matrix lookup.
                    r.load(seq_a + (i - 1) as u64);
                    r.load(seq_b + (j - 1) as u64);
                    r.load(reference + ((i % rows) * rows + (j % rows)) as u64 * 4);
                    // DP dependencies: NW, N, W neighbours.
                    r.load(at(i - 1, j - 1));
                    r.load(at(i - 1, j));
                    r.load(at(i, j - 1));
                    r.compute(6); // max of three + penalty adds
                    r.store(at(i, j));
                    score = score.wrapping_add(rng.below(3) as i64);
                }
            }
            let _ = score;
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::trace::locality_score;

    #[test]
    fn trace_covers_whole_matrix() {
        let t = NeedlemanWunsch.generate(1, Scale::Test);
        let n = seq_len(Scale::Test) + 1;
        // Matrix + two sequences + reference.
        let expected_pages = (n * n * 4) / 4096;
        assert!(
            t.footprint_pages >= expected_pages,
            "footprint {} < matrix pages {expected_pages}",
            t.footprint_pages
        );
    }

    #[test]
    fn wavefront_has_poor_page_locality() {
        let t = NeedlemanWunsch.generate(1, Scale::Test);
        let s = locality_score(&t);
        // Diagonal neighbours are a full matrix row apart.
        assert!(s < 8.0, "nw locality score {s}");
    }

    #[test]
    fn deterministic() {
        let a = NeedlemanWunsch.generate(2, Scale::Test);
        let b = NeedlemanWunsch.generate(2, Scale::Test);
        assert_eq!(a.accesses.len(), b.accesses.len());
    }

    #[test]
    fn write_fraction_is_substantial() {
        // One store per DP cell: nw exercises the dirty-data path (§4.3).
        let t = NeedlemanWunsch.generate(3, Scale::Test);
        let writes = t.accesses.iter().filter(|a| a.write).count();
        let frac = writes as f64 / t.accesses.len() as f64;
        assert!(frac > 0.10, "write fraction {frac}");
    }
}
