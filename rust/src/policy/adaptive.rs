//! Closed-loop control-law registry (ROADMAP item 3; DaeMon §4.5 taken
//! online).
//!
//! Each law the [`AdaptiveController`](crate::system::controller::AdaptiveController)
//! may run is a literal def in `CONTROL_LAWS`, carrying its actuation
//! bounds — the controller clamps every emitted action to its law's
//! declared range, and the fuzz tests in `system::controller` assert the
//! clamp can never be escaped.  daemon-lint R6 cross-checks these ids
//! against the DESIGN.md §"Policy registry" table in both directions,
//! exactly like the movement/recovery/sharing registries in
//! [`super`] — a new control law registers itself here plus one doc row.

/// One registered control law: identity plus the bounds every actuation
/// it emits must satisfy.
pub struct ControlLawDef {
    /// Canonical lowercase id (DESIGN.md table spelling).
    pub id: &'static str,
    /// One-line description for docs and diagnostics.
    pub about: &'static str,
    /// Inclusive lower bound of the actuated quantity.
    pub min: f64,
    /// Inclusive upper bound of the actuated quantity.
    pub max: f64,
    /// Largest per-epoch change of the actuated quantity (damping; the
    /// recovery switch is binary, so its step spans the full range).
    pub max_step: f64,
}

/// The three closed-loop control laws.
///
/// * `ratio-tune` actuates the §4.1 line/page partition ratio of a
///   tenant's fabric ports: toward `max` under observed link distress
///   (critical lines keep flowing when page bandwidth collapses), back
///   toward the scheme's static default when conditions are nominal.
/// * `recovery-switch` actuates the §4.6 degraded-mode policy between
///   `Stall` (0.0) and `Refetch` (1.0) from observed port distress,
///   with a clean-dwell hysteresis before relaxing to `Stall`.
/// * `share-rebalance` actuates per-tenant fabric weights under
///   work-conserving sharing: tenants observed idle through the
///   controller's idle dwell drop to the `min` weight floor and the
///   slack goes to active tenants; weights always renormalize to sum
///   exactly 1.0.
pub static CONTROL_LAWS: [ControlLawDef; 3] = [
    ControlLawDef {
        id: "ratio-tune",
        about: "migration-ratio retuning from observed link conditions",
        min: 0.10,
        max: 0.60,
        max_step: 0.20,
    },
    ControlLawDef {
        id: "recovery-switch",
        about: "Stall<->Refetch switching from observed port distress",
        min: 0.0,
        max: 1.0,
        max_step: 1.0,
    },
    ControlLawDef {
        id: "share-rebalance",
        about: "idle-share reclamation under work-conserving sharing",
        min: 0.05,
        max: 1.0,
        max_step: 1.0,
    },
];

/// Resolve a control law by id.
pub fn control_law(id: &str) -> Option<&'static ControlLawDef> {
    let lower = id.to_ascii_lowercase();
    CONTROL_LAWS.iter().find(|d| d.id == lower)
}

/// Canonical control-law ids in registry order.
pub fn control_law_ids() -> Vec<&'static str> {
    CONTROL_LAWS.iter().map(|d| d.id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_law_registry_is_consistent() {
        for (i, d) in CONTROL_LAWS.iter().enumerate() {
            assert!(!d.id.is_empty() && d.id == d.id.to_ascii_lowercase(), "{}", d.id);
            assert!(
                !CONTROL_LAWS[..i].iter().any(|p| p.id == d.id),
                "duplicate id {}",
                d.id
            );
            assert!(d.min < d.max, "{}: degenerate bounds", d.id);
            assert!(d.max_step > 0.0 && d.max_step <= d.max - d.min, "{}", d.id);
            assert!(!d.about.is_empty(), "{}", d.id);
            let hit = control_law(d.id).expect(d.id);
            assert_eq!(hit.id, d.id);
        }
        assert!(control_law("nope").is_none());
        assert_eq!(control_law_ids(), ["ratio-tune", "recovery-switch", "share-rebalance"]);
    }

    #[test]
    fn ratio_tune_bounds_cover_the_static_sweep_points() {
        // The `adaptive` experiment's static single-knob arms sit exactly
        // on this law's bounds; the default 0.25 lies inside them.
        let d = control_law("ratio-tune").unwrap();
        assert_eq!(d.min, 0.10);
        assert_eq!(d.max, 0.60);
        assert!((d.min..=d.max).contains(&0.25));
    }
}
