//! Pluggable policy registry: movement schemes, recovery routes and
//! sharing disciplines as trait objects behind id lookup.
//!
//! Mirrors `experiments::REGISTRY`: every policy the simulator knows is
//! a literal entry in one of the three tables below (`REGISTRY`,
//! `RECOVERY`, `SHARING`), carrying a greppable `id: "..."` field —
//! daemon-lint R6 cross-checks those ids against the DESIGN.md §"Policy
//! registry" tables in both directions, so a new policy registers itself
//! in this one file plus one doc row.  `SchemeKind::{name, by_name,
//! policy}`, `RecoveryPolicy::name`, `SharingMode::name` and the CLI
//! `--scheme` resolution all delegate here; there is no second hand-kept
//! alias list.
//!
//! To add a policy: add a def literal to the matching table (ids are
//! lowercase, the CLI spelling), document it in DESIGN.md's policy
//! table (R6 enforces the pairing), and — for movement schemes — extend
//! the closed `SchemeKind` enum it drives.

pub mod adaptive;

use crate::config::SharingMode;
use crate::schemes::{Policy, SchemeKind};
use crate::system::fault::RecoveryPolicy;

/// A data-movement scheme (`--scheme`): everything the machine builder
/// needs to instantiate it, keyed by canonical id.
pub trait MovementPolicy: Sync {
    /// Canonical lowercase id — the `--scheme` spelling.
    fn id(&self) -> &'static str;
    /// Display name used in tables and plot legends.
    fn display(&self) -> &'static str;
    /// Accepted alternate spellings (lowercase).
    fn aliases(&self) -> &'static [&'static str];
    /// The closed enum variant this policy drives.
    fn kind(&self) -> SchemeKind;
    /// Decomposed machine-driver flags.
    fn flags(&self) -> Policy;
}

/// §4.6 recovery: how the compute side routes a request whose home
/// module's port is down.
pub trait RecoveryRoute: Sync {
    /// Canonical lowercase id (`RecoveryPolicy::name` spelling).
    fn id(&self) -> &'static str;
    /// The enum variant this route implements.
    fn policy(&self) -> RecoveryPolicy;
    /// Choose the module serving a request homed at `home` out of
    /// `modules`; `port_up(m)` reports reachability at issue time.
    fn route(&self, home: usize, modules: usize, port_up: &dyn Fn(usize) -> bool) -> usize;
}

/// Fabric bandwidth-sharing discipline: identity plus the capability
/// surface the rest of the system keys decisions off.
pub trait SharingPolicy: Sync {
    /// Canonical lowercase id (`SharingMode::name` spelling).
    fn id(&self) -> &'static str;
    /// The enum variant this discipline implements.
    fn mode(&self) -> SharingMode;
    /// Idle peer/sibling capacity is borrowed at request time.
    fn borrows_idle(&self) -> bool;
    /// Fault injection composes with this discipline.  The
    /// work-conserving borrow planner reads a down port as merely idle
    /// and lends its capacity away, so only strict sharing supports
    /// `FaultPlan`s — `ClusterConfig::validate` enforces this.
    fn supports_faults(&self) -> bool;
}

/// One registered movement policy.
pub struct MovementDef {
    pub id: &'static str,
    pub display: &'static str,
    pub aliases: &'static [&'static str],
    pub kind: SchemeKind,
    pub flags: Policy,
}

impl MovementPolicy for MovementDef {
    fn id(&self) -> &'static str {
        self.id
    }
    fn display(&self) -> &'static str {
        self.display
    }
    fn aliases(&self) -> &'static [&'static str] {
        self.aliases
    }
    fn kind(&self) -> SchemeKind {
        self.kind
    }
    fn flags(&self) -> Policy {
        self.flags
    }
}

/// One registered recovery route.
pub struct RecoveryDef {
    pub id: &'static str,
    pub policy: RecoveryPolicy,
    pub route: fn(usize, usize, &dyn Fn(usize) -> bool) -> usize,
}

impl RecoveryRoute for RecoveryDef {
    fn id(&self) -> &'static str {
        self.id
    }
    fn policy(&self) -> RecoveryPolicy {
        self.policy
    }
    fn route(&self, home: usize, modules: usize, port_up: &dyn Fn(usize) -> bool) -> usize {
        (self.route)(home, modules, port_up)
    }
}

/// One registered sharing discipline.
pub struct SharingDef {
    pub id: &'static str,
    pub mode: SharingMode,
    pub borrows_idle: bool,
    pub supports_faults: bool,
}

impl SharingPolicy for SharingDef {
    fn id(&self) -> &'static str {
        self.id
    }
    fn mode(&self) -> SharingMode {
        self.mode
    }
    fn borrows_idle(&self) -> bool {
        self.borrows_idle
    }
    fn supports_faults(&self) -> bool {
        self.supports_faults
    }
}

/// The nine movement schemes (§2.2 motivation + §6 evaluation sets), in
/// historical `by_name` order.  Display names are the exact spellings
/// every table/legend has always used.
pub static REGISTRY: [MovementDef; 9] = [
    MovementDef {
        id: "local",
        display: "Local",
        aliases: &[],
        kind: SchemeKind::Local,
        flags: Policy { local_only: true, ..Policy::none() },
    },
    MovementDef {
        id: "cache-line",
        display: "cache-line",
        aliases: &["cacheline", "cl"],
        kind: SchemeKind::CacheLine,
        flags: Policy { move_lines: true, install_pages: false, ..Policy::none() },
    },
    MovementDef {
        id: "remote",
        display: "Remote",
        aliases: &[],
        kind: SchemeKind::Remote,
        flags: Policy { move_pages: true, blocking_pages: true, ..Policy::none() },
    },
    MovementDef {
        id: "page-free",
        display: "page-free",
        aliases: &["pagefree"],
        kind: SchemeKind::PageFree,
        flags: Policy {
            move_pages: true,
            free_pages: true,
            move_lines: true,
            ..Policy::none()
        },
    },
    MovementDef {
        id: "cache-line+page",
        display: "cache-line+page",
        aliases: &["clp", "naive"],
        kind: SchemeKind::CacheLinePage,
        flags: Policy { move_pages: true, move_lines: true, ..Policy::none() },
    },
    MovementDef {
        id: "lc",
        display: "LC",
        aliases: &[],
        kind: SchemeKind::Lc,
        flags: Policy {
            move_pages: true,
            blocking_pages: true,
            compress: true,
            ..Policy::none()
        },
    },
    MovementDef {
        id: "bp",
        display: "BP",
        aliases: &[],
        kind: SchemeKind::Bp,
        flags: Policy {
            move_pages: true,
            move_lines: true,
            partitioned: true,
            ..Policy::none()
        },
    },
    MovementDef {
        id: "pq",
        display: "PQ",
        aliases: &[],
        kind: SchemeKind::Pq,
        flags: Policy {
            move_pages: true,
            move_lines: true,
            partitioned: true,
            selection: true,
            ..Policy::none()
        },
    },
    MovementDef {
        id: "daemon",
        display: "DaeMon",
        aliases: &[],
        kind: SchemeKind::Daemon,
        flags: Policy {
            move_pages: true,
            move_lines: true,
            partitioned: true,
            selection: true,
            compress: true,
            ..Policy::none()
        },
    },
];

fn route_stall(home: usize, _modules: usize, _port_up: &dyn Fn(usize) -> bool) -> usize {
    home
}

fn route_refetch(home: usize, modules: usize, port_up: &dyn Fn(usize) -> bool) -> usize {
    for k in 0..modules {
        let m = (home + k) % modules;
        if port_up(m) {
            return m;
        }
    }
    home
}

/// The two §4.6 recovery routes.  `stall` waits on the home module
/// (historical routing, byte-identical); `refetch` walks to the next
/// surviving module and falls back to home when everything is down.
pub static RECOVERY: [RecoveryDef; 2] = [
    RecoveryDef {
        id: "stall",
        policy: RecoveryPolicy::Stall,
        route: route_stall,
    },
    RecoveryDef {
        id: "refetch",
        policy: RecoveryPolicy::Refetch,
        route: route_refetch,
    },
];

/// The two fabric sharing disciplines.
pub static SHARING: [SharingDef; 2] = [
    SharingDef {
        id: "strict",
        mode: SharingMode::Strict,
        borrows_idle: false,
        supports_faults: true,
    },
    SharingDef {
        id: "work-conserving",
        mode: SharingMode::WorkConserving,
        borrows_idle: true,
        supports_faults: false,
    },
];

/// Resolve a movement policy by canonical id or alias (the `--scheme`
/// argument, case-insensitive).
pub fn movement(name: &str) -> Option<&'static dyn MovementPolicy> {
    let lower = name.to_ascii_lowercase();
    REGISTRY
        .iter()
        .find(|d| d.id == lower || d.aliases.contains(&lower.as_str()))
        .map(|d| d as &dyn MovementPolicy)
}

/// The registered policy driving `kind`.  Panics if a `SchemeKind`
/// variant was added without a registry entry — the drift test and the
/// first `Machine::new` both catch that immediately.
pub fn movement_for(kind: SchemeKind) -> &'static dyn MovementPolicy {
    REGISTRY
        .iter()
        .find(|d| d.kind == kind)
        .map(|d| d as &dyn MovementPolicy)
        .unwrap_or_else(|| panic!("SchemeKind {kind:?} has no policy::REGISTRY entry"))
}

/// Canonical `--scheme` ids in registry order (what `daemon-sim list`
/// prints and EXPERIMENTS.md documents).
pub fn scheme_ids() -> Vec<&'static str> {
    REGISTRY.iter().map(|d| d.id).collect()
}

/// The registered route implementing `policy`.
#[inline]
pub fn recovery(policy: RecoveryPolicy) -> &'static dyn RecoveryRoute {
    // Indexed, not searched: this sits on the per-request routing path.
    match policy {
        RecoveryPolicy::Stall => &RECOVERY[0],
        RecoveryPolicy::Refetch => &RECOVERY[1],
    }
}

/// Resolve a recovery route by id.
pub fn recovery_by_id(id: &str) -> Option<&'static dyn RecoveryRoute> {
    let lower = id.to_ascii_lowercase();
    RECOVERY
        .iter()
        .find(|d| d.id == lower)
        .map(|d| d as &dyn RecoveryRoute)
}

/// The registered discipline implementing `mode`.
#[inline]
pub fn sharing(mode: SharingMode) -> &'static dyn SharingPolicy {
    match mode {
        SharingMode::Strict => &SHARING[0],
        SharingMode::WorkConserving => &SHARING[1],
    }
}

/// Resolve a sharing discipline by id.
pub fn sharing_by_id(id: &str) -> Option<&'static dyn SharingPolicy> {
    let lower = id.to_ascii_lowercase();
    SHARING
        .iter()
        .find(|d| d.id == lower)
        .map(|d| d as &dyn SharingPolicy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_the_single_source_of_truth() {
        // Ids unique, lowercase, and the CLI round-trip holds.
        for (i, d) in REGISTRY.iter().enumerate() {
            assert!(!d.id.is_empty() && d.id == d.id.to_ascii_lowercase(), "{}", d.id);
            assert!(
                !REGISTRY[..i].iter().any(|p| p.id == d.id),
                "duplicate id {}",
                d.id
            );
            assert!(
                !REGISTRY[..i].iter().any(|p| p.kind == d.kind),
                "duplicate kind {:?}",
                d.kind
            );
            let hit = movement(d.id).expect(d.id);
            assert_eq!(hit.kind(), d.kind);
            for a in d.aliases {
                assert_eq!(movement(a).expect(a).kind(), d.kind, "alias {a}");
                assert!(
                    !REGISTRY.iter().any(|p| p.id == *a),
                    "alias {a} shadows a canonical id"
                );
            }
            assert_eq!(movement_for(d.kind).id(), d.id);
        }
        assert!(movement("nope").is_none());
        assert_eq!(scheme_ids().len(), REGISTRY.len());
    }

    #[test]
    fn recovery_routes_match_their_enum_and_walk_correctly() {
        for d in &RECOVERY {
            assert_eq!(recovery(d.policy).id(), d.id);
            assert_eq!(
                recovery_by_id(d.id).expect(d.id).policy(),
                d.policy
            );
            assert_eq!(d.id, d.policy.name());
        }
        let all_up = |_: usize| true;
        assert_eq!(recovery(RecoveryPolicy::Stall).route(1, 4, &all_up), 1);
        // Stall never consults reachability — historical routing.
        let boom = |_: usize| panic!("stall must not probe ports");
        assert_eq!(recovery(RecoveryPolicy::Stall).route(2, 4, &boom), 2);
        // Refetch walks round-robin from home to the first up port.
        let only_3 = |m: usize| m == 3;
        assert_eq!(recovery(RecoveryPolicy::Refetch).route(1, 4, &only_3), 3);
        // ...and falls back to home when everything is down.
        let none_up = |_: usize| false;
        assert_eq!(recovery(RecoveryPolicy::Refetch).route(1, 4, &none_up), 1);
    }

    #[test]
    fn sharing_capabilities_gate_fault_injection() {
        for d in &SHARING {
            assert_eq!(sharing(d.mode).id(), d.id);
            assert_eq!(sharing_by_id(d.id).expect(d.id).mode(), d.mode);
            assert_eq!(d.id, d.mode.name());
        }
        assert!(sharing(SharingMode::Strict).supports_faults());
        assert!(!sharing(SharingMode::Strict).borrows_idle());
        assert!(!sharing(SharingMode::WorkConserving).supports_faults());
        assert!(sharing(SharingMode::WorkConserving).borrows_idle());
        assert!(sharing_by_id("bogus").is_none());
    }

    #[test]
    fn flags_match_the_documented_technique_stack() {
        // DaeMon = PQ + compression; BP = PQ - selection (§6 ablation).
        let pq = movement("pq").unwrap().flags();
        let dm = movement("daemon").unwrap().flags();
        assert_eq!(Policy { compress: true, ..pq }, dm);
        let bp = movement("bp").unwrap().flags();
        assert_eq!(Policy { selection: true, ..bp }, pq);
    }
}
