//! `variability` — the paper's §6 robustness regime ("high runtime
//! variability in network latencies/bandwidth") opened as a first-class
//! experiment: scheme × sharing-mode × link-condition schedule over the
//! canonical 4-tenant × 2-module cluster.
//!
//! Each cell runs the tenant mix under one [`SharingMode`] and one
//! piecewise [`ScheduleSpec`] (steady, bandwidth bursts, bandwidth +
//! latency bursts).  Reported per cell: aggregate goodput and IPC, the
//! worst per-tenant p99 access cost (tail sensitivity is where adaptive
//! granularity selection shows up), and reclaimed capacity (bytes served
//! on borrowed shares — zero under strict sharing by construction).  A
//! per-phase port-utilization time series rides along for the bursty
//! cells.  Cells batch/shard/merge through the orchestrator like any
//! figure.

use super::cluster::{tenant_cfg, MODULES, TENANT_MIX};
use super::common::Runner;
use super::orchestrator::{CellSpec, Plan};
use crate::config::{ns_to_cycles, ScheduleSpec, SharingMode, SimConfig};
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::table::Table;

/// Page-granularity baseline vs DaeMon — the pair whose p99 gap the
/// bursty schedules are expected to widen.
pub const SCHEMES: [SchemeKind; 2] = [SchemeKind::Pq, SchemeKind::Daemon];

pub const MODES: [SharingMode; 2] = [SharingMode::Strict, SharingMode::WorkConserving];

/// Degraded-phase length: 2 ms, matching Fig. 13/14's disturbance wave.
fn period_cycles() -> f64 {
    ns_to_cycles(2_000_000.0)
}

/// The swept link-condition schedules.  Schedules start degraded at
/// cycle 0 and alternate with nominal phases; past the horizon the link
/// runs nominal.
pub fn schedules() -> Vec<(&'static str, Option<ScheduleSpec>)> {
    let mk = |rate_scale: f64, extra_latency_ns: f64| ScheduleSpec {
        period_cycles: period_cycles(),
        rate_scale,
        extra_latency_ns,
        horizon_cycles: 1e11,
    };
    vec![
        ("steady", None),
        ("bw-burst", Some(mk(0.25, 0.0))),
        ("bw+lat-burst", Some(mk(0.25, 300.0))),
    ]
}

/// One cluster cell of the sweep: the canonical tenant mix, every tenant
/// under `kind`, with the given sharing mode and schedule.
pub fn cell(
    kind: SchemeKind,
    mode: SharingMode,
    sched: Option<ScheduleSpec>,
    cfg: SimConfig,
) -> CellSpec {
    let tenants: Vec<(&str, SchemeKind)> = TENANT_MIX.iter().map(|w| (*w, kind)).collect();
    let mut spec = CellSpec::cluster(&tenants, MODULES, cfg);
    let cl = spec.cluster.as_mut().expect("cluster cell");
    cl.sharing = mode;
    cl.schedule = sched;
    spec
}

/// `variability` — schedule × sharing-mode × scheme sweep, in that cell
/// order (schemes innermost).
pub fn variability_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let scheds = schedules();
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for (sname, sched) in &scheds {
        for &mode in &MODES {
            for &kind in &SCHEMES {
                cells.push(cell(kind, mode, *sched, cfg.clone()));
                labels.push(format!("{}/{}/{}", kind.name(), mode.name(), sname));
            }
        }
    }
    let interval = ns_to_cycles(cfg.interval_ns);
    let assemble = Box::new(move |ms: &[Metrics]| {
        let t = TENANT_MIX.len();
        assert_eq!(ms.len(), labels.len() * t, "variability layout mismatch");
        let cell_ms = |i: usize| &ms[i * t..(i + 1) * t];

        let mut summary = Table::new(
            "Variability: scheme x sharing x schedule, 4 tenants x 2 modules",
            &["cell", "agg-goodput-B/cyc", "agg-IPC", "max-p99-cycles", "reclaimed-MB"],
        );
        for (i, label) in labels.iter().enumerate() {
            let block = cell_ms(i);
            let goodput: f64 = block.iter().map(Metrics::goodput).sum();
            let ipc: f64 = block.iter().map(Metrics::ipc).sum();
            let p99 = block
                .iter()
                .map(Metrics::p99_access_cost)
                .fold(f64::NEG_INFINITY, f64::max);
            let reclaimed: u64 = block.iter().map(|m| m.reclaimed_bytes).sum();
            summary.row_f(label, &[goodput, ipc, p99, reclaimed as f64 / 1e6]);
        }

        // Per-phase mean port utilization for the bw-burst schedule
        // (schedule index 1), one column per scheme x mode, coarsened to
        // 10 buckets like the Fig. 13 series.
        let per_sched = MODES.len() * SCHEMES.len();
        let burst_cells: Vec<usize> = (0..per_sched).map(|k| per_sched + k).collect();
        let tenant_avg = |i: usize| -> Vec<f64> {
            let block = cell_ms(i);
            let len = block.iter().map(|m| m.net_util_series.len()).max().unwrap_or(0);
            let mut avg = vec![0.0f64; len];
            for m in block {
                for (j, v) in m.net_util_series.iter().enumerate() {
                    avg[j] += v;
                }
            }
            avg.iter_mut().for_each(|v| *v /= block.len() as f64);
            avg
        };
        let series: Vec<Vec<f64>> = burst_cells.iter().map(|&i| tenant_avg(i)).collect();
        let mut ts = Table::new(
            &format!(
                "Variability series: mean port utilization under bw-burst \
                 ({}-cycle intervals)",
                interval
            ),
            &[
                "phase",
                "PQ/strict",
                "DaeMon/strict",
                "PQ/work-conserving",
                "DaeMon/work-conserving",
            ],
        );
        let buckets = 10;
        let len = series.iter().map(|s| s.len()).min().unwrap_or(0);
        if len >= buckets {
            let chunk = len / buckets;
            for b in 0..buckets {
                let avg = |v: &Vec<f64>| {
                    let s = &v[b * chunk..(b + 1) * chunk];
                    s.iter().sum::<f64>() / s.len() as f64
                };
                // Cell order within a schedule is modes-outer, schemes
                // inner: [PQ/strict, DaeMon/strict, PQ/wc, DaeMon/wc].
                ts.row_f(
                    &format!("{b}"),
                    &[avg(&series[0]), avg(&series[1]), avg(&series[2]), avg(&series[3])],
                );
            }
        }
        vec![summary, ts]
    });
    Plan { id: "variability".into(), cells, assemble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::orchestrator::{
        self, merge_with_plans, sweep_plans, Shard, ShardData, SweepResult,
    };
    use crate::util::json::Json;
    use crate::workloads::cache::TraceCache;

    #[test]
    fn variability_plan_layout() {
        let r = Runner::test();
        let p = variability_plan(&r);
        assert_eq!(p.cells.len(), schedules().len() * MODES.len() * SCHEMES.len());
        let metrics: usize = p.cells.iter().map(CellSpec::metrics_len).sum();
        assert_eq!(metrics, p.cells.len() * TENANT_MIX.len());
        for c in &p.cells {
            let cl = c.cluster.as_ref().unwrap();
            assert_eq!(cl.modules, MODULES);
            assert_eq!(cl.tenants.len(), TENANT_MIX.len());
        }
        // Steady cells must keep strict/steady defaults where declared.
        assert_eq!(p.cells[0].cluster.as_ref().unwrap().sharing, SharingMode::Strict);
        assert_eq!(p.cells[0].cluster.as_ref().unwrap().schedule, None);
    }

    #[test]
    fn bursty_schedule_costs_cycles() {
        // The bw-burst cell must run no faster than the steady cell for
        // the same scheme/mode (the schedule starts degraded, so short
        // runs sit in a quarter-bandwidth phase).
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let cache = TraceCache::new();
        let sched = schedules();
        let steady = orchestrator::run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Pq, SharingMode::Strict, sched[0].1, cfg.clone()),
        );
        let burst = orchestrator::run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Pq, SharingMode::Strict, sched[1].1, cfg),
        );
        let cyc = |ms: &[Metrics]| ms.iter().map(|m| m.cycles).sum::<f64>();
        assert!(
            cyc(&burst) > cyc(&steady),
            "bursty degradation must cost cycles: {} vs {}",
            cyc(&burst),
            cyc(&steady)
        );
        assert_eq!(
            burst.iter().map(|m| m.instructions).sum::<u64>(),
            steady.iter().map(|m| m.instructions).sum::<u64>()
        );
    }

    /// Reduced 2-cell plan for the shard byte-identity test (full sweep
    /// is CI's job).
    fn mini_plan(r: &Runner) -> Plan {
        let cfg = tenant_cfg(r);
        let sched = schedules()[1].1;
        let cells = vec![
            cell(SchemeKind::Daemon, SharingMode::Strict, sched, cfg.clone()),
            cell(SchemeKind::Daemon, SharingMode::WorkConserving, sched, cfg),
        ];
        let assemble = Box::new(move |ms: &[Metrics]| {
            let mut t = Table::new("variability mini", &["tenant", "goodput"]);
            for (i, m) in ms.iter().enumerate() {
                t.row_f(&format!("{i}"), &[m.goodput()]);
            }
            vec![t]
        });
        Plan { id: "variability_mini".into(), cells, assemble }
    }

    #[test]
    fn variability_cells_shard_byte_identically() {
        let r = Runner::test();
        let ids = vec!["variability_mini".to_string()];
        let full = match sweep_plans(
            vec![mini_plan(&r)],
            &ids,
            &r,
            &TraceCache::new(),
            Shard::full(),
            2,
        )
        .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!("unsharded run produced a shard"),
        };
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let d = match sweep_plans(
                    vec![mini_plan(&r)],
                    &ids,
                    &r,
                    &TraceCache::new(),
                    Shard { index, total: 2 },
                    2,
                )
                .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!("sharded run produced tables"),
                };
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        let merged = merge_with_plans(vec![mini_plan(&r)], &shards).unwrap();
        assert_eq!(
            orchestrator::figures_json(&full).to_string(),
            orchestrator::figures_json(&merged).to_string(),
            "variability cells must shard/merge byte-identically"
        );
    }
}
