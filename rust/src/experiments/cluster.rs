//! Cluster experiments — the multi-tenant scenario axis (§6.7: "pools of
//! processors ... interconnected to pools of memory"), declared as
//! ordinary orchestrator [`Plan`]s so cluster cells batch, shard and
//! merge like any figure.
//!
//! * `cluster_contention` — aggregate throughput as tenants are added to
//!   a fixed pool of shared memory modules, Remote vs DaeMon.
//! * `cluster_fairness` — per-tenant slowdown versus running alone on the
//!   same topology: max slowdown, unfairness index (max/min slowdown) and
//!   per-tenant p99 access cost, Remote vs DaeMon.

use super::common::Runner;
use super::orchestrator::{CellSpec, Plan};
use crate::config::SimConfig;
use crate::metrics::{fairness, Fairness, Metrics};
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::Scale;

/// Canonical tenant mix: one workload per locality class (low / low /
/// high / high compressibility spread).
pub const TENANT_MIX: [&str; 4] = ["pr", "nw", "sp", "hp"];

/// Shared memory-module pool size for the cluster experiments.
pub const MODULES: usize = 2;

/// Tenant counts swept by `cluster_contention`.
pub const TENANT_COUNTS: [usize; 3] = [1, 2, 4];

const SCHEMES: [SchemeKind; 2] = [SchemeKind::Remote, SchemeKind::Daemon];

/// Per-tenant base config scaled to the runner's trace scale (Test-scale
/// traces need the shrunken hierarchy to stay in the footprint ≫ LLC
/// regime the paper evaluates).  Shared with the `variability` cells so
/// both cluster experiment families run the same hierarchy.
pub(super) fn tenant_cfg(r: &Runner) -> SimConfig {
    match r.scale {
        Scale::Test => SimConfig::test_scale(),
        Scale::Paper => SimConfig::default(),
    }
}

/// `cluster_fairness` — 4 tenants × 2 shared memory modules.  For each
/// scheme: 4 solo baseline cells (each tenant alone on the same topology)
/// followed by the shared 4-tenant cell.
pub fn cluster_fairness_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let mut cells = Vec::new();
    for &k in &SCHEMES {
        for wl in TENANT_MIX {
            cells.push(CellSpec::cluster(&[(wl, k)], MODULES, cfg.clone()));
        }
        let tenants: Vec<(&str, SchemeKind)> =
            TENANT_MIX.iter().map(|w| (*w, k)).collect();
        cells.push(CellSpec::cluster(&tenants, MODULES, cfg.clone()));
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let fair = split_fairness(ms);
        let mut summary = Table::new(
            "Cluster fairness: 4 tenants x 2 memory modules, slowdown vs running alone",
            &["scheme", "max-slowdown", "unfairness", "geomean-slowdown"],
        );
        for (k, f) in SCHEMES.iter().zip(&fair) {
            summary.row_f(
                k.name(),
                &[f.max_slowdown, f.unfairness, geomean(&f.slowdowns)],
            );
        }
        let mut detail = Table::new(
            "Cluster fairness per tenant: slowdown / shared-run p99 access cost (cycles)",
            &["tenant", "Remote-slowdown", "DaeMon-slowdown", "Remote-p99", "DaeMon-p99"],
        );
        for (i, wl) in TENANT_MIX.iter().enumerate() {
            detail.row_f(
                wl,
                &[
                    fair[0].slowdowns[i],
                    fair[1].slowdowns[i],
                    fair[0].p99_access_cost[i],
                    fair[1].p99_access_cost[i],
                ],
            );
        }
        vec![summary, detail]
    });
    Plan { id: "cluster_fairness".into(), cells, assemble }
}

/// Split the fairness plan's flattened metrics (per scheme: T solo
/// entries then T shared-tenant entries) into per-scheme [`Fairness`].
pub fn split_fairness(ms: &[Metrics]) -> Vec<Fairness> {
    let t = TENANT_MIX.len();
    let per_scheme = 2 * t;
    assert_eq!(ms.len(), SCHEMES.len() * per_scheme, "fairness layout mismatch");
    SCHEMES
        .iter()
        .enumerate()
        .map(|(s, _)| {
            let block = &ms[s * per_scheme..(s + 1) * per_scheme];
            fairness(&block[..t], &block[t..])
        })
        .collect()
}

/// `cluster_contention` — C ∈ {1,2,4} tenants (cycling the canonical mix)
/// over 2 shared memory modules, Remote vs DaeMon aggregate throughput.
pub fn cluster_contention_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let mut cells = Vec::new();
    for &n in &TENANT_COUNTS {
        for &k in &SCHEMES {
            let tenants: Vec<(&str, SchemeKind)> = (0..n)
                .map(|i| (TENANT_MIX[i % TENANT_MIX.len()], k))
                .collect();
            cells.push(CellSpec::cluster(&tenants, MODULES, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let mut table = Table::new(
            "Cluster contention: aggregate IPC over 2 shared memory modules",
            &["tenants", "Remote-sum-IPC", "DaeMon-sum-IPC", "DaeMon/Remote", "DaeMon-min-IPC"],
        );
        let mut off = 0;
        for &n in &TENANT_COUNTS {
            let remote = &ms[off..off + n];
            off += n;
            let daemon = &ms[off..off + n];
            off += n;
            let rs: f64 = remote.iter().map(Metrics::ipc).sum();
            let ds: f64 = daemon.iter().map(Metrics::ipc).sum();
            let dmin = daemon.iter().map(Metrics::ipc).fold(f64::INFINITY, f64::min);
            table.row_f(&format!("{n}"), &[rs, ds, ds / rs.max(1e-12), dmin]);
        }
        assert_eq!(off, ms.len());
        vec![table]
    });
    Plan { id: "cluster_contention".into(), cells, assemble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::orchestrator;

    #[test]
    fn fairness_plan_layout() {
        let r = Runner::test();
        let p = cluster_fairness_plan(&r);
        // Per scheme: 4 solo cells + 1 shared cell.
        assert_eq!(p.cells.len(), 2 * (TENANT_MIX.len() + 1));
        let metrics: usize = p.cells.iter().map(CellSpec::metrics_len).sum();
        assert_eq!(metrics, 2 * 2 * TENANT_MIX.len());
    }

    #[test]
    fn daemon_max_slowdown_beats_remote() {
        // Acceptance criterion: with 4 tenants contending on 2 shared
        // memory modules, DaeMon's worst-tenant slowdown (vs running
        // alone) must be strictly below the Remote baseline's.
        let r = Runner::test();
        let plan = cluster_fairness_plan(&r);
        let ms = orchestrator::run_plan_metrics(&r, &plan.cells);
        let fair = split_fairness(&ms);
        let (remote, daemon) = (&fair[0], &fair[1]);
        assert!(
            daemon.max_slowdown < remote.max_slowdown,
            "DaeMon max slowdown {} !< Remote {}",
            daemon.max_slowdown,
            remote.max_slowdown
        );
        // Contention can only hurt: every tenant runs no faster shared
        // than alone (small tolerance for metric noise).
        for f in &fair {
            for &s in &f.slowdowns {
                assert!(s > 0.99, "slowdown below 1: {s}");
            }
            assert!(f.unfairness >= 1.0);
        }
    }

    #[test]
    fn contention_scales_and_daemon_wins() {
        let r = Runner::test();
        let plan = cluster_contention_plan(&r);
        let tables = orchestrator::run_plan(&r, plan);
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), TENANT_COUNTS.len());
        for row in rows {
            let remote: f64 = row[1].parse().unwrap();
            let daemon: f64 = row[2].parse().unwrap();
            assert!(remote > 0.0 && daemon > 0.0);
            assert!(
                daemon > remote,
                "DaeMon aggregate {daemon} !> Remote {remote} at {} tenants",
                row[0]
            );
        }
    }
}
