//! `adaptive` — the closed control loop evaluated against every static
//! configuration it generalizes: {static PQ, static DaeMon, each
//! single-knob static setting, closed loop} × a disturbance grid
//! (steady, bandwidth bursts, bandwidth + latency bursts, module
//! crash), over the canonical 4-tenant × 2-module cluster.
//!
//! The headline figure: the closed loop (all three control laws of
//! [`crate::policy::adaptive`] at one epoch cadence) matches the best
//! static arm in every disturbance cell and strictly beats every static
//! arm where conditions actually vary — no single static knob setting
//! wins both the degraded and the nominal phases, while the controller
//! retunes between them.  The static single-knob arms sit exactly on
//! the `ratio-tune` law's registry-declared bounds, so the sweep also
//! demonstrates that the controller's actuation range covers the static
//! design space.  Cells batch/shard/merge through the orchestrator like
//! any figure.

use super::cluster::{tenant_cfg, MODULES, TENANT_MIX};
use super::common::Runner;
use super::orchestrator::{CellSpec, Plan};
use super::resilience::crash_window;
use crate::config::{ns_to_cycles, ControllerSpec, ScheduleSpec, SharingMode, SimConfig};
use crate::metrics::Metrics;
use crate::policy::adaptive::control_law;
use crate::schemes::SchemeKind;
use crate::system::fault::{FaultPlan, RecoveryPolicy};
use crate::util::table::Table;

/// Controller observation/actuation cadence: well under the 2 ms burst
/// period (hundreds of epochs per phase), well over per-access noise.
pub const EPOCH_CYCLES: f64 = 25_000.0;

/// One disturbance-grid condition: link schedule and/or fault plan.
pub fn conditions() -> Vec<(&'static str, Option<ScheduleSpec>, Option<FaultPlan>)> {
    let mk = |rate_scale: f64, extra_latency_ns: f64| ScheduleSpec {
        period_cycles: ns_to_cycles(2_000_000.0),
        rate_scale,
        extra_latency_ns,
        horizon_cycles: 1e11,
    };
    let (from, to) = crash_window();
    vec![
        ("steady", None, None),
        ("bw-burst", Some(mk(0.25, 0.0)), None),
        ("bw+lat-burst", Some(mk(0.25, 300.0)), None),
        ("module-crash", None, Some(FaultPlan::new().module_crash(1, from, to))),
    ]
}

/// One configuration arm of the sweep.
#[derive(Clone, Copy)]
pub struct Arm {
    pub name: &'static str,
    pub kind: SchemeKind,
    /// Static §4.1 partition-ratio override (`None` = scheme default).
    pub ratio: Option<f64>,
    /// Run work-conserving where legal (faulted cells require strict).
    pub work_conserving: bool,
    pub recovery: RecoveryPolicy,
    /// Attach the closed-loop controller (all three laws).
    pub closed_loop: bool,
}

/// The swept arms: two full-static baselines, one static arm per control
/// knob (the ratio arms sit exactly on the `ratio-tune` law's bounds),
/// and the closed loop.  The closed loop gets every knob the statics
/// get — work-conserving sharing where legal, strict under faults — so
/// wins come from feedback, not from a capability gap.
pub fn arms() -> Vec<Arm> {
    let ratio = control_law("ratio-tune").expect("registered law");
    let stat = |name, kind| Arm {
        name,
        kind,
        ratio: None,
        work_conserving: false,
        recovery: RecoveryPolicy::Stall,
        closed_loop: false,
    };
    vec![
        stat("pq", SchemeKind::Pq),
        stat("daemon", SchemeKind::Daemon),
        Arm { name: "daemon/ratio-lo", ratio: Some(ratio.min), ..stat("", SchemeKind::Daemon) },
        Arm { name: "daemon/ratio-hi", ratio: Some(ratio.max), ..stat("", SchemeKind::Daemon) },
        Arm {
            name: "daemon/refetch",
            recovery: RecoveryPolicy::Refetch,
            ..stat("", SchemeKind::Daemon)
        },
        Arm { name: "daemon/wc", work_conserving: true, ..stat("", SchemeKind::Daemon) },
        Arm {
            name: "closed-loop",
            work_conserving: true,
            closed_loop: true,
            ..stat("", SchemeKind::Daemon)
        },
    ]
}

/// The `daemon/wc` static arm duplicates `daemon` exactly in faulted
/// cells (faults require strict sharing), so the grid drops it there.
pub fn arm_runs_in(arm: &Arm, faulted: bool) -> bool {
    !(faulted && arm.name == "daemon/wc")
}

/// One cluster cell: the canonical tenant mix under `arm`, with the
/// given link schedule and fault plan.
pub fn cell(
    arm: &Arm,
    sched: Option<ScheduleSpec>,
    faults: Option<FaultPlan>,
    mut cfg: SimConfig,
) -> CellSpec {
    if let Some(ratio) = arm.ratio {
        cfg.daemon.partition_ratio = ratio;
    }
    let tenants: Vec<(&str, SchemeKind)> = TENANT_MIX.iter().map(|w| (*w, arm.kind)).collect();
    let mut spec = CellSpec::cluster(&tenants, MODULES, cfg);
    let cl = spec.cluster.as_mut().expect("cluster cell");
    let faulted = faults.is_some();
    cl.schedule = sched;
    cl.faults = faults;
    cl.recovery = arm.recovery;
    cl.sharing = if arm.work_conserving && !faulted {
        SharingMode::WorkConserving
    } else {
        SharingMode::Strict
    };
    if arm.closed_loop {
        cl.controller = Some(ControllerSpec::all(EPOCH_CYCLES));
    }
    spec
}

/// `adaptive` — condition × arm grid (arms innermost; `daemon/wc`
/// dropped in faulted conditions).
pub fn adaptive_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for (cname, sched, faults) in conditions() {
        for arm in arms() {
            if !arm_runs_in(&arm, faults.is_some()) {
                continue;
            }
            cells.push(cell(&arm, sched, faults.clone(), cfg.clone()));
            labels.push((cname, arm.name, arm.closed_loop));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let t = TENANT_MIX.len();
        assert_eq!(ms.len(), labels.len() * t, "adaptive layout mismatch");
        let cell_ms = |i: usize| &ms[i * t..(i + 1) * t];
        let goodput = |i: usize| cell_ms(i).iter().map(Metrics::goodput).sum::<f64>();

        let mut table = Table::new(
            "Adaptive: condition x configuration, 4 tenants x 2 modules",
            &["cell", "agg-goodput-B/cyc", "agg-IPC", "max-p99-cycles", "actuations"],
        );
        for (i, (cname, aname, _)) in labels.iter().enumerate() {
            let block = cell_ms(i);
            let ipc: f64 = block.iter().map(Metrics::ipc).sum();
            let p99 = block
                .iter()
                .map(Metrics::p99_access_cost)
                .fold(f64::NEG_INFINITY, f64::max);
            let acts: u64 = block.iter().map(|m| m.controller_actuations).sum();
            table.row_f(
                &format!("{cname}/{aname}"),
                &[goodput(i), ipc, p99, acts as f64],
            );
        }

        // The acceptance figure: per condition, closed loop vs the best
        // static arm on aggregate goodput.
        let mut verdict = Table::new(
            "Adaptive verdict: closed loop vs best static, per condition",
            &["condition", "closed-goodput", "best-static-goodput", "closed/static"],
        );
        let mut i = 0;
        while i < labels.len() {
            let cname = labels[i].0;
            let mut closed = f64::NAN;
            let mut best = f64::NEG_INFINITY;
            while i < labels.len() && labels[i].0 == cname {
                if labels[i].2 {
                    closed = goodput(i);
                } else {
                    best = best.max(goodput(i));
                }
                i += 1;
            }
            verdict.row_f(cname, &[closed, best, closed / best]);
        }
        vec![table, verdict]
    });
    Plan { id: "adaptive".into(), cells, assemble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::orchestrator::{
        self, merge_with_plans, run_plan_metrics, sweep_plans, Shard, ShardData,
        SweepResult,
    };
    use crate::util::json::Json;
    use crate::workloads::cache::TraceCache;

    #[test]
    fn adaptive_plan_layout() {
        let r = Runner::test();
        let p = adaptive_plan(&r);
        // 4 conditions x 7 arms, minus the wc arm in the faulted cell.
        assert_eq!(p.cells.len(), 4 * arms().len() - 1);
        let metrics: usize = p.cells.iter().map(CellSpec::metrics_len).sum();
        assert_eq!(metrics, p.cells.len() * TENANT_MIX.len());
        // Only closed-loop cells carry a controller, and it is live.
        let with_ctl = p
            .cells
            .iter()
            .filter(|c| c.cluster.as_ref().unwrap().controller.is_some())
            .count();
        assert_eq!(with_ctl, conditions().len(), "one closed-loop cell per condition");
        for c in &p.cells {
            let cl = c.cluster.as_ref().unwrap();
            if let Some(spec) = cl.controller {
                assert!(!spec.is_inert());
            }
            if cl.faults.is_some() {
                assert_eq!(cl.sharing, SharingMode::Strict, "faults require strict");
            }
        }
    }

    #[test]
    fn static_ratio_arms_sit_on_the_law_bounds() {
        let law = control_law("ratio-tune").unwrap();
        let arms = arms();
        let lo = arms.iter().find(|a| a.name == "daemon/ratio-lo").unwrap();
        let hi = arms.iter().find(|a| a.name == "daemon/ratio-hi").unwrap();
        assert_eq!(lo.ratio, Some(law.min));
        assert_eq!(hi.ratio, Some(law.max));
        let cfg = SimConfig::test_scale();
        let spec = cell(hi, None, None, cfg);
        assert_eq!(spec.cfg.daemon.partition_ratio, law.max, "ratio override plumbed");
    }

    /// The acceptance criterion: on aggregate goodput the closed loop is
    /// at least as good as every static configuration in every
    /// disturbance cell, and strictly better where conditions vary
    /// (bw-burst) and where a module crashes.
    #[test]
    fn closed_loop_beats_every_static_configuration() {
        let r = Runner::test();
        let p = adaptive_plan(&r);
        // Rebuild the same labeling the plan used.
        let mut labels = Vec::new();
        for (cname, _, faults) in conditions() {
            for arm in arms() {
                if arm_runs_in(&arm, faults.is_some()) {
                    labels.push((cname, arm.name, arm.closed_loop));
                }
            }
        }
        let ms = run_plan_metrics(&r, &p.cells);
        let t = TENANT_MIX.len();
        assert_eq!(ms.len(), labels.len() * t);
        let goodput =
            |i: usize| ms[i * t..(i + 1) * t].iter().map(Metrics::goodput).sum::<f64>();
        for cond in ["steady", "bw-burst", "bw+lat-burst", "module-crash"] {
            let idx: Vec<usize> =
                (0..labels.len()).filter(|&i| labels[i].0 == cond).collect();
            let closed = idx
                .iter()
                .copied()
                .find(|&i| labels[i].2)
                .map(goodput)
                .expect("closed-loop cell present");
            for &i in idx.iter().filter(|&&i| !labels[i].2) {
                let s = goodput(i);
                assert!(
                    closed >= s,
                    "{cond}: closed loop {closed} lost to static {} {s}",
                    labels[i].1
                );
                if cond == "bw-burst" || cond == "module-crash" {
                    assert!(
                        closed > s,
                        "{cond}: closed loop {closed} must strictly beat static {} {s}",
                        labels[i].1
                    );
                }
            }
            // The closed loop actually closed the loop where it won.
            let acts: u64 = idx
                .iter()
                .copied()
                .filter(|&i| labels[i].2)
                .flat_map(|i| ms[i * t..(i + 1) * t].iter())
                .map(|m| m.controller_actuations)
                .sum();
            if cond != "steady" {
                assert!(acts > 0, "{cond}: closed-loop cell never actuated");
            }
        }
    }

    /// Reduced 2-cell plan for the shard byte-identity test (the full
    /// sweep rides CI's 2-shard merge check).
    fn mini_plan(r: &Runner) -> Plan {
        let cfg = tenant_cfg(r);
        let (_, sched, _) = conditions().remove(1);
        let all = arms();
        let closed = *all.iter().find(|a| a.closed_loop).unwrap();
        let daemon = *all.iter().find(|a| a.name == "daemon").unwrap();
        let cells = vec![
            cell(&daemon, sched, None, cfg.clone()),
            cell(&closed, sched, None, cfg),
        ];
        let assemble = Box::new(move |ms: &[Metrics]| {
            let mut t = Table::new("adaptive mini", &["tenant", "goodput", "actuations"]);
            for (i, m) in ms.iter().enumerate() {
                t.row_f(&format!("{i}"), &[m.goodput(), m.controller_actuations as f64]);
            }
            vec![t]
        });
        Plan { id: "adaptive_mini".into(), cells, assemble }
    }

    #[test]
    fn adaptive_cells_shard_byte_identically() {
        let r = Runner::test();
        let ids = vec!["adaptive_mini".to_string()];
        let full = match sweep_plans(
            vec![mini_plan(&r)],
            &ids,
            &r,
            &TraceCache::new(),
            Shard::full(),
            2,
        )
        .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!("unsharded run produced a shard"),
        };
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let d = match sweep_plans(
                    vec![mini_plan(&r)],
                    &ids,
                    &r,
                    &TraceCache::new(),
                    Shard { index, total: 2 },
                    2,
                )
                .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!("sharded run produced tables"),
                };
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        let merged = merge_with_plans(vec![mini_plan(&r)], &shards).unwrap();
        assert_eq!(
            orchestrator::figures_json(&full).to_string(),
            orchestrator::figures_json(&merged).to_string(),
            "adaptive cells must shard/merge byte-identically"
        );
    }
}
