//! `tail_latency` — the request-serving SLO grid: scheme × arrival
//! pattern × load factor × robustness stack over the 2-server × 2-module
//! service cluster ([`crate::system::frontend`]).
//!
//! The headline figure: under overload (load factor ≫ 1) with a
//! mid-run module crash, the full robustness stack (deadline + retry +
//! hedge + shed) strictly beats naive wait-forever serving on both
//! goodput-under-SLO and p99 request latency — shedding refuses work
//! the servers cannot serve within the deadline, so the requests that
//! *are* admitted complete promptly, while the naive queue grows
//! without bound and drags every percentile with it.
//!
//! **Self-calibration.**  Absolute cycle knobs (deadline, watermark,
//! inter-arrival gap) would silently change meaning whenever trace
//! scale, burst size, or the memory hierarchy moves.  Instead the plan
//! first runs a tiny uncontended probe per scheme (fixed seed, huge
//! arrival gap, the naive stack) and measures the per-attempt service
//! time `s` from the request histogram; every knob is then a fixed
//! multiple of the measured `s`, and the request count scales with the
//! load factor so the arrival horizon is the same at every load.  The
//! probe rides the global trace cache and a pinned seed, so plan
//! construction — which also happens at shard-merge time — is
//! deterministic given (scale, max-accesses), keeping sharded sweeps
//! byte-identical to unsharded ones.

use super::cluster::{tenant_cfg, MODULES};
use super::common::Runner;
use super::orchestrator::{CellSpec, Plan};
use crate::config::{ArrivalPattern, ClusterConfig, ServiceSpec, SimConfig};
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::system::fault::FaultPlan;
use crate::system::frontend;
use crate::util::table::Table;
use crate::workloads::cache::TraceCache;

/// Arrival-rate multipliers swept per cell (1.0 = matched to the
/// calibrated service rate at ~50% utilization; the top entry is firm
/// overload under any calibration error).
pub const LOADS: [f64; 3] = [0.5, 2.0, 8.0];

/// Robustness stacks, layered: `naive` waits forever, `retry` adds
/// deadlines + bounded exponential backoff, `full` adds hedged second
/// issues and admission-control shedding on top.
pub const STACKS: [&str; 3] = ["naive", "retry", "full"];

pub const SCHEMES: [SchemeKind; 2] = [SchemeKind::Pq, SchemeKind::Daemon];

/// Servers in every service cell (labels only — request classes map to
/// their own base workloads).
pub const SERVERS: usize = 2;
const SERVER_MIX: [&str; SERVERS] = ["pr", "sp"];

/// Calibration probe: enough completions to fill the attempt histogram,
/// spaced far enough apart that no two bursts ever queue.
const PROBE_REQUESTS: usize = 32;
const PROBE_GAP: f64 = 1e7;
const PROBE_SEED: u64 = 0xCA11B;

/// Knob multiples of the calibrated service time (`s_med` = probe
/// median): per-attempt deadline, shedding watermark, SLO, backoff cap.
/// SLO = watermark + deadline, so a request admitted right at the
/// watermark can still finish a clean first attempt inside the SLO.
pub const TIMEOUT_X: f64 = 10.0;
pub const WATERMARK_X: f64 = 4.0;
pub const SLO_X: f64 = TIMEOUT_X + WATERMARK_X;
pub const BACKOFF_CAP_X: f64 = 4.0;
pub const MAX_RETRIES: u32 = 2;
pub const JITTER_FRAC: f64 = 0.25;
pub const HEDGE_PCT: f64 = 0.95;

/// (requests at load 1.0, accesses per burst), shrunk in quick/test
/// runs (`--max-accesses` below 1M) where the full grid would dominate
/// the smoke sweep.
pub fn scale_knobs(r: &Runner) -> (usize, usize) {
    if r.max_accesses > 0 && r.max_accesses < 1_000_000 {
        (40, 200)
    } else {
        (120, 800)
    }
}

/// Probe-measured per-attempt service time (cycles) for one scheme.
#[derive(Clone, Copy, Debug)]
pub struct Calib {
    pub s_mean: f64,
    pub s_med: f64,
}

/// Run the uncontended probe and read the attempt-latency distribution
/// off the request histogram.  Uses the same `ClusterConfig`
/// construction as the grid cells (`run_cell_spec_obs`), so the probe
/// measures exactly what the cells will see.
pub fn calibrate(r: &Runner, kind: SchemeKind, burst: usize, cfg: &SimConfig) -> Calib {
    let mut ccfg = ClusterConfig::new(MODULES);
    ccfg.net = cfg.net[0];
    let spec =
        ServiceSpec::naive(ArrivalPattern::Steady, PROBE_REQUESTS, burst, PROBE_GAP, 1.0, PROBE_GAP)
            .with_seed(PROBE_SEED);
    let tenants: Vec<(String, SchemeKind)> =
        SERVER_MIX.iter().map(|w| (w.to_string(), kind)).collect();
    let cache = TraceCache::global();
    let ms = frontend::run_service(&ccfg, cfg, &tenants, &spec, |wl| {
        cache.get(wl, r.scale, cfg.seed, r.max_accesses)
    });
    let h = &ms[0].request_hist;
    Calib { s_mean: h.mean().max(1.0), s_med: h.value_at(0.5).max(1.0) }
}

/// The spec for one (stack, pattern, load) cell.  `requests` scales
/// with the load factor so the arrival horizon (`requests x gap` =
/// `base_req x s_mean`) is identical at every load.
pub fn service_spec(
    stack: &str,
    pattern: ArrivalPattern,
    load: f64,
    base_req: usize,
    burst: usize,
    c: &Calib,
) -> ServiceSpec {
    let requests = ((base_req as f64) * load).round().max(1.0) as usize;
    let mut s =
        ServiceSpec::naive(pattern, requests, burst, c.s_mean, load, SLO_X * c.s_med);
    if stack != "naive" {
        s = s.with_retry(
            TIMEOUT_X * c.s_med,
            MAX_RETRIES,
            c.s_med,
            BACKOFF_CAP_X * c.s_med,
            JITTER_FRAC,
        );
    }
    if stack == "full" {
        s = s.with_hedge(HEDGE_PCT).with_shed(WATERMARK_X * c.s_med);
    }
    s
}

/// The swept arrival conditions; the crash window sits inside the
/// (load-invariant) arrival horizon, so every load level takes the
/// same mid-run outage.
pub fn conditions(horizon: f64) -> Vec<(&'static str, ArrivalPattern, Option<FaultPlan>)> {
    vec![
        ("steady", ArrivalPattern::Steady, None),
        ("bursty", ArrivalPattern::Bursty, None),
        ("diurnal", ArrivalPattern::Diurnal, None),
        (
            "bursty-crash",
            ArrivalPattern::Bursty,
            Some(FaultPlan::new().module_crash(0, 0.1 * horizon, 0.3 * horizon)),
        ),
    ]
}

/// One service cell: the 2-server cluster under `kind`, serving `spec`.
pub fn cell(
    kind: SchemeKind,
    spec: ServiceSpec,
    faults: Option<FaultPlan>,
    cfg: SimConfig,
) -> CellSpec {
    let tenants: Vec<(&str, SchemeKind)> = SERVER_MIX.iter().map(|w| (*w, kind)).collect();
    let mut cs = CellSpec::cluster(&tenants, MODULES, cfg);
    let cl = cs.cluster.as_mut().expect("cluster cell");
    cl.faults = faults;
    cl.service = Some(spec);
    cs
}

/// `tail_latency` — scheme × condition × load × stack grid (stacks
/// innermost), one calibration probe per scheme.
pub fn tail_latency_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let (base_req, burst) = scale_knobs(r);
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for kind in SCHEMES {
        let c = calibrate(r, kind, burst, &cfg);
        let horizon = base_req as f64 * c.s_mean;
        for (cname, pattern, faults) in conditions(horizon) {
            for load in LOADS {
                for stack in STACKS {
                    let spec = service_spec(stack, pattern, load, base_req, burst, &c);
                    cells.push(cell(kind, spec, faults.clone(), cfg.clone()));
                    labels.push((kind.name(), cname, load, stack));
                }
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        assert_eq!(ms.len(), labels.len() * SERVERS, "tail_latency layout mismatch");
        // The request ledger lands on each cell's front server.
        let front = |i: usize| &ms[i * SERVERS];

        let mut table = Table::new(
            "Tail latency: scheme x arrival x load x stack, 2 servers x 2 modules",
            &[
                "cell",
                "offered",
                "completed",
                "timed-out",
                "shed",
                "retries",
                "hedge-wins",
                "slo-goodput",
                "p99-cyc",
                "p999-cyc",
            ],
        );
        for (i, (scheme, cond, load, stack)) in labels.iter().enumerate() {
            let m = front(i);
            table.row_f(
                &format!("{scheme}/{cond}/x{load}/{stack}"),
                &[
                    m.requests_offered() as f64,
                    m.requests_completed as f64,
                    m.requests_timed_out as f64,
                    m.requests_shed as f64,
                    m.request_retries as f64,
                    m.request_hedge_wins as f64,
                    m.slo_goodput(),
                    m.p99_request(),
                    m.p999_request(),
                ],
            );
        }

        // The acceptance figure: full stack vs naive at the top load
        // factor, per scheme x condition (stacks are innermost, so the
        // full row sits two slots after its naive row).
        let top = LOADS[LOADS.len() - 1];
        let mut verdict = Table::new(
            "Tail-latency verdict: full stack vs naive at the highest load",
            &["cell", "naive-goodput", "full-goodput", "naive-p99", "full-p99"],
        );
        for (i, l) in labels.iter().enumerate() {
            if l.3 != "naive" || l.2 != top {
                continue;
            }
            assert_eq!(labels[i + 2].3, "full", "stack ordering drifted");
            let (n, f) = (front(i), front(i + 2));
            verdict.row_f(
                &format!("{}/{}", l.0, l.1),
                &[n.slo_goodput(), f.slo_goodput(), n.p99_request(), f.p99_request()],
            );
        }
        vec![table, verdict]
    });
    Plan { id: "tail_latency".into(), cells, assemble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingMode;
    use crate::experiments::orchestrator::{
        self, merge_with_plans, run_plan_metrics, sweep_plans, Shard, ShardData, SweepResult,
    };
    use crate::util::json::Json;

    #[test]
    fn tail_latency_plan_layout() {
        let r = Runner::test();
        let p = tail_latency_plan(&r);
        assert_eq!(p.cells.len(), SCHEMES.len() * 4 * LOADS.len() * STACKS.len());
        let (base_req, burst) = scale_knobs(&r);
        for (j, cs) in p.cells.iter().enumerate() {
            let cl = cs.cluster.as_ref().expect("service cells are cluster cells");
            let svc = cl.service.expect("every tail_latency cell serves requests");
            assert_eq!(cl.tenants.len(), SERVERS);
            assert_eq!(svc.burst_accesses, burst);
            if cl.faults.is_some() {
                assert_eq!(cl.sharing, SharingMode::Strict, "faults require strict");
            }
            // Requests scale with load (fixed arrival horizon) and the
            // stacks layer in declaration order.
            let load = LOADS[(j / STACKS.len()) % LOADS.len()];
            assert_eq!(svc.requests, ((base_req as f64) * load).round() as usize);
            assert_eq!(svc.load, load);
            match STACKS[j % STACKS.len()] {
                "naive" => assert!(!svc.has_timeouts() && !svc.has_hedge() && !svc.has_shed()),
                "retry" => assert!(svc.has_timeouts() && !svc.has_hedge() && !svc.has_shed()),
                _ => assert!(svc.has_timeouts() && svc.has_hedge() && svc.has_shed()),
            }
        }
        // One crash condition per scheme, sitting inside the horizon.
        let crashed = p
            .cells
            .iter()
            .filter(|c| c.cluster.as_ref().unwrap().faults.is_some())
            .count();
        assert_eq!(crashed, SCHEMES.len() * LOADS.len() * STACKS.len());
    }

    #[test]
    fn calibration_is_deterministic_and_positive() {
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let (_, burst) = scale_knobs(&r);
        let a = calibrate(&r, SchemeKind::Daemon, burst, &cfg);
        let b = calibrate(&r, SchemeKind::Daemon, burst, &cfg);
        assert_eq!(a.s_mean.to_bits(), b.s_mean.to_bits(), "probe replay diverged");
        assert_eq!(a.s_med.to_bits(), b.s_med.to_bits());
        assert!(a.s_mean > 1.0 && a.s_med > 1.0, "a burst takes real cycles");
        // Markov (median <= 2 x mean) plus the histogram's factor-2
        // bucket error bound the median from above; the mean side is
        // unbounded for skewed class mixes, so only this direction pins.
        assert!(a.s_med < 4.0 * a.s_mean);
    }

    /// The acceptance criterion: at the highest load factor under the
    /// bursty + crash condition, the full robustness stack strictly
    /// beats naive wait-forever serving on goodput-under-SLO and p99
    /// request latency for the DaeMon scheme.  Run at a larger request
    /// count than the reported grid so the margin is structural, not
    /// statistical: the naive queue grows without bound (only the first
    /// ~SLO/(rho-1) cycles of arrivals can make the deadline) while the
    /// shedding stack keeps every admitted request's latency bounded by
    /// watermark + deadline-chain.
    #[test]
    fn full_stack_beats_naive_at_peak_overload_with_crash() {
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let (_, burst) = scale_knobs(&r);
        let c = calibrate(&r, SchemeKind::Daemon, burst, &cfg);
        let base_req = 400;
        let load = LOADS[LOADS.len() - 1];
        let horizon = base_req as f64 * c.s_mean;
        let faults = FaultPlan::new().module_crash(0, 0.1 * horizon, 0.3 * horizon);
        let cells = vec![
            cell(
                SchemeKind::Daemon,
                service_spec("naive", ArrivalPattern::Bursty, load, base_req, burst, &c),
                Some(faults.clone()),
                cfg.clone(),
            ),
            cell(
                SchemeKind::Daemon,
                service_spec("full", ArrivalPattern::Bursty, load, base_req, burst, &c),
                Some(faults),
                cfg,
            ),
        ];
        let ms = run_plan_metrics(&r, &cells);
        assert_eq!(ms.len(), 2 * SERVERS);
        let (naive, full) = (&ms[0], &ms[SERVERS]);
        let offered = (base_req as f64 * load).round() as u64;
        assert_eq!(naive.requests_offered(), offered);
        assert_eq!(full.requests_offered(), offered);
        assert_eq!(naive.requests_timed_out + naive.requests_shed, 0, "naive never gives up");
        assert!(full.requests_shed > 0, "overload + crash must trip admission control");
        assert!(
            full.slo_goodput() > naive.slo_goodput(),
            "full-stack goodput {} must strictly beat naive {}",
            full.slo_goodput(),
            naive.slo_goodput()
        );
        assert!(
            full.p99_request() < naive.p99_request(),
            "full-stack p99 {} must sit strictly below naive {}",
            full.p99_request(),
            naive.p99_request()
        );
    }

    /// Reduced 2-cell plan for the shard byte-identity test (the full
    /// sweep rides CI's 2-shard merge check).
    fn mini_plan(r: &Runner) -> Plan {
        let cfg = tenant_cfg(r);
        let (base_req, burst) = scale_knobs(r);
        let c = calibrate(r, SchemeKind::Daemon, burst, &cfg);
        let horizon = base_req as f64 * c.s_mean;
        let faults = FaultPlan::new().module_crash(0, 0.1 * horizon, 0.3 * horizon);
        let cells = vec![
            cell(
                SchemeKind::Daemon,
                service_spec("naive", ArrivalPattern::Steady, 2.0, base_req, burst, &c),
                None,
                cfg.clone(),
            ),
            cell(
                SchemeKind::Daemon,
                service_spec("full", ArrivalPattern::Bursty, 8.0, base_req, burst, &c),
                Some(faults),
                cfg,
            ),
        ];
        let assemble = Box::new(move |ms: &[Metrics]| {
            let mut t = Table::new("tail_latency mini", &["server", "completed", "p99"]);
            for (i, m) in ms.iter().enumerate() {
                t.row_f(&format!("{i}"), &[m.requests_completed as f64, m.p99_request()]);
            }
            vec![t]
        });
        Plan { id: "tail_latency_mini".into(), cells, assemble }
    }

    #[test]
    fn service_cells_shard_byte_identically() {
        let r = Runner::test();
        let ids = vec!["tail_latency_mini".to_string()];
        let full = match sweep_plans(
            vec![mini_plan(&r)],
            &ids,
            &r,
            &TraceCache::new(),
            Shard::full(),
            2,
        )
        .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!("unsharded run produced a shard"),
        };
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let d = match sweep_plans(
                    vec![mini_plan(&r)],
                    &ids,
                    &r,
                    &TraceCache::new(),
                    Shard { index, total: 2 },
                    2,
                )
                .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!("sharded run produced tables"),
                };
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        let merged = merge_with_plans(vec![mini_plan(&r)], &shards).unwrap();
        assert_eq!(
            orchestrator::figures_json(&full).to_string(),
            orchestrator::figures_json(&merged).to_string(),
            "service cells must shard/merge byte-identically"
        );
    }
}
