//! `resilience` — the "independent failure-isolated components" claim
//! (§1, §4.6) opened as a first-class experiment: scheme × fault pattern
//! × recovery policy over the canonical 4-tenant × 2-module cluster.
//!
//! Fault patterns: a single-module crash (module 1 down for one window —
//! its ports and DRAM engine lose in-flight work and refuse new work),
//! periodic link flaps on one tenant's port to module 0 (isolation: the
//! other three tenants must be untouched), and a tenant kill (the
//! compute component dies; survivors must reproduce their no-fault
//! numbers).  Each pattern runs under both [`RecoveryPolicy`]s — stall
//! until recovery vs re-fetch from the surviving module — against a
//! no-fault baseline cell per scheme.  Refetch routing is decided at
//! issue time (failure detection is not retroactive), so work already
//! dispatched when a window opens still pays the defer/abort cost; only
//! requests issued during an observed outage route around it.
//! Reported per cell: aggregate IPC,
//! the worst per-tenant slowdown versus the same scheme's no-fault run,
//! port downtime, and aborted/deferred request counts.  Cells
//! batch/shard/merge through the orchestrator like any figure.

use super::cluster::{tenant_cfg, MODULES, TENANT_MIX};
use super::common::Runner;
use super::orchestrator::{CellSpec, Plan};
use crate::config::{ns_to_cycles, SimConfig};
use crate::metrics::{slowdown, Metrics};
use crate::schemes::SchemeKind;
use crate::system::fault::{FaultPlan, RecoveryPolicy};
use crate::util::table::Table;

/// Page-granularity baseline vs DaeMon — the expected headline is that
/// DaeMon's worst-tenant slowdown under a single-module crash stays well
/// below Remote's (cache-line fallback keeps cores fed while pages
/// re-route or wait).
pub const SCHEMES: [SchemeKind; 2] = [SchemeKind::Remote, SchemeKind::Daemon];

pub const POLICIES: [RecoveryPolicy; 2] = [RecoveryPolicy::Stall, RecoveryPolicy::Refetch];

/// Module-crash window: module 1 dies at 0.2 Mcycles and recovers 0.5 ms
/// later — early enough to hit even tiny smoke runs, long enough to
/// dominate a stalled tenant's critical path.
pub fn crash_window() -> (f64, f64) {
    let from = 2e5;
    (from, from + ns_to_cycles(500_000.0))
}

/// The swept fault patterns over the 4-tenant × 2-module cluster.
pub fn fault_patterns() -> Vec<(&'static str, FaultPlan)> {
    let (from, to) = crash_window();
    vec![
        ("module-crash", FaultPlan::new().module_crash(1, from, to)),
        (
            // Tenant 0's port to module 0 flaps 50 µs down / 250 µs
            // period for the whole run horizon: ~20% link downtime for
            // one tenant, zero for the other three.
            "link-flaps",
            FaultPlan::new().link_flaps(
                0,
                0,
                ns_to_cycles(250_000.0),
                ns_to_cycles(50_000.0),
                1e9,
            ),
        ),
        ("tenant-kill", FaultPlan::new().tenant_kill(3, 8e5)),
    ]
}

/// One cluster cell: the canonical tenant mix, every tenant under
/// `kind`, with the given fault plan and recovery policy.
pub fn cell(
    kind: SchemeKind,
    faults: Option<FaultPlan>,
    recovery: RecoveryPolicy,
    cfg: SimConfig,
) -> CellSpec {
    let tenants: Vec<(&str, SchemeKind)> = TENANT_MIX.iter().map(|w| (*w, kind)).collect();
    let mut spec = CellSpec::cluster(&tenants, MODULES, cfg);
    let cl = spec.cluster.as_mut().expect("cluster cell");
    cl.faults = faults;
    cl.recovery = recovery;
    spec
}

/// `resilience` — per scheme: one no-fault baseline cell, then fault
/// pattern × recovery policy (policies innermost).
pub fn resilience_plan(r: &Runner) -> Plan {
    let cfg = tenant_cfg(r);
    let patterns = fault_patterns();
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for &kind in &SCHEMES {
        cells.push(cell(kind, None, RecoveryPolicy::Stall, cfg.clone()));
        labels.push(format!("{}/no-fault/-", kind.name()));
        for (pname, plan) in &patterns {
            for &policy in &POLICIES {
                cells.push(cell(kind, Some(plan.clone()), policy, cfg.clone()));
                labels.push(format!("{}/{}/{}", kind.name(), pname, policy.name()));
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let t = TENANT_MIX.len();
        assert_eq!(ms.len(), labels.len() * t, "resilience layout mismatch");
        let cell_ms = |i: usize| &ms[i * t..(i + 1) * t];
        let per_scheme = labels.len() / SCHEMES.len();
        let mut table = Table::new(
            "Resilience: scheme x fault pattern x recovery policy, 4 tenants x 2 modules",
            &[
                "cell",
                "agg-IPC",
                "max-slowdown-vs-no-fault",
                "downtime-cycles",
                "aborted",
                "deferred",
            ],
        );
        for (i, label) in labels.iter().enumerate() {
            let block = cell_ms(i);
            // The same scheme's no-fault cell heads each scheme block.
            let base = cell_ms((i / per_scheme) * per_scheme);
            let ipc: f64 = block.iter().map(Metrics::ipc).sum();
            let slow = block
                .iter()
                .zip(base)
                .map(|(m, b)| slowdown(b, m))
                .fold(f64::NEG_INFINITY, f64::max);
            let downtime = block.iter().map(|m| m.downtime_cycles).fold(0.0f64, f64::max);
            let aborted: u64 = block.iter().map(|m| m.aborted_transfers).sum();
            let deferred: u64 = block.iter().map(|m| m.deferred_requests).sum();
            table.row_f(label, &[ipc, slow, downtime, aborted as f64, deferred as f64]);
        }
        vec![table]
    });
    Plan { id: "resilience".into(), cells, assemble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::orchestrator::{
        self, merge_with_plans, run_cell_spec, run_cells_flat, sweep_plans, Shard, ShardData,
        SweepResult,
    };
    use crate::util::json::Json;
    use crate::workloads::cache::TraceCache;

    #[test]
    fn resilience_plan_layout() {
        let r = Runner::test();
        let p = resilience_plan(&r);
        let per_scheme = 1 + fault_patterns().len() * POLICIES.len();
        assert_eq!(p.cells.len(), SCHEMES.len() * per_scheme);
        let metrics: usize = p.cells.iter().map(CellSpec::metrics_len).sum();
        assert_eq!(metrics, p.cells.len() * TENANT_MIX.len());
        // Baseline cells keep the no-fault defaults.
        let c0 = p.cells[0].cluster.as_ref().unwrap();
        assert!(c0.faults.is_none());
        assert_eq!(c0.recovery, RecoveryPolicy::Stall);
        // Faulted cells carry their plan and policy.
        let c1 = p.cells[1].cluster.as_ref().unwrap();
        assert!(c1.faults.is_some());
        let c2 = p.cells[2].cluster.as_ref().unwrap();
        assert_eq!(c2.recovery, RecoveryPolicy::Refetch);
    }

    #[test]
    fn module_crash_stalls_then_refetch_routes_around() {
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let cache = TraceCache::new();
        // Module 1 is down from cycle 0 to 1e6: certain to bite.
        let plan = FaultPlan::new().module_crash(1, 0.0, 1e6);
        let base = run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Remote, None, RecoveryPolicy::Stall, cfg.clone()),
        );
        let stall = run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Remote, Some(plan.clone()), RecoveryPolicy::Stall, cfg.clone()),
        );
        let refetch = run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Remote, Some(plan), RecoveryPolicy::Refetch, cfg),
        );
        let cyc = |ms: &[Metrics]| ms.iter().map(|m| m.cycles).sum::<f64>();
        let deferred = |ms: &[Metrics]| ms.iter().map(|m| m.deferred_requests).sum::<u64>();
        let instr = |ms: &[Metrics]| ms.iter().map(|m| m.instructions).sum::<u64>();
        // The no-fault baseline reports no fault activity at all.
        assert_eq!(deferred(&base), 0);
        assert!(base.iter().all(|m| m.aborted_transfers == 0 && m.downtime_cycles == 0.0));
        // Stall: requests to the dead module wait for recovery.
        assert!(deferred(&stall) > 0, "stalled run never hit the crash window");
        assert!(
            cyc(&stall) > cyc(&base),
            "a 1e6-cycle outage must cost cycles: {} vs {}",
            cyc(&stall),
            cyc(&base)
        );
        assert!(stall.iter().all(|m| m.downtime_cycles > 0.0), "downtime must be reported");
        // Refetch: with the module down from cycle 0, every request is
        // issued during an observed outage and routes around the dead
        // module — zero deferrals.  (A window opening mid-run would
        // still defer work dispatched before its edge: routing is
        // decided at issue time.)
        assert_eq!(deferred(&refetch), 0, "refetch must route around the dead module");
        assert!(
            cyc(&refetch) < cyc(&stall),
            "re-fetching from the surviving module must beat stalling: {} vs {}",
            cyc(&refetch),
            cyc(&stall)
        );
        // Same committed work in all three runs.
        assert_eq!(instr(&base), instr(&stall));
        assert_eq!(instr(&base), instr(&refetch));
    }

    #[test]
    fn link_flaps_hit_only_the_flapped_tenant() {
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let cache = TraceCache::new();
        // Tenant 0's module-0 link flaps from cycle 0; others clean.
        let plan = FaultPlan::new().link_flaps(0, 0, 5e5, 2e5, 1e9);
        let base = run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Daemon, None, RecoveryPolicy::Stall, cfg.clone()),
        );
        let flapped = run_cell_spec(
            &r,
            &cache,
            &cell(SchemeKind::Daemon, Some(plan), RecoveryPolicy::Stall, cfg),
        );
        assert!(
            flapped[0].deferred_requests + flapped[0].aborted_transfers > 0,
            "the flapped tenant never hit a down window"
        );
        assert!(flapped[0].downtime_cycles > 0.0);
        // Failure isolation: the other tenants are byte-identical to the
        // no-fault run.
        for i in 1..TENANT_MIX.len() {
            assert_eq!(
                flapped[i].to_json().to_string(),
                base[i].to_json().to_string(),
                "tenant {i} perturbed by tenant 0's link flaps"
            );
        }
    }

    #[test]
    fn fault_metrics_are_thread_count_invariant() {
        // Satellite: same FaultPlan + seed => byte-identical metrics
        // regardless of the --jobs worker count.
        let r = Runner::test();
        let cfg = tenant_cfg(&r);
        let plan = FaultPlan::new().module_crash(1, 0.0, 1e6).link_flaps(0, 1, 5e5, 1e5, 1e8);
        let cells = vec![
            cell(SchemeKind::Daemon, Some(plan.clone()), RecoveryPolicy::Stall, cfg.clone()),
            cell(SchemeKind::Daemon, Some(plan), RecoveryPolicy::Refetch, cfg),
        ];
        let fmt = |slots: Vec<Option<Vec<Metrics>>>| -> Vec<String> {
            slots
                .into_iter()
                .flat_map(|s| s.expect("unsharded run must fill every slot"))
                .map(|m| m.to_json().to_string())
                .collect()
        };
        let one = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 1));
        let eight = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 8));
        assert_eq!(one, eight, "fault runs diverged across --jobs counts");
    }

    /// Reduced 2-cell plan for the shard byte-identity test (the full
    /// sweep rides CI's 2-shard merge check).
    fn mini_plan(r: &Runner) -> Plan {
        let cfg = tenant_cfg(r);
        let (from, to) = crash_window();
        let plan = FaultPlan::new().module_crash(1, from, to);
        let cells = vec![
            cell(SchemeKind::Daemon, Some(plan.clone()), RecoveryPolicy::Stall, cfg.clone()),
            cell(SchemeKind::Daemon, Some(plan), RecoveryPolicy::Refetch, cfg),
        ];
        let assemble = Box::new(move |ms: &[Metrics]| {
            let mut t = Table::new("resilience mini", &["tenant", "ipc", "deferred"]);
            for (i, m) in ms.iter().enumerate() {
                t.row_f(&format!("{i}"), &[m.ipc(), m.deferred_requests as f64]);
            }
            vec![t]
        });
        Plan { id: "resilience_mini".into(), cells, assemble }
    }

    #[test]
    fn resilience_cells_shard_byte_identically() {
        let r = Runner::test();
        let ids = vec!["resilience_mini".to_string()];
        let full = match sweep_plans(
            vec![mini_plan(&r)],
            &ids,
            &r,
            &TraceCache::new(),
            Shard::full(),
            2,
        )
        .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!("unsharded run produced a shard"),
        };
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let d = match sweep_plans(
                    vec![mini_plan(&r)],
                    &ids,
                    &r,
                    &TraceCache::new(),
                    Shard { index, total: 2 },
                    2,
                )
                .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!("sharded run produced tables"),
                };
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        let merged = merge_with_plans(vec![mini_plan(&r)], &shards).unwrap();
        assert_eq!(
            orchestrator::figures_json(&full).to_string(),
            orchestrator::figures_json(&merged).to_string(),
            "resilience cells must shard/merge byte-identically"
        );
    }
}
