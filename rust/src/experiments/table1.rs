//! Table 1 — DaeMon's hardware structure overheads (CACTI-style model).

use super::orchestrator::Plan;
use crate::daemon::hw_cost::{table1, total_kb};
use crate::util::table::Table;

/// Orchestrator plan: no simulation cells, assembly is the analytic model.
pub fn plan() -> Plan {
    Plan { id: "table1".into(), cells: Vec::new(), assemble: Box::new(|_| run()) }
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Table 1: DaeMon hardware overheads (modeled vs paper)",
        &["structure", "entries", "size-KB", "access-ns", "area-mm2", "energy-nJ"],
    );
    for row in table1() {
        t.row(vec![
            row.structure.name.to_string(),
            row.structure
                .entries
                .map(|e| e.to_string())
                .unwrap_or_else(|| "-".into()),
            format!("{}", row.structure.size_kb),
            format!("{:.2}", row.access_ns),
            format!("{:.3}", row.area_mm2),
            format!("{:.3}", row.energy_nj),
        ]);
    }
    t.row(vec![
        "TOTAL compute engine".into(),
        "-".into(),
        format!("{:.1}", total_kb('C')),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t.row(vec![
        "TOTAL memory engine".into(),
        "-".into(),
        format!("{:.1}", total_kb('M')),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_renders() {
        let t = super::run();
        let s = t[0].render();
        assert!(s.contains("Sub-block Queue"));
        assert!(s.contains("TOTAL compute engine"));
    }
}
