//! Figs. 15, 17, 18, 22 — multithreaded, multi-memory-component and
//! multi-workload scaling, declared as orchestrator [`Plan`]s.

use super::common::{speedup, Runner};
use super::orchestrator::{self, CellSpec, Plan};
use crate::config::{NetConfig, SimConfig};
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::SUBSET;

fn owned(workloads: &[&str]) -> Vec<String> {
    workloads.iter().map(|s| s.to_string()).collect()
}

/// Fig. 15 — multithreaded (8 OoO cores) speedup over Remote.
pub fn fig15_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default().with_cores(8);
    let kinds = [
        SchemeKind::Lc,
        SchemeKind::Bp,
        SchemeKind::Pq,
        SchemeKind::Daemon,
        SchemeKind::Local,
    ];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg.clone()));
        for &k in &kinds {
            cells.push(CellSpec::new(wl, k, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_wl = 1 + kinds.len();
        let mut table = Table::new(
            "Fig 15: multithreaded (8 cores) speedup over Remote",
            &["workload", "LC", "BP", "PQ", "DaeMon", "Local"],
        );
        let mut per: Vec<Vec<f64>> = vec![Vec::new(); kinds.len()];
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * per_wl..(w + 1) * per_wl];
            let vals: Vec<f64> = row[1..].iter().map(|m| speedup(m, &row[0])).collect();
            for (i, v) in vals.iter().enumerate() {
                per[i].push(*v);
            }
            table.row_f(wl, &vals);
        }
        table.row_f("geomean", &per.iter().map(|v| geomean(v)).collect::<Vec<_>>());
        vec![table]
    });
    Plan { id: "fig15".into(), cells, assemble }
}

pub fn fig15(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig15_plan(r, workloads))
}

/// Fig. 17's memory-component configurations (table in the paper).
pub fn mc_configs() -> Vec<(&'static str, Vec<NetConfig>)> {
    vec![
        ("MC1.1", vec![NetConfig::new(100.0, 4.0)]),
        ("MC2.1", vec![NetConfig::new(100.0, 4.0); 2]),
        (
            "MC2.2",
            vec![NetConfig::new(400.0, 4.0), NetConfig::new(400.0, 8.0)],
        ),
        ("MC2.3", vec![NetConfig::new(100.0, 8.0); 2]),
        ("MC4.1", vec![NetConfig::new(100.0, 4.0); 4]),
        (
            "MC4.2",
            vec![
                NetConfig::new(100.0, 4.0),
                NetConfig::new(400.0, 8.0),
                NetConfig::new(100.0, 4.0),
                NetConfig::new(400.0, 8.0),
            ],
        ),
        ("MC4.3", vec![NetConfig::new(400.0, 8.0); 4]),
        (
            "MC4.4",
            vec![
                NetConfig::new(100.0, 8.0),
                NetConfig::new(100.0, 16.0),
                NetConfig::new(100.0, 8.0),
                NetConfig::new(100.0, 16.0),
            ],
        ),
    ]
}

/// Fig. 17 — Remote and DaeMon normalized to Local across memory-component
/// configurations.
pub fn fig17_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let kinds = [SchemeKind::Local, SchemeKind::Remote, SchemeKind::Daemon];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for (_, nets) in mc_configs() {
        let cfg = SimConfig::default().with_memory_components(nets);
        for wl in &workloads {
            for &k in &kinds {
                cells.push(CellSpec::new(wl, k, cfg.clone()));
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_cfg = workloads.len() * kinds.len();
        let mut table = Table::new(
            "Fig 17: IPC normalized to Local across memory-component configs (geomean)",
            &["config", "Remote", "DaeMon"],
        );
        for (c, (label, _)) in mc_configs().iter().enumerate() {
            let block = &ms[c * per_cfg..(c + 1) * per_cfg];
            let mut remote = Vec::new();
            let mut daemon = Vec::new();
            for w in 0..workloads.len() {
                let row = &block[w * kinds.len()..(w + 1) * kinds.len()];
                remote.push(speedup(&row[1], &row[0]));
                daemon.push(speedup(&row[2], &row[0]));
            }
            table.row_f(label, &[geomean(&remote), geomean(&daemon)]);
        }
        vec![table]
    });
    Plan { id: "fig17".into(), cells, assemble }
}

pub fn fig17(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig17_plan(r, workloads))
}

/// Fig. 18's workload mixes.
fn fig18_mixes() -> Vec<(&'static str, Vec<&'static str>)> {
    vec![
        ("pr+nw+sp+dr", vec!["pr", "nw", "sp", "dr"]),
        ("bf+ts+hp+rs", vec!["bf", "ts", "hp", "rs"]),
        ("kc+sl+pf+tr", vec!["kc", "sl", "pf", "tr"]),
        ("pr+pr+sp+sp", vec!["pr", "pr", "sp", "sp"]),
    ]
}

/// Fig. 18 — multiple concurrent heterogeneous workloads on a 4-core
/// compute component; per-mix DaeMon speedup over Remote.
pub fn fig18_plan(_r: &Runner) -> Plan {
    let mut cells = Vec::new();
    for (_, mix) in fig18_mixes() {
        // Local memory shrinks per job (~9% each with 4 jobs, per paper).
        let cfg = SimConfig::default()
            .with_cores(4)
            .with_local_fraction(0.09 * 4.0 / 4.0 + 0.11); // ~20% of combined
        cells.push(CellSpec::mix(&mix, SchemeKind::Remote, cfg.clone()));
        cells.push(CellSpec::mix(&mix, SchemeKind::Daemon, cfg));
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let mut table = Table::new(
            "Fig 18: DaeMon over Remote, 4 concurrent workloads on 4 cores",
            &["mix", "speedup"],
        );
        let mut all = Vec::new();
        for (i, (label, _)) in fig18_mixes().iter().enumerate() {
            let sp = speedup(&ms[2 * i + 1], &ms[2 * i]);
            all.push(sp);
            table.row_f(label, &[sp]);
        }
        table.row_f("geomean", &[geomean(&all)]);
        vec![table]
    });
    Plan { id: "fig18".into(), cells, assemble }
}

pub fn fig18(r: &Runner) -> Vec<Table> {
    orchestrator::run_plan(r, fig18_plan(r))
}

/// Fig. 22 — 1/2/4 memory components at identical per-component config.
pub fn fig22_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const COUNTS: [usize; 3] = [1, 2, 4];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for &n in &COUNTS {
        let cfg = SimConfig::default()
            .with_memory_components(vec![NetConfig::new(100.0, 4.0); n]);
        for wl in &workloads {
            cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg.clone()));
            cells.push(CellSpec::new(wl, SchemeKind::Daemon, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_n = 2 * workloads.len();
        let mut table = Table::new(
            "Fig 22: DaeMon speedup over Remote vs #memory components (geomean)",
            &["components", "speedup", "Remote-IPC-gain", "DaeMon-IPC-gain"],
        );
        let mut base: Option<(f64, f64)> = None;
        for (i, &n) in COUNTS.iter().enumerate() {
            let block = &ms[i * per_n..(i + 1) * per_n];
            let mut sp = Vec::new();
            let mut r_ipc = Vec::new();
            let mut d_ipc = Vec::new();
            for w in 0..workloads.len() {
                sp.push(speedup(&block[2 * w + 1], &block[2 * w]));
                r_ipc.push(block[2 * w].ipc());
                d_ipc.push(block[2 * w + 1].ipc());
            }
            let (rg, dg) = (geomean(&r_ipc), geomean(&d_ipc));
            let (rb, db) = *base.get_or_insert((rg, dg));
            table.row_f(&format!("{n}"), &[geomean(&sp), rg / rb, dg / db]);
        }
        vec![table]
    });
    Plan { id: "fig22".into(), cells, assemble }
}

pub fn fig22(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig22_plan(r, workloads))
}

pub fn fig15_default(r: &Runner) -> Vec<Table> {
    fig15(r, &SUBSET)
}
pub fn fig17_default(r: &Runner) -> Vec<Table> {
    fig17(r, &SUBSET)
}
pub fn fig22_default(r: &Runner) -> Vec<Table> {
    fig22(r, &SUBSET)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mc_configs_match_paper_table() {
        let cfgs = mc_configs();
        assert_eq!(cfgs.len(), 8);
        assert_eq!(cfgs[0].1.len(), 1);
        assert_eq!(cfgs[7].1.len(), 4);
        assert_eq!(cfgs[7].1[1].bandwidth_factor, 16.0);
    }

    #[test]
    fn fig22_more_components_help_remote() {
        let r = Runner::test();
        let t = fig22(&r, &["pr"]);
        let one: f64 = t[0].rows[0][2].parse().unwrap();
        let four: f64 = t[0].rows[2][2].parse().unwrap();
        assert!(four >= one, "Remote IPC gain 4-comp {four} vs 1-comp {one}");
    }
}
