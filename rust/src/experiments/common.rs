//! Shared experiment machinery: trace-cached grid runner + speedup math.
//!
//! Experiments iterate workload-major: each workload's trace is generated
//! once, then all (scheme, config) cells run against it in parallel with
//! `std::thread::scope` (traces are read-only).

use crate::compress::synth::Profile;
use crate::config::SimConfig;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::system::Machine;
use crate::workloads::{by_name, Scale, Trace};

/// Experiment effort level.
#[derive(Clone, Copy, Debug)]
pub struct Runner {
    pub scale: Scale,
    /// Trace cap (simulation time bound); 0 = unlimited.
    pub max_accesses: usize,
    pub threads: usize,
}

impl Runner {
    /// Full paper-scale experiments (the bench harness default).
    pub fn paper() -> Runner {
        Runner { scale: Scale::Paper, max_accesses: 2_000_000, threads: default_threads() }
    }

    /// Quick mode for smoke runs and CI.
    pub fn quick() -> Runner {
        Runner { scale: Scale::Paper, max_accesses: 400_000, threads: default_threads() }
    }

    /// Tiny mode for unit tests.
    pub fn test() -> Runner {
        Runner { scale: Scale::Test, max_accesses: 150_000, threads: 2 }
    }

    pub fn gen_trace(&self, workload: &str, seed: u64) -> (Trace, Profile) {
        let w = by_name(workload).unwrap_or_else(|| panic!("unknown workload {workload}"));
        let mut t = w.generate(seed, self.scale);
        if self.max_accesses > 0 {
            t = t.truncated(self.max_accesses);
        }
        (t, w.profile())
    }

    /// Run one (scheme, config) cell against a pre-generated trace.
    pub fn run_cell(
        &self,
        trace: &Trace,
        profile: Profile,
        kind: SchemeKind,
        cfg: &SimConfig,
    ) -> Metrics {
        let mut m = Machine::new(
            cfg.clone(),
            kind,
            trace.footprint_pages,
            vec![profile; cfg.cores.max(1)],
            None,
        );
        m.run(std::slice::from_ref(trace));
        m.metrics.clone()
    }

    /// Run many cells against one trace, fanned out over threads.
    pub fn run_cells(
        &self,
        trace: &Trace,
        profile: Profile,
        cells: &[(SchemeKind, SimConfig)],
    ) -> Vec<Metrics> {
        let n = cells.len();
        let mut out: Vec<Option<Metrics>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let next = std::sync::atomic::AtomicUsize::new(0);
        let slots = std::sync::Mutex::new(&mut out);
        std::thread::scope(|s| {
            for _ in 0..self.threads.min(n.max(1)) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let (kind, cfg) = &cells[i];
                    let m = self.run_cell(trace, profile, *kind, cfg);
                    slots.lock().unwrap()[i] = Some(m);
                });
            }
        });
        out.into_iter().map(Option::unwrap).collect()
    }

    /// Run a heterogeneous multi-workload mix (Fig. 18): one trace per
    /// core.
    pub fn run_mix(&self, workloads: &[&str], kind: SchemeKind, cfg: &SimConfig) -> Metrics {
        assert_eq!(workloads.len(), cfg.cores);
        let pairs: Vec<(Trace, Profile)> = workloads
            .iter()
            .map(|w| self.gen_trace(w, cfg.seed))
            .collect();
        let footprint: usize = pairs.iter().map(|(t, _)| t.footprint_pages).sum();
        let profiles: Vec<Profile> = pairs.iter().map(|(_, p)| *p).collect();
        let traces: Vec<Trace> = pairs.into_iter().map(|(t, _)| t).collect();
        let mut m = Machine::new(cfg.clone(), kind, footprint, profiles, None);
        m.run(&traces);
        m.metrics.clone()
    }
}

pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Speedup of `m` over baseline `base` by IPC.
pub fn speedup(m: &Metrics, base: &Metrics) -> f64 {
    if base.ipc() <= 0.0 {
        0.0
    } else {
        m.ipc() / base.ipc()
    }
}

/// Paper network grid (Fig. 8): switch {100,400} x bandwidth factor
/// {2,4,8}.
pub fn net_grid() -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    for &sw in &[100.0, 400.0] {
        for &bw in &[2.0, 4.0, 8.0] {
            out.push((format!("{}ns,1/{}", sw as u32, bw as u32), sw, bw));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_generates_and_truncates() {
        let r = Runner::test();
        let (t, _) = r.gen_trace("pr", 1);
        assert!(t.accesses.len() <= 150_000);
        assert!(t.footprint_pages > 0);
    }

    #[test]
    fn parallel_cells_match_serial() {
        let r = Runner::test();
        let (t, p) = r.gen_trace("bf", 1);
        let cfg = SimConfig::test_scale();
        let cells = vec![
            (SchemeKind::Remote, cfg.clone()),
            (SchemeKind::Daemon, cfg.clone()),
        ];
        let par = r.run_cells(&t, p, &cells);
        let ser: Vec<Metrics> = cells
            .iter()
            .map(|(k, c)| r.run_cell(&t, p, *k, c))
            .collect();
        for (a, b) in par.iter().zip(ser.iter()) {
            assert_eq!(a.instructions, b.instructions);
            assert!((a.cycles - b.cycles).abs() < 1e-6);
        }
    }

    #[test]
    fn mix_runs_heterogeneous_jobs() {
        let r = Runner::test();
        let cfg = SimConfig::test_scale().with_cores(2);
        let m = r.run_mix(&["pr", "sp"], SchemeKind::Daemon, &cfg);
        assert!(m.instructions > 0);
        assert!(m.ipc() > 0.0);
    }

    #[test]
    fn net_grid_is_paper_shape() {
        let g = net_grid();
        assert_eq!(g.len(), 6);
        assert_eq!(g[0].0, "100ns,1/2");
    }
}
