//! Shared experiment machinery: trace-cached grid runner + speedup math.
//!
//! Traces come from the global [`TraceCache`] — generated once per
//! `(workload, scale, seed, cap)` key and shared read-only across every
//! experiment.  Cell fan-out writes results into an index-addressed
//! `OnceLock` slot table (each worker owns the slots it claims via an
//! atomic cursor), so there is no shared `Mutex` over the output vector.
//! The cross-figure flat scheduler lives in
//! [`super::orchestrator`]; `run_cells` here is the single-trace inner
//! loop it and the legacy per-figure entry points share.

use crate::compress::synth::Profile;
use crate::config::SimConfig;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::system::Machine;
use crate::workloads::cache::TraceCache;
use crate::workloads::{Scale, Trace};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Experiment effort level.
#[derive(Clone, Copy, Debug)]
pub struct Runner {
    pub scale: Scale,
    /// Trace cap (simulation time bound); 0 = unlimited.
    pub max_accesses: usize,
    pub threads: usize,
}

impl Runner {
    /// Full paper-scale experiments (the bench harness default).
    pub fn paper() -> Runner {
        Runner { scale: Scale::Paper, max_accesses: 2_000_000, threads: default_threads() }
    }

    /// Quick mode for smoke runs and CI.
    pub fn quick() -> Runner {
        Runner { scale: Scale::Paper, max_accesses: 400_000, threads: default_threads() }
    }

    /// Tiny mode for unit tests.
    pub fn test() -> Runner {
        Runner { scale: Scale::Test, max_accesses: 150_000, threads: 2 }
    }

    /// Fetch (or generate, once per key) the trace for `workload` from the
    /// global trace cache.
    pub fn gen_trace(&self, workload: &str, seed: u64) -> (Arc<Trace>, Profile) {
        TraceCache::global().get(workload, self.scale, seed, self.max_accesses)
    }

    /// Run one (scheme, config) cell against a pre-generated trace.
    pub fn run_cell(
        &self,
        trace: &Trace,
        profile: Profile,
        kind: SchemeKind,
        cfg: &SimConfig,
    ) -> Metrics {
        let mut m = Machine::new(
            cfg.clone(),
            kind,
            trace.footprint_pages,
            vec![profile; cfg.cores.max(1)],
            None,
        );
        m.run(std::slice::from_ref(trace));
        m.metrics.clone()
    }

    /// Run many cells against one trace, fanned out over threads.  Each
    /// worker claims the next cell index from an atomic cursor and fills
    /// that cell's own `OnceLock` slot — no lock covers the result vector.
    pub fn run_cells(
        &self,
        trace: &Trace,
        profile: Profile,
        cells: &[(SchemeKind, SimConfig)],
    ) -> Vec<Metrics> {
        let n = cells.len();
        let slots: Vec<OnceLock<Metrics>> = (0..n).map(|_| OnceLock::new()).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.threads.max(1).min(n.max(1)) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let (kind, cfg) = &cells[i];
                    let m = self.run_cell(trace, profile, *kind, cfg);
                    let _ = slots[i].set(m);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().expect("cell slot left unfilled"))
            .collect()
    }

    /// Run a heterogeneous multi-workload mix (Fig. 18): one trace per
    /// core, all shared from the trace cache.
    pub fn run_mix(&self, workloads: &[&str], kind: SchemeKind, cfg: &SimConfig) -> Metrics {
        assert_eq!(workloads.len(), cfg.cores);
        let pairs: Vec<(Arc<Trace>, Profile)> = workloads
            .iter()
            .map(|w| self.gen_trace(w, cfg.seed))
            .collect();
        let footprint: usize = pairs.iter().map(|(t, _)| t.footprint_pages).sum();
        let profiles: Vec<Profile> = pairs.iter().map(|(_, p)| *p).collect();
        let traces: Vec<Arc<Trace>> = pairs.into_iter().map(|(t, _)| t).collect();
        let mut m = Machine::new(cfg.clone(), kind, footprint, profiles, None);
        m.run(&traces);
        m.metrics.clone()
    }
}

pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Speedup of `m` over baseline `base` by IPC.
pub fn speedup(m: &Metrics, base: &Metrics) -> f64 {
    if base.ipc() <= 0.0 {
        0.0
    } else {
        m.ipc() / base.ipc()
    }
}

/// Paper network grid (Fig. 8): switch {100,400} x bandwidth factor
/// {2,4,8}.
pub fn net_grid() -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    for &sw in &[100.0, 400.0] {
        for &bw in &[2.0, 4.0, 8.0] {
            out.push((format!("{}ns,1/{}", sw as u32, bw as u32), sw, bw));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_generates_and_truncates() {
        let r = Runner::test();
        let (t, _) = r.gen_trace("pr", 1);
        assert!(t.accesses.len() <= 150_000);
        assert!(t.footprint_pages > 0);
    }

    #[test]
    fn gen_trace_shares_one_copy_per_key() {
        let r = Runner::test();
        let (a, _) = r.gen_trace("ts", 21);
        let (b, _) = r.gen_trace("ts", 21);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn parallel_cells_match_serial() {
        let r = Runner::test();
        let (t, p) = r.gen_trace("bf", 1);
        let cfg = SimConfig::test_scale();
        let cells = vec![
            (SchemeKind::Remote, cfg.clone()),
            (SchemeKind::Daemon, cfg.clone()),
        ];
        let par = r.run_cells(&t, p, &cells);
        let ser: Vec<Metrics> = cells
            .iter()
            .map(|(k, c)| r.run_cell(&t, p, *k, c))
            .collect();
        for (a, b) in par.iter().zip(ser.iter()) {
            assert_eq!(a.instructions, b.instructions);
            assert!((a.cycles - b.cycles).abs() < 1e-6);
        }
    }

    #[test]
    fn run_cells_is_thread_count_invariant() {
        // Deterministic slot table: 1, 2 and 8 workers must produce
        // byte-identical metrics in cell order.
        let base = Runner::test();
        let (t, p) = base.gen_trace("bf", 2);
        let cfg = SimConfig::test_scale();
        let cells = vec![
            (SchemeKind::Remote, cfg.clone()),
            (SchemeKind::Lc, cfg.clone()),
            (SchemeKind::Pq, cfg.clone()),
            (SchemeKind::Daemon, cfg.clone()),
        ];
        let reference: Vec<String> = Runner { threads: 1, ..base }
            .run_cells(&t, p, &cells)
            .iter()
            .map(|m| m.to_json().to_string())
            .collect();
        for threads in [2, 8] {
            let r = Runner { threads, ..base };
            let got: Vec<String> = r
                .run_cells(&t, p, &cells)
                .iter()
                .map(|m| m.to_json().to_string())
                .collect();
            assert_eq!(got, reference, "divergence at {threads} threads");
        }
    }

    #[test]
    fn mix_runs_heterogeneous_jobs() {
        let r = Runner::test();
        let cfg = SimConfig::test_scale().with_cores(2);
        let m = r.run_mix(&["pr", "sp"], SchemeKind::Daemon, &cfg);
        assert!(m.instructions > 0);
        assert!(m.ipc() > 0.0);
    }

    #[test]
    fn net_grid_is_paper_shape() {
        let g = net_grid();
        assert_eq!(g.len(), 6);
        assert_eq!(g[0].0, "100ns,1/2");
    }
}
