//! Experiment drivers — one entry per figure/table in the paper's
//! evaluation (§2.2, §6, Appendix A).  Each returns rendered tables with
//! the same rows/series the paper plots.  See DESIGN.md for the index.

pub mod ablations;
pub mod common;
pub mod disturbance;
pub mod main_results;
pub mod motivation;
pub mod scaling;
pub mod table1;

pub use common::Runner;

use crate::util::table::Table;

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: [&str; 17] = [
    "fig3", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig15",
    "fig16", "fig17", "fig18", "fig19", "fig20", "fig21", "fig22", "table1",
    "headline",
];

/// Run one experiment by id.
pub fn run_experiment(id: &str, r: &Runner) -> Option<Vec<Table>> {
    Some(match id {
        "fig3" => motivation::run_default(r),
        "fig8" => main_results::fig8_default(r),
        "fig9" => main_results::fig9_default(r),
        "fig10" => main_results::fig10_default(r),
        "fig11" => ablations::fig11_default(r),
        "fig12" => ablations::fig12_default(r),
        "fig13" | "fig14" => disturbance::fig13_14_default(r),
        "fig15" => scaling::fig15_default(r),
        "fig16" => ablations::fig16_default(r),
        "fig17" => scaling::fig17_default(r),
        "fig18" => scaling::fig18(r),
        "fig19" => main_results::fig19_default(r),
        "fig20" => ablations::fig20_default(r),
        "fig21" => ablations::fig21_default(r),
        "fig22" => scaling::fig22_default(r),
        "table1" => table1::run(),
        "headline" => {
            let (_, _, t) = main_results::headline(r);
            vec![t]
        }
        "ablation_dirty_threshold" => {
            ablations::ablation_dirty_threshold(r, &crate::workloads::SUBSET)
        }
        "ablation_buffer_size" => {
            ablations::ablation_buffer_size(r, &crate::workloads::SUBSET)
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ids_resolve() {
        let r = Runner::test();
        // table1 is cheap enough to actually run here.
        assert!(run_experiment("table1", &r).is_some());
        assert!(run_experiment("nope", &r).is_none());
    }
}
