//! Experiment drivers — one entry per figure/table in the paper's
//! evaluation (§2.2, §6, Appendix A), plus the cluster / variability /
//! resilience scenario experiments.  Each declares its simulation cells
//! as an orchestrator [`orchestrator::Plan`] and assembles rendered
//! tables with the same rows/series the paper plots.  See DESIGN.md for
//! the index, and `orchestrator.rs` for the flat scheduler + sharding.
//!
//! Registration is a single table: [`REGISTRY`] is the one place an
//! experiment id exists — `plan_for`, the CLI `list` output and the
//! default `experiment all` set all derive from it (drift-tested in
//! `registry_is_the_single_source_of_truth`).

pub mod ablations;
pub mod adaptive;
pub mod cluster;
pub mod common;
pub mod disturbance;
pub mod main_results;
pub mod motivation;
pub mod orchestrator;
pub mod resilience;
pub mod scaling;
pub mod table1;
pub mod tail_latency;
pub mod variability;

pub use common::Runner;

use crate::util::table::Table;
use crate::workloads::{ALL, SUBSET};
use orchestrator::Plan;

/// One registered experiment: its id, a one-line description for the CLI
/// `list` output, whether the default `experiment all` set includes it
/// (aliases and extra ablations resolve by id but opt out), and the plan
/// builder.
pub struct ExperimentDef {
    pub id: &'static str,
    pub about: &'static str,
    pub in_all: bool,
    pub build: fn(&Runner) -> Plan,
}

/// The experiment registry — the single source of truth for experiment
/// ids (paper figures/tables in paper order, then the scenario
/// experiments, then aliases/extras).
pub static REGISTRY: [ExperimentDef; 26] = [
    ExperimentDef {
        id: "fig3",
        about: "motivation: IPC normalized to Local, 6 schemes",
        in_all: true,
        build: |r| motivation::plan(r, &ALL),
    },
    ExperimentDef {
        id: "fig8",
        about: "speedup over Remote across the network grid",
        in_all: true,
        build: |r| main_results::fig8_plan(r, &ALL),
    },
    ExperimentDef {
        id: "fig9",
        about: "data access cost normalized to Remote",
        in_all: true,
        build: |r| main_results::fig9_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig10",
        about: "local-memory hit ratio (+extra pages vs PQ)",
        in_all: true,
        build: |r| main_results::fig10_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig11",
        about: "partition-ratio sweep (PQ, DaeMon)",
        in_all: true,
        build: |r| ablations::fig11_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig12",
        about: "link compression by algorithm",
        in_all: true,
        build: |r| ablations::fig12_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig13",
        about: "IPC + hit ratio under network disturbance",
        in_all: true,
        build: |r| disturbance::fig13_14_plan(r, &["pr", "nw"]),
    },
    ExperimentDef {
        id: "fig15",
        about: "8-core multithreaded speedups",
        in_all: true,
        build: |r| scaling::fig15_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig16",
        about: "FIFO local-memory replacement",
        in_all: true,
        build: |r| ablations::fig16_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig17",
        about: "memory-component configurations (MCx.y)",
        in_all: true,
        build: |r| scaling::fig17_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig18",
        about: "4 concurrent heterogeneous workloads",
        in_all: true,
        build: scaling::fig18_plan,
    },
    ExperimentDef {
        id: "fig19",
        about: "network bandwidth utilization",
        in_all: true,
        build: |r| main_results::fig19_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig20",
        about: "switch-latency sweep",
        in_all: true,
        build: |r| ablations::fig20_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig21",
        about: "bandwidth-factor sweep (8 cores)",
        in_all: true,
        build: |r| ablations::fig21_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "fig22",
        about: "1/2/4 memory components",
        in_all: true,
        build: |r| scaling::fig22_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "table1",
        about: "DaeMon hardware overheads (analytic)",
        in_all: true,
        build: |_| table1::plan(),
    },
    ExperimentDef {
        id: "headline",
        about: "abstract numbers: 2.39x / 3.06x",
        in_all: true,
        build: main_results::headline_plan,
    },
    ExperimentDef {
        id: "cluster_contention",
        about: "aggregate IPC, C tenants x 2 shared modules",
        in_all: true,
        build: cluster::cluster_contention_plan,
    },
    ExperimentDef {
        id: "cluster_fairness",
        about: "max slowdown / unfairness / per-tenant p99",
        in_all: true,
        build: cluster::cluster_fairness_plan,
    },
    ExperimentDef {
        id: "variability",
        about: "scheme x sharing-mode x link-condition schedule",
        in_all: true,
        build: variability::variability_plan,
    },
    ExperimentDef {
        id: "resilience",
        about: "scheme x fault pattern x recovery policy",
        in_all: true,
        build: resilience::resilience_plan,
    },
    ExperimentDef {
        id: "adaptive",
        about: "closed-loop controller vs every static configuration",
        in_all: true,
        build: adaptive::adaptive_plan,
    },
    ExperimentDef {
        id: "tail_latency",
        about: "request SLO grid: arrival x load x robustness stack",
        in_all: true,
        build: tail_latency::tail_latency_plan,
    },
    ExperimentDef {
        id: "fig14",
        about: "alias of fig13 (same plan, requested id kept)",
        in_all: false,
        build: |r| disturbance::fig13_14_plan(r, &["pr", "nw"]),
    },
    ExperimentDef {
        id: "ablation_dirty_threshold",
        about: "our ablation: dirty flush threshold",
        in_all: false,
        build: |r| ablations::ablation_dirty_threshold_plan(r, &SUBSET),
    },
    ExperimentDef {
        id: "ablation_buffer_size",
        about: "our ablation: inflight buffer sizing",
        in_all: false,
        build: |r| ablations::ablation_buffer_size_plan(r, &SUBSET),
    },
];

/// Experiment ids the default `experiment all` sweep runs, in registry
/// order.
pub fn default_experiment_ids() -> Vec<&'static str> {
    REGISTRY.iter().filter(|d| d.in_all).map(|d| d.id).collect()
}

/// Build the orchestrator plan for one experiment id (the default
/// workload sets the paper uses).  `None` for unknown ids.
pub fn plan_for(id: &str, r: &Runner) -> Option<Plan> {
    let def = REGISTRY.iter().find(|d| d.id == id)?;
    let mut plan = (def.build)(r);
    plan.id = id.to_string();
    Some(plan)
}

/// Run one experiment by id through the orchestrator.
pub fn run_experiment(id: &str, r: &Runner) -> Option<Vec<Table>> {
    Some(orchestrator::run_plan(r, plan_for(id, r)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_the_single_source_of_truth() {
        // Drift test: every registered id resolves to a plan carrying
        // that id, ids are unique, and the default set is the in_all
        // slice of the registry.
        let r = Runner::test();
        let mut seen = crate::util::hash::FxHashSet::default();
        for def in &REGISTRY {
            assert!(seen.insert(def.id), "duplicate experiment id {}", def.id);
            assert!(!def.about.is_empty(), "{} has no description", def.id);
            let p = plan_for(def.id, &r).unwrap_or_else(|| panic!("no plan for {}", def.id));
            assert_eq!(p.id, def.id, "plan id drifted from registry id");
        }
        assert!(plan_for("nope", &r).is_none());
        let all = default_experiment_ids();
        assert_eq!(all.len(), REGISTRY.iter().filter(|d| d.in_all).count());
        assert!(all.contains(&"resilience"));
        assert!(all.contains(&"adaptive"));
        assert!(all.contains(&"tail_latency"));
        assert!(!all.contains(&"fig14"), "aliases stay out of `all`");
        assert!(!all.contains(&"ablation_dirty_threshold"));
    }

    #[test]
    fn all_ids_resolve() {
        let r = Runner::test();
        // table1 is cheap enough to actually run here.
        assert!(run_experiment("table1", &r).is_some());
        assert!(run_experiment("nope", &r).is_none());
        // fig14 aliases the fig13 plan but keeps its requested id.
        assert_eq!(plan_for("fig14", &r).unwrap().id, "fig14");
    }

    #[test]
    fn plans_declare_nonempty_grids() {
        let r = Runner::test();
        for def in &REGISTRY {
            let p = plan_for(def.id, &r).unwrap();
            if def.id != "table1" {
                assert!(!p.cells.is_empty(), "{} declared no cells", def.id);
            }
        }
    }
}
