//! Experiment drivers — one entry per figure/table in the paper's
//! evaluation (§2.2, §6, Appendix A).  Each declares its simulation cells
//! as an orchestrator [`orchestrator::Plan`] and assembles rendered tables
//! with the same rows/series the paper plots.  See DESIGN.md for the
//! index, and `orchestrator.rs` for the flat scheduler + sharding.

pub mod ablations;
pub mod cluster;
pub mod common;
pub mod disturbance;
pub mod main_results;
pub mod motivation;
pub mod orchestrator;
pub mod scaling;
pub mod table1;
pub mod variability;

pub use common::Runner;

use crate::util::table::Table;
use crate::workloads::{ALL, SUBSET};

/// All experiment ids: the paper's figures/tables in paper order, then
/// the cluster (multi-tenant) and variability scenario experiments.
pub const ALL_EXPERIMENTS: [&str; 20] = [
    "fig3", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig15",
    "fig16", "fig17", "fig18", "fig19", "fig20", "fig21", "fig22", "table1",
    "headline", "cluster_contention", "cluster_fairness", "variability",
];

/// Build the orchestrator plan for one experiment id (the default
/// workload sets the paper uses).  `None` for unknown ids.
pub fn plan_for(id: &str, r: &Runner) -> Option<orchestrator::Plan> {
    let mut plan = match id {
        "fig3" => motivation::plan(r, &ALL),
        "fig8" => main_results::fig8_plan(r, &ALL),
        "fig9" => main_results::fig9_plan(r, &SUBSET),
        "fig10" => main_results::fig10_plan(r, &SUBSET),
        "fig11" => ablations::fig11_plan(r, &SUBSET),
        "fig12" => ablations::fig12_plan(r, &SUBSET),
        "fig13" | "fig14" => disturbance::fig13_14_plan(r, &["pr", "nw"]),
        "fig15" => scaling::fig15_plan(r, &SUBSET),
        "fig16" => ablations::fig16_plan(r, &SUBSET),
        "fig17" => scaling::fig17_plan(r, &SUBSET),
        "fig18" => scaling::fig18_plan(r),
        "fig19" => main_results::fig19_plan(r, &SUBSET),
        "fig20" => ablations::fig20_plan(r, &SUBSET),
        "fig21" => ablations::fig21_plan(r, &SUBSET),
        "fig22" => scaling::fig22_plan(r, &SUBSET),
        "table1" => table1::plan(),
        "headline" => main_results::headline_plan(r),
        "cluster_contention" => cluster::cluster_contention_plan(r),
        "cluster_fairness" => cluster::cluster_fairness_plan(r),
        "variability" => variability::variability_plan(r),
        "ablation_dirty_threshold" => {
            ablations::ablation_dirty_threshold_plan(r, &SUBSET)
        }
        "ablation_buffer_size" => ablations::ablation_buffer_size_plan(r, &SUBSET),
        _ => return None,
    };
    plan.id = id.to_string();
    Some(plan)
}

/// Run one experiment by id through the orchestrator.
pub fn run_experiment(id: &str, r: &Runner) -> Option<Vec<Table>> {
    Some(orchestrator::run_plan(r, plan_for(id, r)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ids_resolve() {
        let r = Runner::test();
        // table1 is cheap enough to actually run here.
        assert!(run_experiment("table1", &r).is_some());
        assert!(run_experiment("nope", &r).is_none());
        for id in ALL_EXPERIMENTS {
            assert!(plan_for(id, &r).is_some(), "no plan for {id}");
        }
        // fig14 aliases the fig13 plan but keeps its requested id.
        assert_eq!(plan_for("fig14", &r).unwrap().id, "fig14");
    }

    #[test]
    fn plans_declare_nonempty_grids() {
        let r = Runner::test();
        for id in ALL_EXPERIMENTS {
            let p = plan_for(id, &r).unwrap();
            if id != "table1" {
                assert!(!p.cells.is_empty(), "{id} declared no cells");
            }
        }
    }
}
