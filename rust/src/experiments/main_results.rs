//! Figs. 8, 9, 10 and 19 — the paper's core evaluation.
//!
//! Fig. 8: speedup of LC/BP/PQ/DaeMon/Local over Remote across the
//!         {100,400ns} x {1/2,1/4,1/8} network grid, all workloads.
//! Fig. 9: average data access cost normalized to Remote.
//! Fig.10: local-memory hit ratio (+ extra pages DaeMon moves over PQ).
//! Fig.19: network bandwidth utilization.
//!
//! Each figure declares its cells as a [`Plan`]; execution goes through
//! the orchestrator's flat scheduler (see `orchestrator.rs`), so figure
//! entry points here only enumerate cells and assemble tables.

use super::common::{net_grid, speedup, Runner};
use super::orchestrator::{self, CellSpec, Plan};
use crate::config::SimConfig;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::{fmt_num, Table};
use crate::workloads::{ALL, SUBSET};

/// All-scheme grid used by several figures: per workload, per net config,
/// run Remote + the eval set.
fn schemes() -> Vec<SchemeKind> {
    vec![
        SchemeKind::Remote,
        SchemeKind::Lc,
        SchemeKind::Bp,
        SchemeKind::Pq,
        SchemeKind::Daemon,
        SchemeKind::Local,
    ]
}

fn owned(workloads: &[&str]) -> Vec<String> {
    workloads.iter().map(|s| s.to_string()).collect()
}

pub fn fig8_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let schemes = schemes();
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for (_, sw, bw) in net_grid() {
        let cfg = SimConfig::default().with_net(sw, bw);
        for wl in &workloads {
            for &k in &schemes {
                cells.push(CellSpec::new(wl, k, cfg.clone()));
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let schemes = schemes;
        let per_net = workloads.len() * schemes.len();
        let mut tables = Vec::new();
        for (g, (label, _, _)) in net_grid().iter().enumerate() {
            let block = &ms[g * per_net..(g + 1) * per_net];
            let mut table = Table::new(
                &format!("Fig 8: speedup over Remote ({label})"),
                &{
                    let mut h = vec!["workload"];
                    h.extend(schemes.iter().skip(1).map(|s| s.name()));
                    h
                },
            );
            let mut per: Vec<Vec<f64>> = vec![Vec::new(); schemes.len() - 1];
            for (w, wl) in workloads.iter().enumerate() {
                let row = &block[w * schemes.len()..(w + 1) * schemes.len()];
                let base = &row[0];
                let vals: Vec<f64> = row[1..].iter().map(|m| speedup(m, base)).collect();
                for (i, v) in vals.iter().enumerate() {
                    per[i].push(*v);
                }
                table.row_f(wl, &vals);
            }
            table.row_f("geomean", &per.iter().map(|v| geomean(v)).collect::<Vec<_>>());
            tables.push(table);
        }
        tables
    });
    Plan { id: "fig8".into(), cells, assemble }
}

pub fn fig8(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig8_plan(r, workloads))
}

pub fn fig9_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default();
    let schemes = schemes();
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        for &k in &schemes {
            cells.push(CellSpec::new(wl, k, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let schemes = schemes;
        let mut table = Table::new(
            "Fig 9: data access cost normalized to Remote (lower is better)",
            &{
                let mut h = vec!["workload"];
                h.extend(schemes.iter().skip(1).map(|s| s.name()));
                h
            },
        );
        let mut per: Vec<Vec<f64>> = vec![Vec::new(); schemes.len() - 1];
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * schemes.len()..(w + 1) * schemes.len()];
            let base = row[0].mean_access_cost().max(1e-9);
            let vals: Vec<f64> = row[1..]
                .iter()
                .map(|m| m.mean_access_cost() / base)
                .collect();
            for (i, v) in vals.iter().enumerate() {
                per[i].push(*v);
            }
            table.row_f(wl, &vals);
        }
        table.row_f("geomean", &per.iter().map(|v| geomean(v)).collect::<Vec<_>>());
        vec![table]
    });
    Plan { id: "fig9".into(), cells, assemble }
}

pub fn fig9(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig9_plan(r, workloads))
}

pub fn fig10_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default();
    let kinds = [SchemeKind::Remote, SchemeKind::Pq, SchemeKind::Daemon];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        for &k in &kinds {
            cells.push(CellSpec::new(wl, k, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let mut table = Table::new(
            "Fig 10: local memory hit ratio (+DaeMon extra pages over PQ, %)",
            &["workload", "Remote", "PQ", "DaeMon", "extra-pages-%"],
        );
        let mut cols: Vec<Vec<f64>> = vec![Vec::new(); 4];
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * kinds.len()..(w + 1) * kinds.len()];
            let extra = if row[1].pages_moved == 0 {
                0.0
            } else {
                100.0 * (row[2].pages_moved as f64 - row[1].pages_moved as f64)
                    / row[1].pages_moved as f64
            };
            let vals = [
                row[0].local_hit_ratio(),
                row[1].local_hit_ratio(),
                row[2].local_hit_ratio(),
                extra,
            ];
            for (i, v) in vals.iter().enumerate() {
                cols[i].push(*v);
            }
            table.row_f(wl, &vals);
        }
        table.row(vec![
            "mean".into(),
            fmt_num(crate::util::stats::mean(&cols[0])),
            fmt_num(crate::util::stats::mean(&cols[1])),
            fmt_num(crate::util::stats::mean(&cols[2])),
            fmt_num(crate::util::stats::mean(&cols[3])),
        ]);
        vec![table]
    });
    Plan { id: "fig10".into(), cells, assemble }
}

pub fn fig10(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig10_plan(r, workloads))
}

pub fn fig19_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default();
    let schemes = schemes();
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        for &k in &schemes {
            cells.push(CellSpec::new(wl, k, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let schemes = schemes;
        let mut table = Table::new(
            "Fig 19: network bandwidth utilization (%)",
            &{
                let mut h = vec!["workload"];
                h.extend(schemes.iter().map(|s| s.name()));
                h
            },
        );
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * schemes.len()..(w + 1) * schemes.len()];
            let vals: Vec<f64> = row.iter().map(|m| 100.0 * m.net_utilization).collect();
            table.row_f(wl, &vals);
        }
        vec![table]
    });
    Plan { id: "fig19".into(), cells, assemble }
}

pub fn fig19(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig19_plan(r, workloads))
}

/// Headline cells: `(Remote, DaeMon)` per workload at the default config.
fn headline_cells() -> Vec<CellSpec> {
    let cfg = SimConfig::default();
    let mut cells = Vec::new();
    for wl in ALL {
        cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg.clone()));
        cells.push(CellSpec::new(wl, SchemeKind::Daemon, cfg.clone()));
    }
    cells
}

fn headline_assemble(ms: &[Metrics]) -> (f64, f64, Table) {
    let mut speedups = Vec::new();
    let mut cost_gains = Vec::new();
    let mut table = Table::new(
        "Headline: DaeMon vs Remote (paper: 2.39x speedup, 3.06x access cost)",
        &["workload", "speedup", "access-cost-gain", "hit-Remote", "hit-DaeMon"],
    );
    for (w, wl) in ALL.iter().enumerate() {
        let (remote, daemon) = (&ms[2 * w], &ms[2 * w + 1]);
        let sp = speedup(daemon, remote);
        let cg = remote.mean_access_cost() / daemon.mean_access_cost().max(1e-9);
        speedups.push(sp);
        cost_gains.push(cg);
        table.row_f(
            wl,
            &[sp, cg, remote.local_hit_ratio(), daemon.local_hit_ratio()],
        );
    }
    let (s, c) = (geomean(&speedups), geomean(&cost_gains));
    table.row_f("geomean", &[s, c, 0.0, 0.0]);
    (s, c, table)
}

pub fn headline_plan(_r: &Runner) -> Plan {
    Plan {
        id: "headline".into(),
        cells: headline_cells(),
        assemble: Box::new(|ms| vec![headline_assemble(ms).2]),
    }
}

/// Headline numbers (abstract): DaeMon vs Remote geomean speedup and
/// access-cost improvement across all workloads at the default config.
pub fn headline(r: &Runner) -> (f64, f64, Table) {
    let ms = orchestrator::run_plan_metrics(r, &headline_cells());
    headline_assemble(&ms)
}

pub fn fig8_default(r: &Runner) -> Vec<Table> {
    fig8(r, &ALL)
}

pub fn fig9_default(r: &Runner) -> Vec<Table> {
    fig9(r, &SUBSET)
}

pub fn fig10_default(r: &Runner) -> Vec<Table> {
    fig10(r, &SUBSET)
}

pub fn fig19_default(r: &Runner) -> Vec<Table> {
    fig19(r, &SUBSET)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_and_10_shapes() {
        let r = Runner::test();
        let t9 = fig9(&r, &["pr"]);
        assert_eq!(t9[0].rows.len(), 2);
        let t10 = fig10(&r, &["pr"]);
        // Hit ratios are probabilities.
        let hit: f64 = t10[0].rows[0][1].parse().unwrap();
        assert!((0.0..=1.0).contains(&hit));
    }

    #[test]
    fn headline_runs_on_two_workloads() {
        // Shrunken sanity: DaeMon >= Remote on a low-locality workload.
        let r = Runner::test();
        let cfg = crate::config::SimConfig::test_scale();
        let (trace, profile) = r.gen_trace("pr", cfg.seed);
        let cells = vec![
            (SchemeKind::Remote, cfg.clone()),
            (SchemeKind::Daemon, cfg.clone()),
        ];
        let ms = r.run_cells(&trace, profile, &cells);
        assert!(speedup(&ms[1], &ms[0]) > 0.9);
    }
}
