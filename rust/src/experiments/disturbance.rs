//! Figs. 13 and 14 — performance and hit ratio under runtime network
//! disturbance: artificial packets injected by other compute components
//! sharing the network, as a square wave of load phases.
//!
//! Disturbed cells are ordinary orchestrator cells with a
//! `(load, period)` disturbance attached; the interval series needed for
//! the time plots ride along inside [`Metrics`], so sharded runs
//! reassemble these figures like any other.

use super::common::Runner;
use super::orchestrator::{self, CellSpec, Plan};
use crate::config::{ns_to_cycles, SimConfig};
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;

/// Figs. 13/14 for `pr` and `nw` (the paper's two highest data-movement
/// workloads): overall IPC + windowed series under a 60%-load square wave.
pub fn fig13_14_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default();
    let period = ns_to_cycles(2_000_000.0); // 2ms disturbance phases
    let kinds = [SchemeKind::Lc, SchemeKind::Pq, SchemeKind::Daemon];
    let workloads: Vec<String> = workloads.iter().map(|s| s.to_string()).collect();
    let mut cells = Vec::new();
    for wl in &workloads {
        for &k in &kinds {
            cells.push(CellSpec::disturbed(wl, k, cfg.clone(), 0.6, period));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let interval = ns_to_cycles(cfg.interval_ns);
        let mut tables = Vec::new();
        let mut summary = Table::new(
            "Fig 13: IPC under runtime network disturbance (60% injected load)",
            &["workload", "LC", "PQ", "DaeMon", "DaeMon/LC", "DaeMon/PQ"],
        );
        let mut dm_lc = Vec::new();
        let mut dm_pq = Vec::new();
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * kinds.len()..(w + 1) * kinds.len()];
            let ipcs: Vec<f64> = row.iter().map(|m| m.ipc()).collect();
            let series: Vec<Vec<f64>> =
                row.iter().map(|m| m.ipc_series(interval)).collect();
            let hits: Vec<Vec<f64>> =
                row.iter().map(|m| m.hit_ratio_series()).collect();
            dm_lc.push(ipcs[2] / ipcs[0].max(1e-12));
            dm_pq.push(ipcs[2] / ipcs[1].max(1e-12));
            summary.row_f(
                wl,
                &[
                    ipcs[0],
                    ipcs[1],
                    ipcs[2],
                    ipcs[2] / ipcs[0].max(1e-12),
                    ipcs[2] / ipcs[1].max(1e-12),
                ],
            );

            // Time-series tables (coarsened to 10 buckets).
            let mut ts = Table::new(
                &format!("Fig 13 series: {wl} per-phase IPC"),
                &["phase", "LC", "PQ", "DaeMon"],
            );
            let mut hr = Table::new(
                &format!("Fig 14 series: {wl} per-phase local hit ratio"),
                &["phase", "LC", "PQ", "DaeMon"],
            );
            let buckets = 10;
            let len = series.iter().map(|s| s.len()).min().unwrap_or(0);
            if len >= buckets {
                let chunk = len / buckets;
                for b in 0..buckets {
                    let avg = |v: &Vec<f64>| {
                        let s = &v[b * chunk..(b + 1) * chunk];
                        s.iter().sum::<f64>() / s.len() as f64
                    };
                    ts.row_f(
                        &format!("{b}"),
                        &[avg(&series[0]), avg(&series[1]), avg(&series[2])],
                    );
                    let havg = |v: &Vec<f64>| {
                        let s = &v[(b * chunk).min(v.len().saturating_sub(1))
                            ..((b + 1) * chunk).min(v.len())];
                        if s.is_empty() {
                            0.0
                        } else {
                            s.iter().sum::<f64>() / s.len() as f64
                        }
                    };
                    hr.row_f(
                        &format!("{b}"),
                        &[havg(&hits[0]), havg(&hits[1]), havg(&hits[2])],
                    );
                }
            }
            tables.push(ts);
            tables.push(hr);
        }
        summary.row_f("geomean", &[0.0, 0.0, 0.0, geomean(&dm_lc), geomean(&dm_pq)]);
        tables.insert(0, summary);
        tables
    });
    Plan { id: "fig13".into(), cells, assemble }
}

pub fn fig13_14(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig13_14_plan(r, workloads))
}

pub fn fig13_14_default(r: &Runner) -> Vec<Table> {
    fig13_14(r, &["pr", "nw"])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::cache::TraceCache;

    /// Run one workload under disturbance, returning per-interval IPC and
    /// hit-ratio series (the same cell path fig13/14 declare).
    fn run_disturbed(
        r: &Runner,
        wl: &str,
        kind: SchemeKind,
        cfg: &SimConfig,
        load: f64,
        period_cycles: f64,
    ) -> (Vec<f64>, Vec<f64>, f64) {
        let spec = CellSpec::disturbed(wl, kind, cfg.clone(), load, period_cycles);
        let m = orchestrator::run_cell_spec(r, TraceCache::global(), &spec)
            .pop()
            .expect("single-machine cell yields one metrics");
        let interval = ns_to_cycles(cfg.interval_ns);
        (m.ipc_series(interval), m.hit_ratio_series(), m.ipc())
    }

    #[test]
    fn disturbance_slows_execution() {
        let r = Runner::test();
        let cfg = SimConfig::test_scale();
        let (_, _, quiet) = run_disturbed(&r, "pr", SchemeKind::Daemon, &cfg, 0.0, 1e9);
        let (_, _, loud) = run_disturbed(&r, "pr", SchemeKind::Daemon, &cfg, 0.9, 1e4);
        assert!(loud < quiet, "disturbed {loud} vs quiet {quiet}");
    }

    #[test]
    fn series_are_produced() {
        let r = Runner::test();
        let tables = fig13_14(&r, &["pr"]);
        assert!(tables.len() >= 3);
        assert!(!tables[0].rows.is_empty());
    }
}
