//! Figs. 13 and 14 — performance and hit ratio under runtime network
//! disturbance: artificial packets injected by other compute components
//! sharing the network, as a square wave of load phases.

use super::common::Runner;
use crate::config::{ns_to_cycles, SimConfig};
use crate::net::Disturbance;
use crate::schemes::SchemeKind;
use crate::system::Machine;
use crate::util::stats::geomean;
use crate::util::table::Table;

/// Run one workload under disturbance, returning per-interval IPC and
/// hit-ratio series.
fn run_disturbed(
    r: &Runner,
    wl: &str,
    kind: SchemeKind,
    cfg: &SimConfig,
    load: f64,
    period_cycles: f64,
) -> (Vec<f64>, Vec<f64>, f64) {
    let (trace, profile) = r.gen_trace(wl, cfg.seed);
    let mut m = Machine::new(
        cfg.clone(),
        kind,
        trace.footprint_pages,
        vec![profile; cfg.cores.max(1)],
        None,
    );
    m.set_disturbance(|capacity| {
        Disturbance::square_wave(period_cycles, load, 1e12, 5_000.0, capacity)
    });
    m.run(std::slice::from_ref(&trace));
    let interval = ns_to_cycles(cfg.interval_ns);
    (
        m.metrics.ipc_series(interval),
        m.metrics.hit_ratio_series(),
        m.metrics.ipc(),
    )
}

/// Figs. 13/14 for `pr` and `nw` (the paper's two highest data-movement
/// workloads): overall IPC + windowed series under a 60%-load square wave.
pub fn fig13_14(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let cfg = SimConfig::default();
    let period = ns_to_cycles(2_000_000.0); // 2ms disturbance phases
    let kinds = [SchemeKind::Lc, SchemeKind::Pq, SchemeKind::Daemon];
    let mut tables = Vec::new();

    let mut summary = Table::new(
        "Fig 13: IPC under runtime network disturbance (60% injected load)",
        &["workload", "LC", "PQ", "DaeMon", "DaeMon/LC", "DaeMon/PQ"],
    );
    let mut dm_lc = Vec::new();
    let mut dm_pq = Vec::new();
    for wl in workloads {
        let mut ipcs = Vec::new();
        let mut series: Vec<Vec<f64>> = Vec::new();
        let mut hits: Vec<Vec<f64>> = Vec::new();
        for &k in &kinds {
            let (s, h, ipc) = run_disturbed(r, wl, k, &cfg, 0.6, period);
            ipcs.push(ipc);
            series.push(s);
            hits.push(h);
        }
        dm_lc.push(ipcs[2] / ipcs[0].max(1e-12));
        dm_pq.push(ipcs[2] / ipcs[1].max(1e-12));
        summary.row_f(
            wl,
            &[
                ipcs[0],
                ipcs[1],
                ipcs[2],
                ipcs[2] / ipcs[0].max(1e-12),
                ipcs[2] / ipcs[1].max(1e-12),
            ],
        );

        // Time-series tables (coarsened to 10 buckets).
        let mut ts = Table::new(
            &format!("Fig 13 series: {wl} per-phase IPC"),
            &["phase", "LC", "PQ", "DaeMon"],
        );
        let mut hr = Table::new(
            &format!("Fig 14 series: {wl} per-phase local hit ratio"),
            &["phase", "LC", "PQ", "DaeMon"],
        );
        let buckets = 10;
        let len = series.iter().map(|s| s.len()).min().unwrap_or(0);
        if len >= buckets {
            let chunk = len / buckets;
            for b in 0..buckets {
                let avg = |v: &Vec<f64>| {
                    let s = &v[b * chunk..(b + 1) * chunk];
                    s.iter().sum::<f64>() / s.len() as f64
                };
                ts.row_f(&format!("{b}"), &[avg(&series[0]), avg(&series[1]), avg(&series[2])]);
                let havg = |v: &Vec<f64>| {
                    let s = &v[(b * chunk).min(v.len().saturating_sub(1))
                        ..((b + 1) * chunk).min(v.len())];
                    if s.is_empty() {
                        0.0
                    } else {
                        s.iter().sum::<f64>() / s.len() as f64
                    }
                };
                hr.row_f(&format!("{b}"), &[havg(&hits[0]), havg(&hits[1]), havg(&hits[2])]);
            }
        }
        tables.push(ts);
        tables.push(hr);
    }
    summary.row_f("geomean", &[0.0, 0.0, 0.0, geomean(&dm_lc), geomean(&dm_pq)]);
    tables.insert(0, summary);
    tables
}

pub fn fig13_14_default(r: &Runner) -> Vec<Table> {
    fig13_14(r, &["pr", "nw"])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disturbance_slows_execution() {
        let r = Runner::test();
        let cfg = SimConfig::test_scale();
        let (_, _, quiet) = run_disturbed(&r, "pr", SchemeKind::Daemon, &cfg, 0.0, 1e9);
        let (_, _, loud) = run_disturbed(&r, "pr", SchemeKind::Daemon, &cfg, 0.9, 1e4);
        assert!(loud < quiet, "disturbed {loud} vs quiet {quiet}");
    }

    #[test]
    fn series_are_produced() {
        let r = Runner::test();
        let tables = fig13_14(&r, &["pr"]);
        assert!(tables.len() >= 3);
        assert!(!tables[0].rows.is_empty());
    }
}
