//! Figs. 11, 12, 16, 20, 21 — the paper's ablations and sensitivity
//! studies, plus our own ablations called out in DESIGN.md.

use super::common::{speedup, Runner};
use crate::compress::Algo;
use crate::config::{Replacement, SimConfig};
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::SUBSET;

/// Fig. 11 — bandwidth partitioning ratio sweep for PQ and DaeMon.
pub fn fig11(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let ratios = [0.10, 0.25, 0.50, 0.80];
    let mut tables = Vec::new();
    for &sw in &[100.0, 400.0] {
        for kind in [SchemeKind::Pq, SchemeKind::Daemon] {
            let mut table = Table::new(
                &format!(
                    "Fig 11: {} speedup over Remote vs partition ratio ({}ns)",
                    kind.name(),
                    sw as u32
                ),
                &["workload", "10%", "25%", "50%", "80%"],
            );
            let mut per: Vec<Vec<f64>> = vec![Vec::new(); ratios.len()];
            for wl in workloads {
                let base_cfg = SimConfig::default().with_net(sw, 4.0);
                let (trace, profile) = r.gen_trace(wl, base_cfg.seed);
                let mut cells = vec![(SchemeKind::Remote, base_cfg.clone())];
                for &ratio in &ratios {
                    cells.push((kind, base_cfg.clone().with_partition_ratio(ratio)));
                }
                let ms = r.run_cells(&trace, profile, &cells);
                let vals: Vec<f64> =
                    ms[1..].iter().map(|m| speedup(m, &ms[0])).collect();
                for (i, v) in vals.iter().enumerate() {
                    per[i].push(*v);
                }
                table.row_f(wl, &vals);
            }
            table.row_f("geomean", &per.iter().map(|v| geomean(v)).collect::<Vec<_>>());
            tables.push(table);
        }
    }
    tables
}

/// Fig. 12 — LC with the three compression schemes.
pub fn fig12(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let algos = [Algo::FpcBdi, Algo::Fve, Algo::Lz];
    let cfg0 = SimConfig::default();
    let mut table = Table::new(
        "Fig 12: LC speedup over Remote by compression scheme",
        &["workload", "fpcbdi", "fve", "LZ", "ratio-fpcbdi", "ratio-fve", "ratio-LZ"],
    );
    let mut per: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for wl in workloads {
        let (trace, profile) = r.gen_trace(wl, cfg0.seed);
        let mut cells = vec![(SchemeKind::Remote, cfg0.clone())];
        for &a in &algos {
            let mut c = cfg0.clone().with_compress(Some(a));
            c.daemon.compress_cycles = a.latency_cycles();
            cells.push((SchemeKind::Lc, c));
        }
        let ms = r.run_cells(&trace, profile, &cells);
        let mut vals: Vec<f64> = ms[1..].iter().map(|m| speedup(m, &ms[0])).collect();
        for (i, v) in vals.iter().enumerate() {
            per[i].push(*v);
        }
        vals.extend(ms[1..].iter().map(|m| m.compression_ratio));
        table.row_f(wl, &vals);
    }
    let mut gm: Vec<f64> = per.iter().map(|v| geomean(v)).collect();
    gm.extend([0.0, 0.0, 0.0]);
    table.row_f("geomean", &gm);
    vec![table]
}

/// Fig. 16 — FIFO replacement in local memory.
pub fn fig16(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let cfg = SimConfig::default().with_replacement(Replacement::Fifo);
    let mut table = Table::new(
        "Fig 16: Local and DaeMon over Remote with FIFO local memory",
        &["workload", "Local", "DaeMon"],
    );
    let mut per: Vec<Vec<f64>> = vec![Vec::new(); 2];
    for wl in workloads {
        let (trace, profile) = r.gen_trace(wl, cfg.seed);
        let cells = vec![
            (SchemeKind::Remote, cfg.clone()),
            (SchemeKind::Local, cfg.clone()),
            (SchemeKind::Daemon, cfg.clone()),
        ];
        let ms = r.run_cells(&trace, profile, &cells);
        let vals = [speedup(&ms[1], &ms[0]), speedup(&ms[2], &ms[0])];
        per[0].push(vals[0]);
        per[1].push(vals[1]);
        table.row_f(wl, &vals);
    }
    table.row_f("geomean", &[geomean(&per[0]), geomean(&per[1])]);
    vec![table]
}

/// Fig. 20 — switch latency sweep (appendix A.2).
pub fn fig20(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let latencies = [100.0, 200.0, 400.0, 700.0, 1000.0];
    let mut table = Table::new(
        "Fig 20: DaeMon speedup over Remote vs switch latency (geomean)",
        &["switch-ns", "speedup"],
    );
    for &sw in &latencies {
        let cfg = SimConfig::default().with_net(sw, 4.0);
        let mut sp = Vec::new();
        for wl in workloads {
            let (trace, profile) = r.gen_trace(wl, cfg.seed);
            let cells = vec![
                (SchemeKind::Remote, cfg.clone()),
                (SchemeKind::Daemon, cfg.clone()),
            ];
            let ms = r.run_cells(&trace, profile, &cells);
            sp.push(speedup(&ms[1], &ms[0]));
        }
        table.row_f(&format!("{}", sw as u32), &[geomean(&sp)]);
    }
    vec![table]
}

/// Fig. 21 — bandwidth factor sweep with 8-core multithreaded runs
/// (appendix A.3).
pub fn fig21(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let factors = [2.0, 4.0, 8.0, 16.0];
    let mut table = Table::new(
        "Fig 21: DaeMon speedup over Remote vs bandwidth factor (8 cores)",
        &["bw-factor", "speedup"],
    );
    for &bw in &factors {
        let cfg = SimConfig::default().with_net(100.0, bw).with_cores(8);
        let mut sp = Vec::new();
        for wl in workloads {
            let (trace, profile) = r.gen_trace(wl, cfg.seed);
            let cells = vec![
                (SchemeKind::Remote, cfg.clone()),
                (SchemeKind::Daemon, cfg.clone()),
            ];
            let ms = r.run_cells(&trace, profile, &cells);
            sp.push(speedup(&ms[1], &ms[0]));
        }
        table.row_f(&format!("1/{}", bw as u32), &[geomean(&sp)]);
    }
    vec![table]
}

/// Our ablation: dirty-buffer flush threshold (DESIGN.md).
pub fn ablation_dirty_threshold(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let thresholds = [2usize, 8, 32];
    let mut table = Table::new(
        "Ablation: DaeMon speedup over Remote vs dirty flush threshold",
        &["workload", "2", "8", "32"],
    );
    for wl in workloads {
        let cfg0 = SimConfig::default();
        let (trace, profile) = r.gen_trace(wl, cfg0.seed);
        let mut cells = vec![(SchemeKind::Remote, cfg0.clone())];
        for &t in &thresholds {
            let mut c = cfg0.clone();
            c.daemon.dirty_flush_threshold = t;
            cells.push((SchemeKind::Daemon, c));
        }
        let ms = r.run_cells(&trace, profile, &cells);
        let vals: Vec<f64> = ms[1..].iter().map(|m| speedup(m, &ms[0])).collect();
        table.row_f(wl, &vals);
    }
    vec![table]
}

/// Our ablation: inflight buffer sizing.
pub fn ablation_buffer_size(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let sizes = [(32usize, 64usize), (128, 256), (512, 1024)];
    let mut table = Table::new(
        "Ablation: DaeMon speedup over Remote vs inflight buffer sizes",
        &["workload", "32/64", "128/256", "512/1024"],
    );
    for wl in workloads {
        let cfg0 = SimConfig::default();
        let (trace, profile) = r.gen_trace(wl, cfg0.seed);
        let mut cells = vec![(SchemeKind::Remote, cfg0.clone())];
        for &(l, p) in &sizes {
            let mut c = cfg0.clone();
            c.daemon.inflight_subblock_buf = l;
            c.daemon.inflight_page_buf = p;
            cells.push((SchemeKind::Daemon, c));
        }
        let ms = r.run_cells(&trace, profile, &cells);
        let vals: Vec<f64> = ms[1..].iter().map(|m| speedup(m, &ms[0])).collect();
        table.row_f(wl, &vals);
    }
    vec![table]
}

pub fn fig11_default(r: &Runner) -> Vec<Table> {
    fig11(r, &SUBSET)
}
pub fn fig12_default(r: &Runner) -> Vec<Table> {
    fig12(r, &SUBSET)
}
pub fn fig16_default(r: &Runner) -> Vec<Table> {
    fig16(r, &SUBSET)
}
pub fn fig20_default(r: &Runner) -> Vec<Table> {
    fig20(r, &SUBSET)
}
pub fn fig21_default(r: &Runner) -> Vec<Table> {
    fig21(r, &SUBSET)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_orders_lz_best_on_compressible() {
        let r = Runner::test();
        let t = fig12(&r, &["sp"]);
        let row = &t[0].rows[0];
        let lz_ratio: f64 = row[6].parse().unwrap();
        let fpc_ratio: f64 = row[4].parse().unwrap();
        assert!(lz_ratio > fpc_ratio, "LZ {lz_ratio} vs fpcbdi {fpc_ratio}");
    }

    #[test]
    fn fig16_runs_fifo() {
        let r = Runner::test();
        let t = fig16(&r, &["bf"]);
        let local: f64 = t[0].rows[0][1].parse().unwrap();
        assert!(local > 1.0, "Local must beat Remote under FIFO: {local}");
    }
}
