//! Figs. 11, 12, 16, 20, 21 — the paper's ablations and sensitivity
//! studies, plus our own ablations called out in DESIGN.md.
//!
//! Cells are declared as orchestrator [`Plan`]s (see `orchestrator.rs`);
//! the figure entry points run their plan through the flat scheduler.

use super::common::{speedup, Runner};
use super::orchestrator::{self, CellSpec, Plan};
use crate::compress::Algo;
use crate::config::{Replacement, SimConfig};
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::SUBSET;

fn owned(workloads: &[&str]) -> Vec<String> {
    workloads.iter().map(|s| s.to_string()).collect()
}

/// Fig. 11 — bandwidth partitioning ratio sweep for PQ and DaeMon.
pub fn fig11_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const RATIOS: [f64; 4] = [0.10, 0.25, 0.50, 0.80];
    let kinds = [SchemeKind::Pq, SchemeKind::Daemon];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for &sw in &[100.0, 400.0] {
        for &kind in &kinds {
            for wl in &workloads {
                let base_cfg = SimConfig::default().with_net(sw, 4.0);
                cells.push(CellSpec::new(wl, SchemeKind::Remote, base_cfg.clone()));
                for &ratio in &RATIOS {
                    cells.push(CellSpec::new(
                        wl,
                        kind,
                        base_cfg.clone().with_partition_ratio(ratio),
                    ));
                }
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_wl = 1 + RATIOS.len();
        let per_table = workloads.len() * per_wl;
        let mut tables = Vec::new();
        for (s, &sw) in [100.0f64, 400.0].iter().enumerate() {
            for (k, kind) in kinds.iter().enumerate() {
                let block_idx = s * kinds.len() + k;
                let block = &ms[block_idx * per_table..(block_idx + 1) * per_table];
                let mut table = Table::new(
                    &format!(
                        "Fig 11: {} speedup over Remote vs partition ratio ({}ns)",
                        kind.name(),
                        sw as u32
                    ),
                    &["workload", "10%", "25%", "50%", "80%"],
                );
                let mut per: Vec<Vec<f64>> = vec![Vec::new(); RATIOS.len()];
                for (w, wl) in workloads.iter().enumerate() {
                    let row = &block[w * per_wl..(w + 1) * per_wl];
                    let vals: Vec<f64> =
                        row[1..].iter().map(|m| speedup(m, &row[0])).collect();
                    for (i, v) in vals.iter().enumerate() {
                        per[i].push(*v);
                    }
                    table.row_f(wl, &vals);
                }
                table.row_f(
                    "geomean",
                    &per.iter().map(|v| geomean(v)).collect::<Vec<_>>(),
                );
                tables.push(table);
            }
        }
        tables
    });
    Plan { id: "fig11".into(), cells, assemble }
}

pub fn fig11(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig11_plan(r, workloads))
}

/// Fig. 12 — LC with the three compression schemes.
pub fn fig12_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const ALGOS: [Algo; 3] = [Algo::FpcBdi, Algo::Fve, Algo::Lz];
    let cfg0 = SimConfig::default();
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg0.clone()));
        for &a in &ALGOS {
            let mut c = cfg0.clone().with_compress(Some(a));
            c.daemon.compress_cycles = a.latency_cycles();
            cells.push(CellSpec::new(wl, SchemeKind::Lc, c));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_wl = 1 + ALGOS.len();
        let mut table = Table::new(
            "Fig 12: LC speedup over Remote by compression scheme",
            &["workload", "fpcbdi", "fve", "LZ", "ratio-fpcbdi", "ratio-fve", "ratio-LZ"],
        );
        let mut per: Vec<Vec<f64>> = vec![Vec::new(); 3];
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * per_wl..(w + 1) * per_wl];
            let mut vals: Vec<f64> =
                row[1..].iter().map(|m| speedup(m, &row[0])).collect();
            for (i, v) in vals.iter().enumerate() {
                per[i].push(*v);
            }
            vals.extend(row[1..].iter().map(|m| m.compression_ratio));
            table.row_f(wl, &vals);
        }
        let mut gm: Vec<f64> = per.iter().map(|v| geomean(v)).collect();
        gm.extend([0.0, 0.0, 0.0]);
        table.row_f("geomean", &gm);
        vec![table]
    });
    Plan { id: "fig12".into(), cells, assemble }
}

pub fn fig12(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig12_plan(r, workloads))
}

/// Fig. 16 — FIFO replacement in local memory.
pub fn fig16_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let cfg = SimConfig::default().with_replacement(Replacement::Fifo);
    let kinds = [SchemeKind::Remote, SchemeKind::Local, SchemeKind::Daemon];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for wl in &workloads {
        for &k in &kinds {
            cells.push(CellSpec::new(wl, k, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let mut table = Table::new(
            "Fig 16: Local and DaeMon over Remote with FIFO local memory",
            &["workload", "Local", "DaeMon"],
        );
        let mut per: Vec<Vec<f64>> = vec![Vec::new(); 2];
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * kinds.len()..(w + 1) * kinds.len()];
            let vals = [speedup(&row[1], &row[0]), speedup(&row[2], &row[0])];
            per[0].push(vals[0]);
            per[1].push(vals[1]);
            table.row_f(wl, &vals);
        }
        table.row_f("geomean", &[geomean(&per[0]), geomean(&per[1])]);
        vec![table]
    });
    Plan { id: "fig16".into(), cells, assemble }
}

pub fn fig16(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig16_plan(r, workloads))
}

/// Fig. 20 — switch latency sweep (appendix A.2).
pub fn fig20_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const LATENCIES: [f64; 5] = [100.0, 200.0, 400.0, 700.0, 1000.0];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for &sw in &LATENCIES {
        let cfg = SimConfig::default().with_net(sw, 4.0);
        for wl in &workloads {
            cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg.clone()));
            cells.push(CellSpec::new(wl, SchemeKind::Daemon, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_lat = 2 * workloads.len();
        let mut table = Table::new(
            "Fig 20: DaeMon speedup over Remote vs switch latency (geomean)",
            &["switch-ns", "speedup"],
        );
        for (l, &sw) in LATENCIES.iter().enumerate() {
            let block = &ms[l * per_lat..(l + 1) * per_lat];
            let sp: Vec<f64> = (0..workloads.len())
                .map(|w| speedup(&block[2 * w + 1], &block[2 * w]))
                .collect();
            table.row_f(&format!("{}", sw as u32), &[geomean(&sp)]);
        }
        vec![table]
    });
    Plan { id: "fig20".into(), cells, assemble }
}

pub fn fig20(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig20_plan(r, workloads))
}

/// Fig. 21 — bandwidth factor sweep with 8-core multithreaded runs
/// (appendix A.3).
pub fn fig21_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const FACTORS: [f64; 4] = [2.0, 4.0, 8.0, 16.0];
    let workloads = owned(workloads);
    let mut cells = Vec::new();
    for &bw in &FACTORS {
        let cfg = SimConfig::default().with_net(100.0, bw).with_cores(8);
        for wl in &workloads {
            cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg.clone()));
            cells.push(CellSpec::new(wl, SchemeKind::Daemon, cfg.clone()));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_bw = 2 * workloads.len();
        let mut table = Table::new(
            "Fig 21: DaeMon speedup over Remote vs bandwidth factor (8 cores)",
            &["bw-factor", "speedup"],
        );
        for (b, &bw) in FACTORS.iter().enumerate() {
            let block = &ms[b * per_bw..(b + 1) * per_bw];
            let sp: Vec<f64> = (0..workloads.len())
                .map(|w| speedup(&block[2 * w + 1], &block[2 * w]))
                .collect();
            table.row_f(&format!("1/{}", bw as u32), &[geomean(&sp)]);
        }
        vec![table]
    });
    Plan { id: "fig21".into(), cells, assemble }
}

pub fn fig21(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, fig21_plan(r, workloads))
}

/// Our ablation: dirty-buffer flush threshold (DESIGN.md).
pub fn ablation_dirty_threshold_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const THRESHOLDS: [usize; 3] = [2, 8, 32];
    let workloads = owned(workloads);
    let cfg0 = SimConfig::default();
    let mut cells = Vec::new();
    for wl in &workloads {
        cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg0.clone()));
        for &t in &THRESHOLDS {
            let mut c = cfg0.clone();
            c.daemon.dirty_flush_threshold = t;
            cells.push(CellSpec::new(wl, SchemeKind::Daemon, c));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_wl = 1 + THRESHOLDS.len();
        let mut table = Table::new(
            "Ablation: DaeMon speedup over Remote vs dirty flush threshold",
            &["workload", "2", "8", "32"],
        );
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * per_wl..(w + 1) * per_wl];
            let vals: Vec<f64> = row[1..].iter().map(|m| speedup(m, &row[0])).collect();
            table.row_f(wl, &vals);
        }
        vec![table]
    });
    Plan { id: "ablation_dirty_threshold".into(), cells, assemble }
}

pub fn ablation_dirty_threshold(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, ablation_dirty_threshold_plan(r, workloads))
}

/// Our ablation: inflight buffer sizing.
pub fn ablation_buffer_size_plan(_r: &Runner, workloads: &[&str]) -> Plan {
    const SIZES: [(usize, usize); 3] = [(32, 64), (128, 256), (512, 1024)];
    let workloads = owned(workloads);
    let cfg0 = SimConfig::default();
    let mut cells = Vec::new();
    for wl in &workloads {
        cells.push(CellSpec::new(wl, SchemeKind::Remote, cfg0.clone()));
        for &(l, p) in &SIZES {
            let mut c = cfg0.clone();
            c.daemon.inflight_subblock_buf = l;
            c.daemon.inflight_page_buf = p;
            cells.push(CellSpec::new(wl, SchemeKind::Daemon, c));
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_wl = 1 + SIZES.len();
        let mut table = Table::new(
            "Ablation: DaeMon speedup over Remote vs inflight buffer sizes",
            &["workload", "32/64", "128/256", "512/1024"],
        );
        for (w, wl) in workloads.iter().enumerate() {
            let row = &ms[w * per_wl..(w + 1) * per_wl];
            let vals: Vec<f64> = row[1..].iter().map(|m| speedup(m, &row[0])).collect();
            table.row_f(wl, &vals);
        }
        vec![table]
    });
    Plan { id: "ablation_buffer_size".into(), cells, assemble }
}

pub fn ablation_buffer_size(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, ablation_buffer_size_plan(r, workloads))
}

pub fn fig11_default(r: &Runner) -> Vec<Table> {
    fig11(r, &SUBSET)
}
pub fn fig12_default(r: &Runner) -> Vec<Table> {
    fig12(r, &SUBSET)
}
pub fn fig16_default(r: &Runner) -> Vec<Table> {
    fig16(r, &SUBSET)
}
pub fn fig20_default(r: &Runner) -> Vec<Table> {
    fig20(r, &SUBSET)
}
pub fn fig21_default(r: &Runner) -> Vec<Table> {
    fig21(r, &SUBSET)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_orders_lz_best_on_compressible() {
        let r = Runner::test();
        let t = fig12(&r, &["sp"]);
        let row = &t[0].rows[0];
        let lz_ratio: f64 = row[6].parse().unwrap();
        let fpc_ratio: f64 = row[4].parse().unwrap();
        assert!(lz_ratio > fpc_ratio, "LZ {lz_ratio} vs fpcbdi {fpc_ratio}");
    }

    #[test]
    fn fig16_runs_fifo() {
        let r = Runner::test();
        let t = fig16(&r, &["bf"]);
        let local: f64 = t[0].rows[0][1].parse().unwrap();
        assert!(local > 1.0, "Local must beat Remote under FIFO: {local}");
    }

    #[test]
    fn fig11_block_layout_matches_legacy_shape() {
        let r = Runner::test();
        let tables = fig11(&r, &["pr"]);
        // 2 switch latencies x 2 schemes.
        assert_eq!(tables.len(), 4);
        assert!(tables[0].title.contains("PQ") && tables[0].title.contains("100ns"));
        assert!(tables[3].title.contains("DaeMon") && tables[3].title.contains("400ns"));
        // 1 workload + geomean rows, 4 ratio columns.
        assert_eq!(tables[0].rows.len(), 2);
        assert_eq!(tables[0].headers.len(), 5);
    }
}
