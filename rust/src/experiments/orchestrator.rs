//! Unified experiment orchestrator: every figure declares its simulation
//! cells up front (a [`Plan`]); the orchestrator batches all requested
//! figures' cells into **one flat job list**, runs them with a
//! work-stealing scheduler over OS threads, and hands each figure its
//! slice of results to assemble into tables.
//!
//! Three properties fall out of the design:
//!
//! * **Shared traces** — cells pull traces from a [`TraceCache`], so a
//!   `(workload, scale, seed, cap)` trace is generated once per sweep no
//!   matter how many figures touch it (the seed harness regenerated per
//!   figure).
//! * **Lock-free result collection** — workers claim cell indices from an
//!   atomic cursor and fill per-cell `OnceLock` slots; no `Mutex` guards
//!   the output vector, and results are deterministic in slot order
//!   regardless of thread count.
//! * **Sharding** — because the job list is flat and its order is a pure
//!   function of the experiment ids, a [`Shard`] can deterministically
//!   split it across CI jobs or machines (`slot % total == index`).  Each
//!   shard emits its raw per-slot metrics as JSON; [`merge_shards`]
//!   recombines them and re-runs the same deterministic assembly, so the
//!   merged figure set is byte-identical to an unsharded run.

use super::common::Runner;
use super::plan_for;
use crate::config::{
    ClusterConfig, ControllerSpec, ScheduleSpec, ServiceSpec, SharingMode, SimConfig,
};
use crate::metrics::Metrics;
use crate::net::Disturbance;
use crate::obs::{ObsSpec, Recorder};
use crate::schemes::SchemeKind;
use crate::system::fault::{FaultPlan, RecoveryPolicy};
use crate::system::{cluster, Machine};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::cache::TraceCache;
use crate::workloads::{Scale, Trace};
use crate::compress::synth::Profile;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// A multi-tenant cluster cell: one `(workload, scheme)` per tenant over
/// `modules` shared memory modules on the switched fabric.  Contributes
/// one `Metrics` per tenant to the flat result list.
#[derive(Clone, Debug)]
pub struct ClusterCell {
    pub tenants: Vec<(String, SchemeKind)>,
    pub modules: usize,
    /// Per-tenant fabric/bus bandwidth weights (empty = equal).
    pub weights: Vec<f64>,
    /// Extra fabric hop latency, ns.
    pub hop_ns: f64,
    /// Idle-capacity policy on the shared fabric/bus (default strict).
    pub sharing: SharingMode,
    /// Time-varying link conditions on every fabric port (default
    /// steady).
    pub schedule: Option<ScheduleSpec>,
    /// Fault-injection plan on the shared fabric/engines (default none;
    /// requires strict sharing).
    pub faults: Option<FaultPlan>,
    /// Degraded-mode policy while a home module is down (default stall).
    pub recovery: RecoveryPolicy,
    /// Closed-loop controller (default none = static policies; inert
    /// specs are equivalent to none, byte for byte).
    pub controller: Option<ControllerSpec>,
    /// Request-serving front-end (`system::frontend`): `Some` drives
    /// the tenants burst-by-burst from an open-loop request stream
    /// instead of one merged trace run; `None` keeps the historical
    /// trace-driven path, byte for byte.  The tenant workload names
    /// then pick the servers' labels only — the request classes map to
    /// their own base workloads.
    pub service: Option<ServiceSpec>,
}

/// One simulation cell in the flat job list.
#[derive(Clone, Debug)]
pub struct CellSpec {
    /// One entry = single-trace cell; several = per-core mix (Fig. 18).
    pub workloads: Vec<String>,
    /// Scheme of the cell.  For cluster cells this is only tenant 0's
    /// representative — the authoritative per-tenant schemes live in
    /// `cluster.tenants`.
    pub kind: SchemeKind,
    pub cfg: SimConfig,
    /// Square-wave network disturbance `(load, period_cycles)`
    /// (Figs. 13/14); step and horizon match the legacy harness.
    pub disturbance: Option<(f64, f64)>,
    /// Multi-tenant cluster cell (overrides the single/mix execution
    /// path; `cfg.net[0]` supplies the per-port link parameters).
    pub cluster: Option<ClusterCell>,
}

impl CellSpec {
    /// A single-trace machine cell.
    pub fn new(workload: &str, kind: SchemeKind, cfg: SimConfig) -> CellSpec {
        CellSpec {
            workloads: vec![workload.to_string()],
            kind,
            cfg,
            disturbance: None,
            cluster: None,
        }
    }

    /// A per-core heterogeneous mix cell (one workload per core).
    pub fn mix(workloads: &[&str], kind: SchemeKind, cfg: SimConfig) -> CellSpec {
        CellSpec {
            workloads: workloads.iter().map(|w| w.to_string()).collect(),
            kind,
            cfg,
            disturbance: None,
            cluster: None,
        }
    }

    /// A machine cell under square-wave network disturbance (Fig. 13/14).
    pub fn disturbed(
        workload: &str,
        kind: SchemeKind,
        cfg: SimConfig,
        load: f64,
        period_cycles: f64,
    ) -> CellSpec {
        CellSpec {
            workloads: vec![workload.to_string()],
            kind,
            cfg,
            disturbance: Some((load, period_cycles)),
            cluster: None,
        }
    }

    /// A cluster cell: `(workload, scheme)` per tenant, `modules` shared
    /// memory modules; `cfg` carries the per-tenant knobs and (via
    /// `cfg.net[0]`) the per-port link parameters.
    pub fn cluster(tenants: &[(&str, SchemeKind)], modules: usize, cfg: SimConfig) -> CellSpec {
        assert!(!tenants.is_empty(), "cluster cell needs at least one tenant");
        CellSpec {
            workloads: tenants.iter().map(|(w, _)| w.to_string()).collect(),
            kind: tenants[0].1,
            cfg,
            disturbance: None,
            cluster: Some(ClusterCell {
                tenants: tenants.iter().map(|(w, k)| (w.to_string(), *k)).collect(),
                modules,
                weights: Vec::new(),
                hop_ns: 0.0,
                sharing: SharingMode::Strict,
                schedule: None,
                faults: None,
                recovery: RecoveryPolicy::Stall,
                controller: None,
                service: None,
            }),
        }
    }

    /// Number of `Metrics` this cell contributes to the flat result list
    /// (one per tenant for cluster cells, one otherwise).
    pub fn metrics_len(&self) -> usize {
        self.cluster.as_ref().map(|c| c.tenants.len()).unwrap_or(1)
    }
}

/// Closure assembling a figure's tables from its cells' metrics (in cell
/// declaration order).
pub type Assemble = Box<dyn FnOnce(&[Metrics]) -> Vec<Table> + Send>;

/// One experiment's declared cells + assembly step.
pub struct Plan {
    pub id: String,
    pub cells: Vec<CellSpec>,
    pub assemble: Assemble,
}

/// Deterministic slice of the flat job list: this process owns slot `i`
/// iff `i % total == index`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub total: usize,
}

impl Shard {
    /// The whole grid (unsharded run).
    pub fn full() -> Shard {
        Shard { index: 0, total: 1 }
    }

    pub fn owns(&self, slot: usize) -> bool {
        slot % self.total.max(1) == self.index
    }
}

/// Simulate one cell.  This is the single execution path all figures
/// share; it reproduces the legacy `run_cell` / `run_mix` /
/// `run_disturbed` semantics exactly.  Returns one `Metrics` per slot
/// entry: a single element for machine cells, one per tenant for cluster
/// cells.
pub fn run_cell_spec(r: &Runner, cache: &TraceCache, spec: &CellSpec) -> Vec<Metrics> {
    run_cell_spec_obs(r, cache, spec, None).0
}

/// [`run_cell_spec`] with optional observability: when `obs` is set,
/// every machine the cell instantiates gets a recorder, returned in
/// tenant order (a single element for machine cells).  `None` runs the
/// exact historical path — recorders are never built.
pub fn run_cell_spec_obs(
    r: &Runner,
    cache: &TraceCache,
    spec: &CellSpec,
    obs: Option<&ObsSpec>,
) -> (Vec<Metrics>, Vec<Recorder>) {
    let cfg = &spec.cfg;
    if let Some(cl) = &spec.cluster {
        assert!(spec.disturbance.is_none(), "disturbed cluster cells unsupported");
        let ccfg = ClusterConfig {
            memory_modules: cl.modules,
            net: cfg.net[0],
            fabric_hop_ns: cl.hop_ns,
            weights: cl.weights.clone(),
            sharing: cl.sharing,
            schedule: cl.schedule,
            faults: cl.faults.clone(),
            recovery: cl.recovery,
            controller: cl.controller,
        };
        if let Some(service) = &cl.service {
            return crate::system::frontend::run_service_obs(
                &ccfg,
                cfg,
                &cl.tenants,
                service,
                |wl| cache.get(wl, r.scale, cfg.seed, r.max_accesses),
                obs,
            );
        }
        return cluster::run_cluster_obs(
            &ccfg,
            cfg,
            &cl.tenants,
            |wl| cache.get(wl, r.scale, cfg.seed, r.max_accesses),
            obs,
        );
    }
    if let [workload] = spec.workloads.as_slice() {
        let (trace, profile) = cache.get(workload, r.scale, cfg.seed, r.max_accesses);
        let mut m = Machine::new(
            cfg.clone(),
            spec.kind,
            trace.footprint_pages,
            vec![profile; cfg.cores.max(1)],
            None,
        );
        if let Some(o) = obs {
            m.set_obs(Recorder::new(*o));
        }
        if let Some((load, period)) = spec.disturbance {
            m.set_disturbance(|capacity| {
                Disturbance::square_wave(period, load, 1e12, 5_000.0, capacity)
            });
        }
        m.run(std::slice::from_ref(&*trace));
        let recs = m.take_obs().into_iter().collect();
        (vec![m.metrics.clone()], recs)
    } else {
        assert_eq!(spec.workloads.len(), cfg.cores, "one mix workload per core");
        assert!(spec.disturbance.is_none(), "disturbed mix cells unsupported");
        let pairs: Vec<(Arc<Trace>, Profile)> = spec
            .workloads
            .iter()
            .map(|w| cache.get(w, r.scale, cfg.seed, r.max_accesses))
            .collect();
        let footprint: usize = pairs.iter().map(|(t, _)| t.footprint_pages).sum();
        let profiles: Vec<Profile> = pairs.iter().map(|(_, p)| *p).collect();
        let traces: Vec<Arc<Trace>> = pairs.into_iter().map(|(t, _)| t).collect();
        let mut m = Machine::new(cfg.clone(), spec.kind, footprint, profiles, None);
        if let Some(o) = obs {
            m.set_obs(Recorder::new(*o));
        }
        m.run(&traces);
        let recs = m.take_obs().into_iter().collect();
        (vec![m.metrics.clone()], recs)
    }
}

/// Work-stealing scheduler: run this shard's share of `cells` over `jobs`
/// OS threads.  Returns one entry per global slot — `None` for slots
/// outside the shard.  A slot carries the cell's full metrics list (one
/// per tenant for cluster cells).
pub fn run_cells_flat(
    r: &Runner,
    cache: &TraceCache,
    cells: &[CellSpec],
    shard: Shard,
    jobs: usize,
) -> Vec<Option<Vec<Metrics>>> {
    run_cells_flat_obs(r, cache, cells, shard, jobs, None, None)
        .into_iter()
        .map(|slot| slot.map(|(m, _)| m))
        .collect()
}

/// [`run_cells_flat`] with optional observability and progress
/// reporting.  Each filled slot carries the cell's metrics plus its
/// recorders (empty unless `obs` is set) — still keyed by global slot,
/// so downstream ordering is independent of `jobs`.  `progress`, when
/// given, is invoked as cells complete with `(cells done, cells owned)`;
/// completion order is scheduling-dependent, so the callback must feed
/// ephemeral reporting only, never a deterministic artifact.
pub fn run_cells_flat_obs(
    r: &Runner,
    cache: &TraceCache,
    cells: &[CellSpec],
    shard: Shard,
    jobs: usize,
    obs: Option<&ObsSpec>,
    progress: Option<&(dyn Fn(usize, usize) + Sync)>,
) -> Vec<Option<(Vec<Metrics>, Vec<Recorder>)>> {
    let n = cells.len();
    let todo: Vec<usize> = (0..n).filter(|i| shard.owns(*i)).collect();
    let slots: Vec<OnceLock<(Vec<Metrics>, Vec<Recorder>)>> =
        (0..n).map(|_| OnceLock::new()).collect();
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs.max(1).min(todo.len().max(1)) {
            s.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= todo.len() {
                    break;
                }
                let i = todo[k];
                let m = run_cell_spec_obs(r, cache, &cells[i], obs);
                let _ = slots[i].set(m);
                if let Some(cb) = progress {
                    cb(done.fetch_add(1, Ordering::Relaxed) + 1, todo.len());
                }
            });
        }
    });
    slots.into_iter().map(OnceLock::into_inner).collect()
}

/// Run one plan end-to-end on the global trace cache (the per-figure entry
/// points and `run_experiment` route through here).
pub fn run_plan(r: &Runner, plan: Plan) -> Vec<Table> {
    let ms = run_plan_metrics(r, &plan.cells);
    (plan.assemble)(&ms)
}

/// Run a cell list unsharded and return the metrics flattened in slot
/// order (cluster cells contribute one entry per tenant, in tenant
/// order — the layout every plan's `assemble` indexes).
pub fn run_plan_metrics(r: &Runner, cells: &[CellSpec]) -> Vec<Metrics> {
    run_cells_flat(r, TraceCache::global(), cells, Shard::full(), r.threads)
        .into_iter()
        .flat_map(|m| m.expect("unsharded run must fill every slot"))
        .collect()
}

/// Resolve experiment ids into plans (same registry as `run_experiment`).
pub fn plans_for(ids: &[String], r: &Runner) -> Result<Vec<Plan>, String> {
    ids.iter()
        .map(|id| {
            plan_for(id, r)
                .ok_or_else(|| format!("unknown experiment '{id}' — see `daemon-sim list`"))
        })
        .collect()
}

/// A sharded run's raw output: enough to recombine and re-assemble the
/// full figure set without re-simulating.
#[derive(Clone, Debug)]
pub struct ShardData {
    pub ids: Vec<String>,
    pub scale: Scale,
    pub max_accesses: usize,
    pub shard: Shard,
    pub total_slots: usize,
    /// `(global slot, that cell's metrics list)` for every slot this
    /// shard owns (one entry per tenant for cluster cells).
    pub results: Vec<(usize, Vec<Metrics>)>,
}

/// v6: `Metrics` gained the request-serving ledger (`requests_*`,
/// `request_*`, `request_hist`) for the `tail_latency` experiment; v5
/// added `controller_actuations` for the closed-loop `adaptive`
/// experiment; v4 added the fault counters (`downtime_cycles`,
/// `aborted_transfers`, `deferred_requests`); v3 added
/// `reclaimed_bytes` + `net_util_series`; v2 carried per-slot metrics
/// arrays + `access_hist`.  Older files are rejected with a clear
/// regenerate message.
const SHARD_FORMAT: &str = "daemon-sim-shard-v6";

fn scale_name(s: Scale) -> &'static str {
    match s {
        Scale::Test => "test",
        Scale::Paper => "paper",
    }
}

fn scale_by_name(s: &str) -> Result<Scale, String> {
    match s {
        "test" => Ok(Scale::Test),
        "paper" => Ok(Scale::Paper),
        other => Err(format!("shard json: unknown scale '{other}'")),
    }
}

impl ShardData {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(SHARD_FORMAT)),
            ("ids", Json::Arr(self.ids.iter().map(|s| Json::str(s)).collect())),
            ("scale", Json::str(scale_name(self.scale))),
            ("max_accesses", Json::num(self.max_accesses as f64)),
            ("shard_index", Json::num(self.shard.index as f64)),
            ("shard_total", Json::num(self.shard.total as f64)),
            ("total_slots", Json::num(self.total_slots as f64)),
            (
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|(slot, ms)| {
                            Json::obj(vec![
                                ("slot", Json::num(*slot as f64)),
                                (
                                    "metrics",
                                    Json::Arr(ms.iter().map(Metrics::to_json).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardData, String> {
        let fmt = j.get_str("format").unwrap_or("");
        if fmt != SHARD_FORMAT {
            return Err(format!(
                "not a daemon-sim shard file (format '{fmt}', want '{SHARD_FORMAT}')"
            ));
        }
        let num = |k: &str| {
            j.get_f64(k)
                .ok_or_else(|| format!("shard json: missing '{k}'"))
        };
        let ids = j
            .get_arr("ids")
            .ok_or("shard json: missing 'ids'")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "shard json: non-string id".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        let scale = scale_by_name(j.get_str("scale").unwrap_or(""))?;
        let shard = Shard {
            index: num("shard_index")? as usize,
            total: num("shard_total")? as usize,
        };
        if shard.total == 0 || shard.index >= shard.total {
            return Err(format!("shard json: bad shard {}/{}", shard.index, shard.total));
        }
        let mut results = Vec::new();
        for entry in j.get_arr("results").ok_or("shard json: missing 'results'")? {
            let slot = entry
                .get_f64("slot")
                .ok_or("shard json: result missing 'slot'")? as usize;
            let metrics = entry
                .get_arr("metrics")
                .ok_or("shard json: result missing 'metrics' array")?
                .iter()
                .map(Metrics::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            results.push((slot, metrics));
        }
        Ok(ShardData {
            ids,
            scale,
            max_accesses: num("max_accesses")? as usize,
            shard,
            total_slots: num("total_slots")? as usize,
            results,
        })
    }
}

/// Outcome of a sweep: the full figure set, or this shard's raw metrics.
pub enum SweepResult {
    /// `(experiment id, its tables)`, in request order.
    Tables(Vec<(String, Vec<Table>)>),
    Shard(ShardData),
}

/// Batch every requested experiment's cells into one flat job list and run
/// (this shard of) it.
pub fn sweep(
    ids: &[String],
    r: &Runner,
    cache: &TraceCache,
    shard: Shard,
    jobs: usize,
) -> Result<SweepResult, String> {
    let plans = plans_for(ids, r)?;
    sweep_plans(plans, ids, r, cache, shard, jobs)
}

/// Observability output of an unsharded sweep: one entry per cell in
/// global slot order — a `"<experiment id>/<cell index>"` label plus the
/// cell's recorders in tenant order.  This is exactly the exporter input
/// shape (`obs::telemetry_jsonl` / `obs::chrome_trace`), and because
/// slot order is a pure function of the requested ids, serializing it
/// yields byte-identical files across `--jobs` counts.
pub struct SweepObs {
    pub cells: Vec<(String, Vec<Recorder>)>,
}

/// Unsharded sweep with observability and/or progress reporting: like
/// `sweep` with `Shard::full()`, additionally returning every cell's
/// label and recorders (the recorder lists are empty unless `obs` is
/// set).  Sharded runs don't carry observability — recorders would
/// straddle shard files; run unsharded to trace.
pub fn sweep_obs(
    ids: &[String],
    r: &Runner,
    cache: &TraceCache,
    jobs: usize,
    obs: Option<&ObsSpec>,
    progress: Option<&(dyn Fn(usize, usize) + Sync)>,
) -> Result<(Vec<(String, Vec<Table>)>, SweepObs), String> {
    let plans = plans_for(ids, r)?;
    let labels: Vec<String> = plans
        .iter()
        .flat_map(|p| (0..p.cells.len()).map(move |k| format!("{}/{k}", p.id)))
        .collect();
    let cells: Vec<CellSpec> =
        plans.iter().flat_map(|p| p.cells.iter().cloned()).collect();
    let slots = run_cells_flat_obs(r, cache, &cells, Shard::full(), jobs, obs, progress);
    let mut all: Vec<Metrics> = Vec::new();
    let mut obs_cells: Vec<(String, Vec<Recorder>)> = Vec::new();
    for (label, slot) in labels.into_iter().zip(slots) {
        let (ms, recs) = slot.expect("unsharded run must fill every slot");
        all.extend(ms);
        obs_cells.push((label, recs));
    }
    Ok((assemble_all(plans, &all), SweepObs { cells: obs_cells }))
}

/// [`sweep`] over pre-built plans (tests hand in reduced workload sets).
pub fn sweep_plans(
    plans: Vec<Plan>,
    ids: &[String],
    r: &Runner,
    cache: &TraceCache,
    shard: Shard,
    jobs: usize,
) -> Result<SweepResult, String> {
    if shard.total == 1 {
        let cells: Vec<CellSpec> =
            plans.iter().flat_map(|p| p.cells.iter().cloned()).collect();
        let all: Vec<Metrics> = run_cells_flat(r, cache, &cells, shard, jobs)
            .into_iter()
            .flat_map(|m| m.expect("unsharded run must fill every slot"))
            .collect();
        Ok(SweepResult::Tables(assemble_all(plans, &all)))
    } else {
        Ok(SweepResult::Shard(shard_plans(&plans, ids, r, cache, shard, jobs)))
    }
}

/// Run (this shard of) the plans' cells and package the raw per-slot
/// results.  Works for any `total >= 1` — an explicit `--shard 0/1` is a
/// complete run that still emits a mergeable shard file.
pub fn shard_plans(
    plans: &[Plan],
    ids: &[String],
    r: &Runner,
    cache: &TraceCache,
    shard: Shard,
    jobs: usize,
) -> ShardData {
    let cells: Vec<CellSpec> =
        plans.iter().flat_map(|p| p.cells.iter().cloned()).collect();
    let slots = run_cells_flat(r, cache, &cells, shard, jobs);
    let results = slots
        .into_iter()
        .enumerate()
        .filter_map(|(i, m)| m.map(|m| (i, m)))
        .collect();
    ShardData {
        ids: ids.to_vec(),
        scale: r.scale,
        max_accesses: r.max_accesses,
        shard,
        total_slots: cells.len(),
        results,
    }
}

/// [`shard_plans`] from experiment ids — what `--shard I/N` runs.
pub fn sweep_shard(
    ids: &[String],
    r: &Runner,
    cache: &TraceCache,
    shard: Shard,
    jobs: usize,
) -> Result<ShardData, String> {
    let plans = plans_for(ids, r)?;
    Ok(shard_plans(&plans, ids, r, cache, shard, jobs))
}

/// Hand each plan its slice of the flat (per-tenant-expanded) result
/// vector, in declaration order.
fn assemble_all(plans: Vec<Plan>, all: &[Metrics]) -> Vec<(String, Vec<Table>)> {
    let mut out = Vec::with_capacity(plans.len());
    let mut off = 0;
    for p in plans {
        let n: usize = p.cells.iter().map(CellSpec::metrics_len).sum();
        let tables = (p.assemble)(&all[off..off + n]);
        off += n;
        out.push((p.id, tables));
    }
    debug_assert_eq!(off, all.len());
    out
}

/// Recombine shard files: headers must agree, every slot must be covered
/// exactly once, and assembly re-runs the same deterministic plans the
/// sharded runs used — so the output is byte-identical to an unsharded
/// sweep of the same ids.
pub fn merge_shards(shards: &[ShardData]) -> Result<Vec<(String, Vec<Table>)>, String> {
    let first = shards.first().ok_or("merge: no shard files given")?;
    let r = Runner {
        scale: first.scale,
        max_accesses: first.max_accesses,
        threads: 1,
    };
    let plans = plans_for(&first.ids, &r)?;
    merge_with_plans(plans, shards)
}

/// [`merge_shards`] over pre-built plans (tests hand in reduced sets).
pub fn merge_with_plans(
    plans: Vec<Plan>,
    shards: &[ShardData],
) -> Result<Vec<(String, Vec<Table>)>, String> {
    let first = shards.first().ok_or("merge: no shard files given")?;
    for s in &shards[1..] {
        if s.ids != first.ids
            || s.scale != first.scale
            || s.max_accesses != first.max_accesses
            || s.total_slots != first.total_slots
            || s.shard.total != first.shard.total
        {
            return Err(format!(
                "merge: shard {}/{} disagrees with shard {}/{} on the sweep header",
                s.shard.index, s.shard.total, first.shard.index, first.shard.total
            ));
        }
    }
    let planned: usize = plans.iter().map(|p| p.cells.len()).sum();
    if planned != first.total_slots {
        return Err(format!(
            "merge: shard files carry {} slots but the current experiment \
             definitions produce {planned} — regenerate the shards",
            first.total_slots
        ));
    }
    // Per-slot metrics count (1, or the tenant count for cluster cells):
    // a mismatch means the cluster definitions changed since the shards
    // were written and flat assembly would silently misalign.
    let expected: Vec<usize> = plans
        .iter()
        .flat_map(|p| p.cells.iter().map(CellSpec::metrics_len))
        .collect();
    let mut all: Vec<Option<Vec<Metrics>>> = vec![None; first.total_slots];
    for s in shards {
        for (slot, m) in &s.results {
            let cell = all
                .get_mut(*slot)
                .ok_or_else(|| format!("merge: slot {slot} out of range"))?;
            if cell.is_some() {
                return Err(format!("merge: slot {slot} provided by two shards"));
            }
            if m.len() != expected[*slot] {
                return Err(format!(
                    "merge: slot {slot} carries {} metrics but the current \
                     experiment definitions expect {} — regenerate the shards",
                    m.len(),
                    expected[*slot]
                ));
            }
            *cell = Some(m.clone());
        }
    }
    let missing = all.iter().filter(|m| m.is_none()).count();
    if missing > 0 {
        return Err(format!(
            "merge: {missing} of {} slots missing — pass every shard 0..{}",
            all.len(),
            first.shard.total
        ));
    }
    let all: Vec<Metrics> = all.into_iter().flat_map(Option::unwrap).collect();
    Ok(assemble_all(plans, &all))
}

/// Machine-readable figure set — the artifact the sharded-vs-unsharded
/// byte-identity check compares (`figures.json`).
pub fn figures_json(sets: &[(String, Vec<Table>)]) -> Json {
    Json::obj(vec![(
        "figures",
        Json::Arr(
            sets.iter()
                .map(|(id, tables)| {
                    Json::obj(vec![
                        ("id", Json::str(id)),
                        (
                            "tables",
                            Json::Arr(tables.iter().map(Table::to_json).collect()),
                        ),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::main_results;

    fn mini_plans(r: &Runner) -> Vec<Plan> {
        vec![
            main_results::fig9_plan(r, &["pr"]),
            main_results::fig10_plan(r, &["pr"]),
        ]
    }

    fn mini_ids() -> Vec<String> {
        vec!["fig9".to_string(), "fig10".to_string()]
    }

    #[test]
    fn flat_sweep_generates_each_trace_once() {
        let r = Runner::test();
        let cache = TraceCache::new();
        let plans = mini_plans(&r);
        let n_cells: usize = plans.iter().map(|p| p.cells.len()).sum();
        let res = sweep_plans(plans, &mini_ids(), &r, &cache, Shard::full(), 4).unwrap();
        let SweepResult::Tables(sets) = res else { panic!("expected tables") };
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0].0, "fig9");
        let stats = cache.stats();
        assert_eq!(stats.misses, 1, "one distinct (workload, scale, seed, cap) key");
        assert_eq!(stats.hits as usize, n_cells - 1, "every other cell reuses it");
    }

    #[test]
    fn sharded_merge_is_byte_identical_to_unsharded() {
        let r = Runner::test();
        let full = {
            let cache = TraceCache::new();
            match sweep_plans(mini_plans(&r), &mini_ids(), &r, &cache, Shard::full(), 2)
                .unwrap()
            {
                SweepResult::Tables(sets) => sets,
                SweepResult::Shard(_) => panic!("unsharded run produced a shard"),
            }
        };
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let cache = TraceCache::new();
                let shard = Shard { index, total: 2 };
                match sweep_plans(mini_plans(&r), &mini_ids(), &r, &cache, shard, 2)
                    .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!("sharded run produced tables"),
                }
            })
            .collect();
        // Round-trip each shard through the JSON wire format the CLI uses.
        let shards: Vec<ShardData> = shards
            .iter()
            .map(|d| {
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        let merged = merge_with_plans(mini_plans(&r), &shards).unwrap();
        assert_eq!(
            figures_json(&full).to_string(),
            figures_json(&merged).to_string(),
            "sharded + merged figure JSON must be byte-identical"
        );
    }

    #[test]
    fn scheduler_is_thread_count_invariant() {
        let r = Runner::test();
        let plan = main_results::fig10_plan(&r, &["bf"]);
        let one = run_cells_flat(&r, &TraceCache::new(), &plan.cells, Shard::full(), 1);
        let many = run_cells_flat(&r, &TraceCache::new(), &plan.cells, Shard::full(), 8);
        assert_eq!(one.len(), many.len());
        let fmt = |slot: &Option<Vec<Metrics>>| -> Vec<String> {
            slot.as_ref()
                .unwrap()
                .iter()
                .map(|m| m.to_json().to_string())
                .collect()
        };
        for (a, b) in one.iter().zip(many.iter()) {
            assert_eq!(fmt(a), fmt(b));
        }
    }

    /// A minimal plan holding one 2-tenant cluster cell + one machine
    /// cell, assembling per-tenant IPCs — exercises the multi-metrics
    /// slot path end to end.
    fn cluster_mini_plan(_r: &Runner) -> Plan {
        let cfg = SimConfig::test_scale();
        let cells = vec![
            CellSpec::cluster(
                &[("pr", SchemeKind::Daemon), ("sp", SchemeKind::Remote)],
                2,
                cfg.clone(),
            ),
            CellSpec::new("pr", SchemeKind::Remote, cfg),
        ];
        let assemble = Box::new(move |ms: &[Metrics]| {
            assert_eq!(ms.len(), 3, "2 tenants + 1 machine cell");
            let mut t = Table::new("cluster mini", &["cell", "ipc"]);
            for (i, m) in ms.iter().enumerate() {
                t.row_f(&format!("{i}"), &[m.ipc()]);
            }
            vec![t]
        });
        Plan { id: "cluster_mini".into(), cells, assemble }
    }

    #[test]
    fn cluster_cells_flatten_and_shard_like_any_figure() {
        let r = Runner::test();
        let ids = vec!["cluster_mini".to_string()];
        let full = match sweep_plans(
            vec![cluster_mini_plan(&r)],
            &ids,
            &r,
            &TraceCache::new(),
            Shard::full(),
            2,
        )
        .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!(),
        };
        assert_eq!(full[0].1[0].rows.len(), 3, "cluster cell expands per tenant");
        // Shard 2 ways (slot 0 = cluster cell, slot 1 = machine cell),
        // round-trip the wire format, merge: byte-identical tables.
        let shards: Vec<ShardData> = (0..2)
            .map(|index| {
                let d = match sweep_plans(
                    vec![cluster_mini_plan(&r)],
                    &ids,
                    &r,
                    &TraceCache::new(),
                    Shard { index, total: 2 },
                    2,
                )
                .unwrap()
                {
                    SweepResult::Shard(d) => d,
                    SweepResult::Tables(_) => panic!(),
                };
                ShardData::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
                    .unwrap()
            })
            .collect();
        assert_eq!(shards[0].results[0].1.len(), 2, "cluster slot carries 2 metrics");
        let merged = merge_with_plans(vec![cluster_mini_plan(&r)], &shards).unwrap();
        assert_eq!(
            figures_json(&full).to_string(),
            figures_json(&merged).to_string()
        );
    }

    #[test]
    fn shards_partition_the_slots() {
        assert!(Shard::full().owns(0) && Shard::full().owns(17));
        let s0 = Shard { index: 0, total: 2 };
        let s1 = Shard { index: 1, total: 2 };
        for slot in 0..10 {
            assert_ne!(s0.owns(slot), s1.owns(slot));
        }
    }

    #[test]
    fn merge_rejects_incomplete_duplicate_and_mismatched_shards() {
        let r = Runner::test();
        let mk = |index| {
            let cache = TraceCache::new();
            match sweep_plans(
                vec![main_results::fig10_plan(&r, &["pr"])],
                &["fig10".to_string()],
                &r,
                &cache,
                Shard { index, total: 2 },
                2,
            )
            .unwrap()
            {
                SweepResult::Shard(d) => d,
                SweepResult::Tables(_) => panic!(),
            }
        };
        let d0 = mk(0);
        let plans = || vec![main_results::fig10_plan(&r, &["pr"])];
        let err = merge_with_plans(plans(), &[d0.clone()]).unwrap_err();
        assert!(err.contains("missing"), "{err}");
        let err = merge_with_plans(plans(), &[d0.clone(), d0.clone()]).unwrap_err();
        assert!(err.contains("two shards"), "{err}");
        let mut wrong = d0.clone();
        wrong.total_slots += 1;
        let err = merge_with_plans(plans(), &[d0.clone(), wrong]).unwrap_err();
        assert!(err.contains("header"), "{err}");
        // A slot whose metrics count disagrees with the current cell
        // definitions (e.g. a cluster cell's tenant count changed).
        let mut inflated = d0.clone();
        let extra = inflated.results[0].1[0].clone();
        inflated.results[0].1.push(extra);
        let err = merge_with_plans(plans(), &[inflated, mk(1)]).unwrap_err();
        assert!(err.contains("regenerate"), "{err}");
        assert!(merge_with_plans(plans(), &[d0, mk(1)]).is_ok());
    }

    #[test]
    fn cluster_cell_weights_and_hop_are_plumbed_through() {
        let r = Runner::test();
        let cfg = SimConfig::test_scale();
        // Same workload twice; tenant 0 gets 3x the bandwidth weight.
        let mut weighted = CellSpec::cluster(
            &[("pr", SchemeKind::Remote), ("pr", SchemeKind::Remote)],
            1,
            cfg.clone(),
        );
        weighted.cluster.as_mut().unwrap().weights = vec![3.0, 1.0];
        let ms = run_cell_spec(&r, &TraceCache::new(), &weighted);
        assert_eq!(ms.len(), 2);
        assert!(
            ms[0].ipc() > ms[1].ipc(),
            "heavier-weighted tenant must run faster: {} vs {}",
            ms[0].ipc(),
            ms[1].ipc()
        );
        // An extra fabric hop slows every remote access down.
        let base = CellSpec::cluster(&[("pr", SchemeKind::Remote)], 1, cfg.clone());
        let mut hopped = base.clone();
        hopped.cluster.as_mut().unwrap().hop_ns = 400.0;
        let cache = TraceCache::new();
        let b = run_cell_spec(&r, &cache, &base);
        let h = run_cell_spec(&r, &cache, &hopped);
        assert!(
            h[0].cycles > b[0].cycles,
            "fabric hop must cost cycles: {} vs {}",
            h[0].cycles,
            b[0].cycles
        );
    }

    #[test]
    fn explicit_single_shard_still_merges_to_full_tables() {
        // `--shard 0/1` must behave like any other shard matrix entry.
        let r = Runner::test();
        let plans = || vec![main_results::fig10_plan(&r, &["pr"])];
        let ids = vec!["fig10".to_string()];
        let full = match sweep_plans(plans(), &ids, &r, &TraceCache::new(), Shard::full(), 2)
            .unwrap()
        {
            SweepResult::Tables(sets) => sets,
            SweepResult::Shard(_) => panic!(),
        };
        let d = shard_plans(&plans(), &ids, &r, &TraceCache::new(), Shard::full(), 2);
        assert_eq!(d.results.len(), d.total_slots, "0/1 shard covers every slot");
        let merged = merge_with_plans(plans(), &[d]).unwrap();
        assert_eq!(figures_json(&full).to_string(), figures_json(&merged).to_string());
    }

    #[test]
    fn table1_plan_has_no_cells_and_still_assembles() {
        let r = Runner::test();
        let plan = plan_for("table1", &r).unwrap();
        assert!(plan.cells.is_empty());
        let tables = run_plan(&r, plan);
        assert!(tables[0].render().contains("TOTAL compute engine"));
    }
}
