//! Fig. 3 — data-movement overheads in fully disaggregated systems.
//!
//! Six configurations (Local / cache-line / Remote / page-free /
//! cache-line+page / DaeMon) across all workloads, at 100ns and 400ns
//! switch latency with a 1/4 bandwidth factor; reported as slowdown
//! relative to Local (the paper plots speedup normalized to Local).

use super::common::Runner;
use super::orchestrator::{self, CellSpec, Plan};
use crate::config::SimConfig;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::ALL;

pub fn plan(_r: &Runner, workloads: &[&str]) -> Plan {
    let schemes = SchemeKind::motivation_set();
    let workloads: Vec<String> = workloads.iter().map(|s| s.to_string()).collect();
    let mut cells = Vec::new();
    for &sw in &[100.0, 400.0] {
        let cfg = SimConfig::default().with_net(sw, 4.0);
        for wl in &workloads {
            for &k in &schemes {
                cells.push(CellSpec::new(wl, k, cfg.clone()));
            }
        }
    }
    let assemble = Box::new(move |ms: &[Metrics]| {
        let per_net = workloads.len() * schemes.len();
        let mut tables = Vec::new();
        for (g, &sw) in [100.0f64, 400.0].iter().enumerate() {
            let block = &ms[g * per_net..(g + 1) * per_net];
            let mut table = Table::new(
                &format!("Fig 3: IPC normalized to Local ({}ns switch, 1/4 bw)", sw as u32),
                &{
                    let mut h = vec!["workload"];
                    h.extend(schemes.iter().map(|s| s.name()));
                    h
                },
            );
            let mut per_scheme: Vec<Vec<f64>> = vec![Vec::new(); schemes.len()];
            for (w, wl) in workloads.iter().enumerate() {
                let row = &block[w * schemes.len()..(w + 1) * schemes.len()];
                let local_ipc = row[0].ipc(); // Local is first in the set
                let vals: Vec<f64> =
                    row.iter().map(|m| m.ipc() / local_ipc.max(1e-12)).collect();
                for (i, v) in vals.iter().enumerate() {
                    per_scheme[i].push(*v);
                }
                table.row_f(wl, &vals);
            }
            let gm: Vec<f64> = per_scheme.iter().map(|v| geomean(v)).collect();
            table.row_f("geomean", &gm);
            tables.push(table);
        }
        tables
    });
    Plan { id: "fig3".into(), cells, assemble }
}

pub fn run(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    orchestrator::run_plan(r, plan(r, workloads))
}

/// Full paper workload set.
pub fn run_default(r: &Runner) -> Vec<Table> {
    run(r, &ALL)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motivation_shape_holds() {
        let r = Runner::test();
        let tables = run(&r, &["pr", "sp"]);
        assert_eq!(tables.len(), 2);
        let t = &tables[0];
        assert_eq!(t.rows.len(), 3); // 2 workloads + geomean
        // Local column is exactly 1.0 and Remote is a real slowdown.
        let gm = t.rows.last().unwrap();
        let local: f64 = gm[1].parse().unwrap();
        let remote: f64 = gm[3].parse().unwrap();
        assert!((local - 1.0).abs() < 1e-6);
        assert!(remote < 0.8, "Remote should be well below Local, got {remote}");
    }
}
