//! Fig. 3 — data-movement overheads in fully disaggregated systems.
//!
//! Six configurations (Local / cache-line / Remote / page-free /
//! cache-line+page / DaeMon) across all workloads, at 100ns and 400ns
//! switch latency with a 1/4 bandwidth factor; reported as slowdown
//! relative to Local (the paper plots speedup normalized to Local).

use super::common::Runner;
use crate::config::SimConfig;
use crate::schemes::SchemeKind;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workloads::ALL;

pub fn run(r: &Runner, workloads: &[&str]) -> Vec<Table> {
    let mut tables = Vec::new();
    for &sw in &[100.0, 400.0] {
        let cfg = SimConfig::default().with_net(sw, 4.0);
        let schemes = SchemeKind::motivation_set();
        let mut table = Table::new(
            &format!("Fig 3: IPC normalized to Local ({}ns switch, 1/4 bw)", sw as u32),
            &{
                let mut h = vec!["workload"];
                h.extend(schemes.iter().map(|s| s.name()));
                h
            },
        );
        let mut per_scheme: Vec<Vec<f64>> = vec![Vec::new(); schemes.len()];
        for wl in workloads {
            let (trace, profile) = r.gen_trace(wl, cfg.seed);
            let cells: Vec<_> = schemes.iter().map(|&k| (k, cfg.clone())).collect();
            let ms = r.run_cells(&trace, profile, &cells);
            let local_ipc = ms[0].ipc(); // Local is first in the set
            let vals: Vec<f64> = ms.iter().map(|m| m.ipc() / local_ipc.max(1e-12)).collect();
            for (i, v) in vals.iter().enumerate() {
                per_scheme[i].push(*v);
            }
            table.row_f(wl, &vals);
        }
        let gm: Vec<f64> = per_scheme.iter().map(|v| geomean(v)).collect();
        table.row_f("geomean", &gm);
        tables.push(table);
    }
    tables
}

/// Full paper workload set.
pub fn run_default(r: &Runner) -> Vec<Table> {
    run(r, &ALL)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motivation_shape_holds() {
        let r = Runner::test();
        let tables = run(&r, &["pr", "sp"]);
        assert_eq!(tables.len(), 2);
        let t = &tables[0];
        assert_eq!(t.rows.len(), 3); // 2 workloads + geomean
        // Local column is exactly 1.0 and Remote is a real slowdown.
        let gm = t.rows.last().unwrap();
        let local: f64 = gm[1].parse().unwrap();
        let remote: f64 = gm[3].parse().unwrap();
        assert!((local - 1.0).abs() < 1e-6);
        assert!(remote < 0.8, "Remote should be well below Local, got {remote}");
    }
}
