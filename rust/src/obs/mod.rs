//! Deterministic observability: epoch telemetry and sim-time event
//! tracing (DESIGN.md §"Observability").
//!
//! The simulator's only online mechanism — §4.5 adaptive granularity
//! selection — reacts to observed queue occupancies and link conditions,
//! and the roadmap's closed-loop policy layer must be a pure function of
//! observed state.  This module is that observation substrate: a
//! per-machine [`Recorder`] that samples telemetry [`Snapshot`]s at a
//! configurable sim-cycle epoch and logs structured [`Event`]s into a
//! bounded ring, both stamped with **sim cycles only** (lint rule R2).
//!
//! Determinism contract:
//!
//! * **Off by default, byte-identity-pinned when off.**  A machine
//!   without a recorder runs the exact historical code path (one
//!   `Option` check per hook site).
//! * **Observation-only when on.**  Every accessor a recorder samples
//!   takes `&self` on the sampled component, so attaching a recorder
//!   cannot perturb simulation state: metrics stay byte-identical with
//!   and without one (pinned by `rust/tests/determinism.rs`).
//! * **Jobs-invariant output.**  Recorders are machine-local; exporters
//!   serialize them in cell/tenant order, so the files are byte-identical
//!   across `--jobs 1` vs N and across repeat runs.  Process-global
//!   counters (size memo, trace cache) are scheduling-dependent and are
//!   deliberately excluded — they surface via the CLI `--stats` summary,
//!   never in these artifacts.

pub mod telemetry;
pub mod trace;

pub use telemetry::{telemetry_jsonl, ModuleSample, Snapshot, Telemetry};
pub use trace::{chrome_trace, Event, EventKind, TraceRing};

use crate::system::fault::PortState;

/// Configuration for one machine's recorder.
#[derive(Clone, Copy, Debug)]
pub struct ObsSpec {
    /// Record epoch telemetry snapshots.
    pub telemetry: bool,
    /// Record structured trace events.
    pub trace: bool,
    /// Sampling epoch in sim cycles — the cadence of telemetry snapshots
    /// and port-state edge detection.
    pub epoch_cycles: f64,
    /// Trace ring capacity in events; once full, the oldest event is
    /// dropped (and counted) per push.
    pub trace_capacity: usize,
}

impl ObsSpec {
    pub const DEFAULT_EPOCH_CYCLES: f64 = 100_000.0;
    pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

    /// Both channels on, default epoch and ring capacity.
    pub fn enabled() -> ObsSpec {
        ObsSpec {
            telemetry: true,
            trace: true,
            epoch_cycles: Self::DEFAULT_EPOCH_CYCLES,
            trace_capacity: Self::DEFAULT_TRACE_CAPACITY,
        }
    }

    /// Override the sampling epoch (sim cycles, must be positive).
    pub fn with_epoch(mut self, cycles: f64) -> ObsSpec {
        assert!(cycles > 0.0, "telemetry epoch must be a positive cycle count");
        self.epoch_cycles = cycles;
        self
    }

    /// Override the trace ring capacity.
    pub fn with_trace_capacity(mut self, cap: usize) -> ObsSpec {
        self.trace_capacity = cap;
        self
    }
}

/// Static label for a port-state transition, for event args.
fn edge_detail(from: PortState, to: PortState) -> &'static str {
    match (from, to) {
        (PortState::Up, PortState::Down) => "up->down",
        (PortState::Up, PortState::Recovering) => "up->recovering",
        (PortState::Down, PortState::Up) => "down->up",
        (PortState::Down, PortState::Recovering) => "down->recovering",
        (PortState::Recovering, PortState::Up) => "recovering->up",
        (PortState::Recovering, PortState::Down) => "recovering->down",
        _ => "unchanged",
    }
}

/// Per-machine telemetry + trace recorder.
///
/// One recorder observes one machine (one tenant in a cluster).  The
/// machine drives it from its stepping hot path: unconditional event
/// hooks (page/line scheduling, throttles, re-requests) plus an
/// epoch-gated sampling pass for snapshots and port edges.
pub struct Recorder {
    spec: ObsSpec,
    pub telemetry: Telemetry,
    pub trace: TraceRing,
    /// Next unsampled epoch boundary (sim cycles).
    next_epoch: f64,
    /// Last sampled port state per module, for edge detection.  Ports
    /// start `Up`; the vec grows lazily to the module count.
    port_seen: Vec<PortState>,
}

impl Recorder {
    pub fn new(spec: ObsSpec) -> Recorder {
        assert!(spec.epoch_cycles > 0.0, "telemetry epoch must be a positive cycle count");
        Recorder {
            telemetry: Telemetry::new(),
            trace: TraceRing::new(spec.trace_capacity),
            next_epoch: spec.epoch_cycles,
            port_seen: Vec::new(),
            spec,
        }
    }

    pub fn wants_telemetry(&self) -> bool {
        self.spec.telemetry
    }

    pub fn wants_trace(&self) -> bool {
        self.spec.trace
    }

    /// Latest unsampled epoch boundary at or before `now`, advancing the
    /// cadence past `now`; `None` while the boundary is still ahead.
    /// Boundaries with no machine activity in between collapse into one
    /// sample stamped at the most recent crossed boundary (machine time
    /// is event-driven, so an idle epoch has nothing new to report).
    pub fn epoch_crossed(&mut self, now: f64) -> Option<f64> {
        if now < self.next_epoch {
            return None;
        }
        let e = self.spec.epoch_cycles;
        let at = self.next_epoch + ((now - self.next_epoch) / e).floor() * e;
        self.next_epoch = at + e;
        Some(at)
    }

    /// Log a structured event (no-op unless tracing is enabled).
    pub fn event(&mut self, ev: Event) {
        if self.spec.trace {
            self.trace.push(ev);
        }
    }

    /// Record the sampled state of module `m`'s port, emitting a
    /// `PortEdge` event when it changed since the previous sample.
    pub fn port_edge(&mut self, m: usize, state: PortState, at: f64, tenant: usize) {
        while self.port_seen.len() <= m {
            self.port_seen.push(PortState::Up);
        }
        let prev = self.port_seen[m];
        if prev != state {
            self.port_seen[m] = state;
            let mut ev = Event::instant(EventKind::PortEdge, tenant, Some(m), 0, at);
            ev.detail = Some(edge_detail(prev, state));
            self.event(ev);
        }
    }

    /// Append a telemetry snapshot (no-op unless telemetry is enabled).
    pub fn push_snapshot(&mut self, snap: Snapshot) {
        if self.spec.telemetry {
            self.telemetry.snapshots.push(snap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_crossing_collapses_idle_boundaries() {
        let mut r = Recorder::new(ObsSpec::enabled().with_epoch(100.0));
        assert_eq!(r.epoch_crossed(50.0), None);
        assert_eq!(r.epoch_crossed(100.0), Some(100.0));
        assert_eq!(r.epoch_crossed(150.0), None);
        // A long idle gap yields one sample at the latest boundary.
        assert_eq!(r.epoch_crossed(1234.0), Some(1200.0));
        assert_eq!(r.epoch_crossed(1299.0), None);
        assert_eq!(r.epoch_crossed(1300.0), Some(1300.0));
    }

    #[test]
    fn port_edges_fire_only_on_transitions() {
        let mut r = Recorder::new(ObsSpec::enabled());
        r.port_edge(0, PortState::Up, 10.0, 0);
        assert_eq!(r.trace.len(), 0, "ports start Up; no edge");
        r.port_edge(0, PortState::Down, 20.0, 0);
        r.port_edge(0, PortState::Down, 30.0, 0);
        r.port_edge(0, PortState::Recovering, 40.0, 0);
        r.port_edge(0, PortState::Up, 50.0, 0);
        let kinds: Vec<&str> = r.trace.events().map(|e| e.detail.unwrap()).collect();
        assert_eq!(kinds, ["up->down", "down->recovering", "recovering->up"]);
    }

    #[test]
    fn disabled_channels_record_nothing() {
        let mut spec = ObsSpec::enabled();
        spec.telemetry = false;
        spec.trace = false;
        let mut r = Recorder::new(spec);
        r.event(Event::instant(EventKind::Throttle, 0, None, 7, 5.0));
        r.push_snapshot(Snapshot::empty(0, 100.0));
        assert_eq!(r.trace.len(), 0);
        assert!(r.telemetry.snapshots.is_empty());
    }
}
