//! Structured sim-time event log: a bounded ring buffer of machine
//! events plus a Chrome-trace-event JSON exporter, so runs open directly
//! in Perfetto / `chrome://tracing`.
//!
//! Timestamps are **sim cycles** (one trace `ts` unit per cycle), never
//! the wall clock — lint rule R2 applies to this module like any other
//! simulation code.  The ring is bounded: once at capacity each push
//! drops the oldest event and counts the drop, so memory stays flat and
//! the drop count itself is deterministic.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::util::json::Json;

/// Event taxonomy (DESIGN.md §"Observability" keeps the table of record).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A page migration was scheduled: a complete span from issue to
    /// arrival in local memory (covers demand, prefetch, and replayed
    /// requests alike).
    PageMove,
    /// A migrated page arrived and was installed in local memory.
    PageInstall,
    /// A cache-line fetch was scheduled: a span from issue to LLC fill.
    LineFetch,
    /// The selection unit throttled a page request (buffer pressure).
    Throttle,
    /// The selection unit suppressed a line request (buffer pressure).
    Suppress,
    /// A throttled page was re-requested after its deferred arrival.
    Rerequest,
    /// A fabric port changed state (fault down / recovery edges).
    PortEdge,
    /// A cluster tenant was killed at its configured kill cycle.
    TenantKill,
    /// The closed-loop controller applied a bounded actuation to this
    /// tenant at an observation-epoch boundary (`detail` carries the
    /// control-law id).
    Actuate,
    /// The request front-end re-issued a timed-out request attempt
    /// after its backoff delay (`page` carries the request id).
    Retry,
    /// The request front-end issued a hedged second attempt for a
    /// still-outstanding request (`page` carries the request id).
    Hedge,
    /// Admission control shed an arriving request at the backlog
    /// watermark (`page` carries the request id).
    Shed,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::PageMove => "PageMove",
            EventKind::PageInstall => "PageInstall",
            EventKind::LineFetch => "LineFetch",
            EventKind::Throttle => "Throttle",
            EventKind::Suppress => "Suppress",
            EventKind::Rerequest => "Rerequest",
            EventKind::PortEdge => "PortEdge",
            EventKind::TenantKill => "TenantKill",
            EventKind::Actuate => "Actuate",
            EventKind::Retry => "Retry",
            EventKind::Hedge => "Hedge",
            EventKind::Shed => "Shed",
        }
    }

    /// Thread lane a kind renders on: one lane per (tenant, resource).
    fn lane(self) -> (u64, &'static str) {
        match self {
            EventKind::PageMove
            | EventKind::PageInstall
            | EventKind::Throttle
            | EventKind::Rerequest => (0, "pages"),
            EventKind::LineFetch | EventKind::Suppress => (1, "lines"),
            EventKind::PortEdge => (2, "port"),
            EventKind::TenantKill
            | EventKind::Actuate
            | EventKind::Retry
            | EventKind::Hedge
            | EventKind::Shed => (3, "lifecycle"),
        }
    }
}

/// One recorded event.  Spans carry a positive `dur`; instants carry 0.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Sim cycle of issue (spans) or occurrence (instants).
    pub at: f64,
    /// Span length in sim cycles; 0.0 for instant events.
    pub dur: f64,
    /// Tenant index on the shared fabric (0 for a solo machine).
    pub tenant: usize,
    /// Memory module involved, when the event is module-specific.
    pub module: Option<usize>,
    /// Page number the event concerns (0 when not applicable).
    pub page: u64,
    /// Bytes on the wire for transfer spans (0 otherwise).
    pub bytes: u64,
    /// Static annotation, e.g. a port-edge transition label.
    pub detail: Option<&'static str>,
}

impl Event {
    /// A complete span from `at` lasting `dur` cycles.
    pub fn span(
        kind: EventKind,
        tenant: usize,
        module: Option<usize>,
        page: u64,
        bytes: u64,
        at: f64,
        dur: f64,
    ) -> Event {
        Event { kind, at, dur, tenant, module, page, bytes, detail: None }
    }

    /// An instant event at `at`.
    pub fn instant(
        kind: EventKind,
        tenant: usize,
        module: Option<usize>,
        page: u64,
        at: f64,
    ) -> Event {
        Event { kind, at, dur: 0.0, tenant, module, page, bytes: 0, detail: None }
    }
}

/// Bounded ring buffer of events.  Pushing onto a full ring evicts the
/// oldest event and increments the drop counter — the tail of the run is
/// always retained, and the number of drops is itself deterministic.
pub struct TraceRing {
    cap: usize,
    events: VecDeque<Event>,
    dropped: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { cap, events: VecDeque::with_capacity(cap.min(1024)), dropped: 0 }
    }

    pub fn push(&mut self, ev: Event) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &Event> + '_ {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted (or refused, for a zero-capacity ring) so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Export cells' rings as one Chrome trace-event JSON document
/// (<https://ui.perfetto.dev> opens it directly).
///
/// Layout: one trace *process* per (cell, module) — plus a per-cell
/// "engine" process for events with no module — and one *thread* lane
/// per (tenant, resource) within it, where the resource is the event
/// kind's lane (pages / lines / port / lifecycle).  `ts`/`dur` are sim
/// cycles.  Everything is emitted in (cell, recorder, ring) order with
/// pids assigned by first sorted appearance, so the document is a pure
/// function of the cell list: byte-identical across `--jobs` counts.
///
/// Ring-overflow drop counts are reported under `otherData.cells`.
pub fn chrome_trace(cells: &[(String, Vec<&super::Recorder>)]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut summary: Vec<Json> = Vec::new();
    let mut next_pid = 1u64;
    for (label, recs) in cells {
        // Stable pid per module within this cell (None = engine lane).
        let mut modules: BTreeSet<Option<usize>> = BTreeSet::new();
        let mut lanes: BTreeSet<(Option<usize>, usize, u64, &str)> = BTreeSet::new();
        for rec in recs {
            for ev in rec.trace.events() {
                let (lane, lane_name) = ev.kind.lane();
                modules.insert(ev.module);
                lanes.insert((ev.module, ev.tenant, lane, lane_name));
            }
        }
        let mut pids: BTreeMap<Option<usize>, u64> = BTreeMap::new();
        for m in &modules {
            let pid = next_pid;
            next_pid += 1;
            pids.insert(*m, pid);
            let pname = match m {
                Some(m) => format!("{label} · module{m}"),
                None => format!("{label} · engine"),
            };
            events.push(meta_event("process_name", pid, None, &pname));
        }
        for (m, tenant, lane, lane_name) in &lanes {
            let pid = pids[m];
            let tid = (*tenant as u64) * 4 + lane;
            let tname = format!("t{tenant}/{lane_name}");
            events.push(meta_event("thread_name", pid, Some(tid), &tname));
        }
        let (mut count, mut dropped) = (0u64, 0u64);
        for rec in recs {
            for ev in rec.trace.events() {
                events.push(trace_event(ev, pids[&ev.module]));
                count += 1;
            }
            dropped += rec.trace.dropped();
        }
        summary.push(Json::obj(vec![
            ("cell", Json::str(label)),
            ("events", Json::num(count as f64)),
            ("dropped", Json::num(dropped as f64)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        (
            "otherData",
            Json::obj(vec![
                ("clock", Json::str("sim-cycles")),
                ("cells", Json::arr(summary)),
            ]),
        ),
    ])
}

fn meta_event(name: &str, pid: u64, tid: Option<u64>, value: &str) -> Json {
    let mut pairs = vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid.unwrap_or(0) as f64)),
        ("args", Json::obj(vec![("name", Json::str(value))])),
    ];
    if tid.is_none() {
        pairs.retain(|(k, _)| *k != "tid");
    }
    Json::obj(pairs)
}

fn trace_event(ev: &Event, pid: u64) -> Json {
    let (lane, lane_name) = ev.kind.lane();
    let mut args = vec![("page", Json::num(ev.page as f64))];
    if ev.bytes > 0 {
        args.push(("bytes", Json::num(ev.bytes as f64)));
    }
    if let Some(d) = ev.detail {
        args.push(("detail", Json::str(d)));
    }
    let mut pairs = vec![
        ("name", Json::str(ev.kind.name())),
        ("cat", Json::str(lane_name)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num((ev.tenant as u64 * 4 + lane) as f64)),
        ("ts", Json::num(ev.at)),
        ("args", Json::obj(args)),
    ];
    if ev.dur > 0.0 {
        pairs.push(("ph", Json::str("X")));
        pairs.push(("dur", Json::num(ev.dur)));
    } else {
        pairs.push(("ph", Json::str("i")));
        pairs.push(("s", Json::str("t")));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ObsSpec, Recorder};

    fn ev(kind: EventKind, at: f64) -> Event {
        Event::instant(kind, 0, Some(0), 42, at)
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let mut ring = TraceRing::new(3);
        for i in 0..5 {
            ring.push(ev(EventKind::Throttle, i as f64));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let ts: Vec<f64> = ring.events().map(|e| e.at).collect();
        assert_eq!(ts, [2.0, 3.0, 4.0], "tail of the run is retained");
        let mut zero = TraceRing::new(0);
        zero.push(ev(EventKind::Throttle, 0.0));
        assert_eq!((zero.len(), zero.dropped()), (0, 1));
    }

    #[test]
    fn chrome_export_is_valid_and_complete() {
        let mut rec = Recorder::new(ObsSpec::enabled());
        rec.event(Event::span(EventKind::PageMove, 1, Some(0), 7, 2048, 100.0, 50.0));
        rec.event(Event::instant(EventKind::Throttle, 1, None, 8, 160.0));
        let doc = chrome_trace(&[("fig9/0".to_string(), vec![&rec])]);
        let v = Json::parse(&doc.to_string()).unwrap();
        let evs = v.get_arr("traceEvents").unwrap();
        // 2 process_name + 2 thread_name metadata + 2 events.
        assert_eq!(evs.len(), 6);
        let span = evs.iter().find(|e| e.get_str("name") == Some("PageMove")).unwrap();
        assert_eq!(span.get_str("ph"), Some("X"));
        assert_eq!(span.get_f64("ts"), Some(100.0));
        assert_eq!(span.get_f64("dur"), Some(50.0));
        let inst = evs.iter().find(|e| e.get_str("name") == Some("Throttle")).unwrap();
        assert_eq!(inst.get_str("ph"), Some("i"));
        assert_eq!(inst.get_str("s"), Some("t"));
        let cells = v.get("otherData").unwrap().get_arr("cells").unwrap();
        assert_eq!(cells[0].get_f64("events"), Some(2.0));
        assert_eq!(cells[0].get_f64("dropped"), Some(0.0));
    }

    #[test]
    fn export_orders_pids_by_cell_then_module() {
        let mut a = Recorder::new(ObsSpec::enabled());
        a.event(Event::instant(EventKind::LineFetch, 0, Some(1), 1, 5.0));
        a.event(Event::instant(EventKind::Throttle, 0, None, 1, 6.0));
        let mut b = Recorder::new(ObsSpec::enabled());
        b.event(Event::instant(EventKind::LineFetch, 0, Some(0), 2, 7.0));
        let doc = chrome_trace(&[
            ("cellA".to_string(), vec![&a]),
            ("cellB".to_string(), vec![&b]),
        ]);
        let s1 = doc.to_string();
        let s2 = chrome_trace(&[
            ("cellA".to_string(), vec![&a]),
            ("cellB".to_string(), vec![&b]),
        ])
        .to_string();
        assert_eq!(s1, s2, "export is a pure function of its input");
        // cellA gets pids 1 (engine lane, None sorts first) and 2; cellB pid 3.
        let v = Json::parse(&s1).unwrap();
        let names: Vec<(f64, String)> = v
            .get_arr("traceEvents")
            .unwrap()
            .iter()
            .filter(|e| e.get_str("name") == Some("process_name"))
            .map(|e| {
                let arg = e.get("args").unwrap().get_str("name").unwrap().to_string();
                (e.get_f64("pid").unwrap(), arg)
            })
            .collect();
        assert_eq!(
            names,
            vec![
                (1.0, "cellA · engine".to_string()),
                (2.0, "cellA · module1".to_string()),
                (3.0, "cellB · module0".to_string()),
            ]
        );
    }
}
