//! Epoch telemetry: per-tenant / per-module snapshots of the observable
//! state the adaptive-policy layer will consume, exported as JSONL via
//! the zero-dependency `util::json`.
//!
//! A snapshot is taken at each crossed epoch boundary (see
//! `Recorder::epoch_crossed`) plus once at the run horizon, and carries
//! compute-engine queue depths, local-memory occupancy, cumulative
//! movement counters, and a per-module sample of link/engine backlogs,
//! port state, fault counters, and raw-vs-compressed egress bytes.
//! Everything is cumulative-or-instantaneous machine-local state: no
//! wall clock, no process-global counters.

use crate::system::fault::PortState;
use crate::util::json::Json;

/// Markdown/JSON-friendly name of a port state.
pub fn port_name(s: PortState) -> &'static str {
    match s {
        PortState::Up => "up",
        PortState::Down => "down",
        PortState::Recovering => "recovering",
    }
}

/// One memory module's observable state at a snapshot instant, as seen
/// from the sampling tenant's ports.
#[derive(Clone, Debug, PartialEq)]
pub struct ModuleSample {
    pub module: usize,
    /// This tenant's downlink port state on the module.
    pub port: PortState,
    /// Fabric downlink backlog in cycles, by traffic class.
    pub link_backlog_pages: f64,
    pub link_backlog_lines: f64,
    /// Memory-engine bus backlog in cycles, by traffic class.
    pub engine_backlog_pages: f64,
    pub engine_backlog_lines: f64,
    /// Cumulative uncompressed bytes the module served toward this
    /// tenant, and bytes actually sent after link compression.
    pub egress_raw_bytes: u64,
    pub egress_sent_bytes: u64,
    /// Cumulative capacity served on borrowed shares (work-conserving
    /// sharing modes only).
    pub reclaimed_bytes: u64,
    /// Cumulative aborted-and-replayed transfers (fabric + engine).
    pub aborted: u64,
    /// Cumulative fault-deferred requests (fabric + engine).
    pub deferred: u64,
    /// Link-condition scale from the module's downlink schedule at the
    /// sample instant: 1.0 = nominal, < 1.0 = degraded bandwidth.  This
    /// is the *schedule's* multiplier, not the absolute rate, so it is
    /// invariant under controller weight rebalancing — the closed-loop
    /// distress signal cannot feed back on its own actuation.
    pub link_rate_scale: f64,
}

impl ModuleSample {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("module", Json::num(self.module as f64)),
            ("port", Json::str(port_name(self.port))),
            ("link_backlog_pages", Json::num(self.link_backlog_pages)),
            ("link_backlog_lines", Json::num(self.link_backlog_lines)),
            ("engine_backlog_pages", Json::num(self.engine_backlog_pages)),
            ("engine_backlog_lines", Json::num(self.engine_backlog_lines)),
            ("egress_raw_bytes", Json::num(self.egress_raw_bytes as f64)),
            ("egress_sent_bytes", Json::num(self.egress_sent_bytes as f64)),
            ("reclaimed_bytes", Json::num(self.reclaimed_bytes as f64)),
            ("aborted", Json::num(self.aborted as f64)),
            ("deferred", Json::num(self.deferred as f64)),
            ("link_rate_scale", Json::num(self.link_rate_scale)),
        ])
    }
}

/// One tenant-wide telemetry sample at a sim-cycle instant.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Sim cycle the sample is stamped with (an epoch boundary, or the
    /// run horizon for the final sample).
    pub cycle: f64,
    /// Tenant index on the shared fabric (0 for a solo machine).
    pub tenant: usize,
    /// Compute-engine selection-unit queue depths.
    pub inflight_pages: usize,
    pub inflight_lines: usize,
    pub dirty_buffered: usize,
    /// Inflight-buffer occupancy fractions (the §4.5 selection inputs).
    pub page_buf_util: f64,
    pub line_buf_util: f64,
    /// Local-memory occupancy and cumulative hit rate.
    pub local_pages: usize,
    pub local_capacity: usize,
    pub local_hit_rate: f64,
    /// Cumulative movement counters (mirrors of the run metrics).
    pub pages_moved: u64,
    pub lines_moved: u64,
    pub pages_throttled: u64,
    pub net_bytes_in: u64,
    /// Achieved compression ratio so far (1.0 for uncompressed schemes).
    pub compression_ratio: f64,
    pub modules: Vec<ModuleSample>,
}

impl Snapshot {
    /// An all-zero snapshot shell (tests and pre-wiring callers).
    pub fn empty(tenant: usize, cycle: f64) -> Snapshot {
        Snapshot {
            cycle,
            tenant,
            inflight_pages: 0,
            inflight_lines: 0,
            dirty_buffered: 0,
            page_buf_util: 0.0,
            line_buf_util: 0.0,
            local_pages: 0,
            local_capacity: 0,
            local_hit_rate: 0.0,
            pages_moved: 0,
            lines_moved: 0,
            pages_throttled: 0,
            net_bytes_in: 0,
            compression_ratio: 1.0,
            modules: Vec::new(),
        }
    }

    /// One JSONL record; `cell` labels which sweep cell produced it.
    pub fn to_json(&self, cell: &str) -> Json {
        Json::obj(vec![
            ("cell", Json::str(cell)),
            ("cycle", Json::num(self.cycle)),
            ("tenant", Json::num(self.tenant as f64)),
            ("inflight_pages", Json::num(self.inflight_pages as f64)),
            ("inflight_lines", Json::num(self.inflight_lines as f64)),
            ("dirty_buffered", Json::num(self.dirty_buffered as f64)),
            ("page_buf_util", Json::num(self.page_buf_util)),
            ("line_buf_util", Json::num(self.line_buf_util)),
            ("local_pages", Json::num(self.local_pages as f64)),
            ("local_capacity", Json::num(self.local_capacity as f64)),
            ("local_hit_rate", Json::num(self.local_hit_rate)),
            ("pages_moved", Json::num(self.pages_moved as f64)),
            ("lines_moved", Json::num(self.lines_moved as f64)),
            ("pages_throttled", Json::num(self.pages_throttled as f64)),
            ("net_bytes_in", Json::num(self.net_bytes_in as f64)),
            ("compression_ratio", Json::num(self.compression_ratio)),
            ("modules", Json::arr(self.modules.iter().map(ModuleSample::to_json))),
        ])
    }
}

/// A machine's ordered snapshot series.
#[derive(Default)]
pub struct Telemetry {
    pub snapshots: Vec<Snapshot>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { snapshots: Vec::new() }
    }
}

/// Serialize cells' telemetry as JSONL, one record per snapshot, in
/// (cell, tenant, snapshot) order — the order is a pure function of the
/// cell list, so the output is byte-identical across `--jobs` counts.
pub fn telemetry_jsonl(cells: &[(String, Vec<&super::Recorder>)]) -> String {
    let mut out = String::new();
    for (label, recs) in cells {
        for rec in recs {
            for snap in &rec.telemetry.snapshots {
                out.push_str(&snap.to_json(label).to_string());
                out.push('\n');
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ObsSpec, Recorder};

    #[test]
    fn snapshot_jsonl_round_trips_through_the_parser() {
        let mut snap = Snapshot::empty(1, 200_000.0);
        snap.inflight_pages = 3;
        snap.modules.push(ModuleSample {
            module: 0,
            port: PortState::Recovering,
            link_backlog_pages: 12.5,
            link_backlog_lines: 0.0,
            engine_backlog_pages: 3.0,
            engine_backlog_lines: 1.0,
            egress_raw_bytes: 4096,
            egress_sent_bytes: 1024,
            reclaimed_bytes: 0,
            aborted: 1,
            deferred: 2,
            link_rate_scale: 0.25,
        });
        let mut rec = Recorder::new(ObsSpec::enabled());
        rec.push_snapshot(snap);
        let jsonl = telemetry_jsonl(&[("fig9/0".to_string(), vec![&rec])]);
        let line = jsonl.lines().next().unwrap();
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get_str("cell"), Some("fig9/0"));
        assert_eq!(v.get_f64("cycle"), Some(200_000.0));
        assert_eq!(v.get_f64("inflight_pages"), Some(3.0));
        let m = &v.get_arr("modules").unwrap()[0];
        assert_eq!(m.get_str("port"), Some("recovering"));
        assert_eq!(m.get_f64("egress_sent_bytes"), Some(1024.0));
        assert_eq!(m.get_f64("link_rate_scale"), Some(0.25));
    }
}
