//! daemon-sim — CLI for the DaeMon disaggregated-system simulator.
//!
//! ```text
//! daemon-sim run --workload pr --scheme daemon [--switch-ns 100]
//!            [--bw-factor 4] [--cores 1] [--ratio 0.25] [--fifo]
//!            [--max-accesses N] [--estimator exact|pjrt] [--json]
//! daemon-sim experiment fig8 [fig9 ...] [--quick] [--jobs K]
//!            [--shard I/N] [--out results/]
//!            [--telemetry-out t.jsonl] [--telemetry-epoch 100000]
//!            [--trace-out trace.json] [--stats] [--progress]
//! daemon-sim experiment all [--quick]
//! daemon-sim merge shard-0-of-2.json shard-1-of-2.json [--out results/]
//! daemon-sim list
//! ```

use daemon_sim::config::{Replacement, SimConfig};
use daemon_sim::experiments::orchestrator::{self, Shard, ShardData};
use daemon_sim::experiments::{default_experiment_ids, Runner, REGISTRY};
use daemon_sim::obs::{self, ObsSpec};
use daemon_sim::runtime::{ModelRunner, NetParams, PjrtOracle};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::util::cli::Args;
use daemon_sim::util::json::Json;
use daemon_sim::util::table::Table;
use daemon_sim::workloads::cache::TraceCache;
use daemon_sim::workloads::{by_name, Scale, ALL};
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        // `sweep` is an alias: every experiment run goes through the
        // orchestrator's flat scheduler.
        Some("experiment") | Some("sweep") => cmd_experiment(&args),
        Some("merge") => cmd_merge(&args),
        Some("list") => cmd_list(),
        _ => {
            eprintln!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
daemon-sim — DaeMon (SIGMETRICS'23) disaggregated-system simulator

USAGE:
  daemon-sim run --workload <wl> --scheme <s> [options]
  daemon-sim experiment <id>... | all [--quick] [--jobs K] [--shard I/N]
             [--out DIR]
  daemon-sim merge <shard.json>... [--out DIR]
  daemon-sim list

RUN OPTIONS:
  --workload    one of kc tr pr nw bf bc ts sp sl hp pf dr rs
  --scheme      local | cache-line | remote | page-free |
                cache-line+page | lc | bp | pq | daemon
  --switch-ns   network switch latency, ns        [100]
  --bw-factor   DRAM-bandwidth / link-bandwidth   [4]
  --cores       cores in the compute component    [1]
  --ratio       line bandwidth partition ratio    [0.25]
  --memcomps    number of memory components       [1]
  --fifo        FIFO local-memory replacement (default LRU)
  --scale       test | paper                      [paper]
  --max-accesses trace cap                        [2000000]
  --estimator   exact | pjrt (AOT artifact)       [exact]
  --seed        RNG seed                          [3565]
  --json        machine-readable output

EXPERIMENT OPTIONS:
  --quick       400K-access traces (CI smoke) instead of 2M
  --scale       test | paper trace scale            [paper]
  --max-accesses trace cap override (tiny smoke runs)
  --jobs K      worker threads for the cell scheduler  [cores-1]
  --shard I/N   run only slots with slot%N==I and write a
                shard-I-of-N.json for `merge` (CI grid splitting)
  --out DIR     write per-table CSVs + figures.json (or the shard file)
  --telemetry-out F  write epoch-sampled telemetry snapshots as JSONL
                (unsharded runs only; byte-identical across --jobs)
  --telemetry-epoch C  telemetry/port sampling period, sim cycles [100000]
  --trace-out F write structured sim-time events as Chrome-trace JSON
                (open at https://ui.perfetto.dev; ts/dur are sim cycles)
  --stats       end-of-run counter summary on stderr (size memo,
                trace cache, cells) — process-global, never in artifacts
  --progress    live cells-completed progress on stderr

Cluster experiments (`cluster_contention`, `cluster_fairness`) simulate
C tenants sharing M memory modules over the switched fabric and report
per-tenant + fairness aggregates; `variability` sweeps scheme x
sharing-mode (strict vs work-conserving) x link-condition schedule
(steady / bandwidth bursts / bandwidth+latency bursts) over the same
cluster; `resilience` sweeps scheme x fault pattern (module crash, link
flaps, tenant kill) x recovery policy (stall-until-recovery vs re-fetch
from a surviving module) and reports downtime, aborted/deferred
requests, and per-tenant slowdown vs the no-fault run; `adaptive` runs
the closed-loop controller (per-epoch migration-ratio retuning,
recovery switching, idle-share rebalancing) against every static
configuration across a disturbance grid and reports goodput plus
controller actuation counts; `tail_latency` serves an open-loop request
stream (steady / bursty / diurnal arrivals x load factor) through the
cluster under layered robustness stacks (naive, deadline+retry,
+hedge+shed) and reports p99/p999 request latency, goodput-under-SLO
and timeout/retry/hedge/shed counts, with every knob self-calibrated
from a per-scheme probe run.  All of them batch/shard like any figure;
`list` prints the full registry.
";

fn parse_scale(s: &str) -> Result<Scale, String> {
    match s {
        "test" => Ok(Scale::Test),
        "paper" => Ok(Scale::Paper),
        other => Err(format!("bad --scale '{other}'")),
    }
}

fn cmd_list() -> i32 {
    println!("workloads: {}", ALL.join(" "));
    // Scheme ids come straight from the policy registry, so `list` can
    // never drift from what `--scheme` actually resolves.
    println!("schemes:   {}", daemon_sim::policy::scheme_ids().join(" "));
    println!("experiments:");
    for d in REGISTRY.iter() {
        let extra = if d.in_all { "" } else { "  [extra; not in `all`]" };
        println!("  {:<24} {}{}", d.id, d.about, extra);
    }
    0
}

fn build_cfg(args: &Args) -> Result<SimConfig, String> {
    let mut cfg = SimConfig::default()
        .with_net(
            args.get_f64("switch-ns", 100.0)?,
            args.get_f64("bw-factor", 4.0)?,
        )
        .with_cores(args.get_usize("cores", 1)?)
        .with_partition_ratio(args.get_f64("ratio", 0.25)?)
        .with_seed(args.get_u64("seed", 3565)?);
    let n = args.get_usize("memcomps", 1)?;
    if n > 1 {
        let net0 = cfg.net[0];
        cfg = cfg.with_memory_components(vec![net0; n]);
    }
    if args.flag("fifo") {
        cfg = cfg.with_replacement(Replacement::Fifo);
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> i32 {
    let run_inner = || -> Result<i32, String> {
        let wl_name = args.get("workload").ok_or("missing --workload")?;
        let scheme_name = args.get("scheme").ok_or("missing --scheme")?;
        let kind = SchemeKind::by_name(scheme_name)
            .ok_or_else(|| format!("unknown scheme '{scheme_name}'"))?;
        let workload =
            by_name(wl_name).ok_or_else(|| format!("unknown workload '{wl_name}'"))?;
        let cfg = build_cfg(args)?;
        let scale = parse_scale(args.get_or("scale", "paper"))?;
        let max = args.get_usize("max-accesses", 2_000_000)?;
        let trace = workload.generate(cfg.seed, scale).truncated(max);

        let oracle: Option<Box<dyn daemon_sim::system::SizeOracle>> =
            match args.get_or("estimator", "exact") {
                "exact" => None,
                "pjrt" => {
                    let runner =
                        ModelRunner::load_default().map_err(|e| format!("{e:#}"))?;
                    let mut params = NetParams::paper_default();
                    params.switch_cycles = (cfg.net[0].switch_latency_ns * 3.6) as f32;
                    params.partition_ratio = cfg.daemon.partition_ratio as f32;
                    Some(Box::new(PjrtOracle::new(
                        runner,
                        params,
                        cfg.seed,
                        vec![workload.profile(); cfg.cores],
                    )))
                }
                other => return Err(format!("bad --estimator '{other}'")),
            };

        let mut m = Machine::new(
            cfg.clone(),
            kind,
            trace.footprint_pages,
            vec![workload.profile(); cfg.cores],
            oracle,
        );
        // CLI progress reporting only — never feeds simulated time.
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        m.run(std::slice::from_ref(&trace));
        let wall = t0.elapsed().as_secs_f64();
        let metrics = &m.metrics;

        if args.flag("json") {
            let j = Json::obj(vec![
                ("workload", Json::str(wl_name)),
                ("scheme", Json::str(kind.name())),
                ("ipc", Json::num(metrics.ipc())),
                ("cycles", Json::num(metrics.cycles)),
                ("instructions", Json::num(metrics.instructions as f64)),
                ("access_cost_cycles", Json::num(metrics.mean_access_cost())),
                ("local_hit_ratio", Json::num(metrics.local_hit_ratio())),
                ("pages_moved", Json::num(metrics.pages_moved as f64)),
                ("lines_moved", Json::num(metrics.lines_moved as f64)),
                ("net_utilization", Json::num(metrics.net_utilization)),
                ("compression_ratio", Json::num(metrics.compression_ratio)),
                ("wall_seconds", Json::num(wall)),
            ]);
            println!("{j}");
        } else {
            println!("workload={wl_name} scheme={}", kind.name());
            println!("  IPC               {:.4}", metrics.ipc());
            println!("  cycles            {:.0}", metrics.cycles);
            println!("  instructions      {}", metrics.instructions);
            println!("  access cost       {:.1} cycles", metrics.mean_access_cost());
            println!("  local hit ratio   {:.3}", metrics.local_hit_ratio());
            println!("  pages moved       {}", metrics.pages_moved);
            println!("  lines moved       {}", metrics.lines_moved);
            println!("  net utilization   {:.2}", metrics.net_utilization);
            println!("  compression ratio {:.2}", metrics.compression_ratio);
            println!(
                "  simulated {:.2}M accesses in {:.2}s ({:.2}M acc/s)",
                trace.accesses.len() as f64 / 1e6,
                wall,
                trace.accesses.len() as f64 / 1e6 / wall
            );
        }
        Ok(0)
    };
    match run_inner() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

/// Print a figure set and write its CSVs + figures.json under `out_dir`.
fn emit_sets(
    sets: &[(String, Vec<Table>)],
    out_dir: &Option<PathBuf>,
) -> Result<(), String> {
    for (id, tables) in sets {
        for t in tables {
            println!("{}", t.render());
            if let Some(d) = out_dir {
                let fname = t
                    .title
                    .chars()
                    .map(|c| if c.is_alphanumeric() { c } else { '_' })
                    .collect::<String>();
                std::fs::write(d.join(format!("{fname}.csv")), t.to_csv())
                    .map_err(|e| format!("write csv for {id}: {e}"))?;
            }
        }
    }
    if let Some(d) = out_dir {
        let path = d.join("figures.json");
        std::fs::write(&path, orchestrator::figures_json(sets).to_string())
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        eprintln!("[wrote {}]", path.display());
    }
    Ok(())
}

/// Write an observability artifact, creating parent directories as
/// needed (obs outputs are standalone paths, not tied to `--out`).
fn write_artifact(path: &Path, text: &str) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("write {}: {e}", path.display()))?;
    eprintln!("[wrote {}]", path.display());
    Ok(())
}

/// `--stats` end-of-run summary.  These counters are process-global and
/// scheduling-dependent, so they print to stderr only and never land in
/// deterministic artifacts (shard files, figures.json, obs outputs).
fn print_stats(cache: &TraceCache, cells: usize) {
    let memo = daemon_sim::compress::global_memo_stats();
    let tc = cache.stats();
    eprintln!("[stats] cells completed: {cells}");
    eprintln!(
        "[stats] size memo: {} entries, {} full drops",
        memo.entries, memo.full_drops
    );
    eprintln!("[stats] trace cache: {} hits, {} misses", tc.hits, tc.misses);
}

fn cmd_experiment(args: &Args) -> i32 {
    let inner = || -> Result<i32, String> {
        let mut runner = if args.flag("quick") {
            Runner::quick()
        } else {
            Runner::paper()
        };
        if let Some(s) = args.get("scale") {
            runner.scale = parse_scale(s)?;
        }
        runner.max_accesses = args.get_usize("max-accesses", runner.max_accesses)?;
        runner.threads = args.get_usize("jobs", runner.threads)?.max(1);
        // An explicit --shard always produces a shard file, even 0/1, so
        // scripted shard matrices work at N=1.
        let shard = args
            .get_shard("shard")?
            .map(|(index, total)| Shard { index, total });
        let ids: Vec<String> = if args.positional.iter().any(|p| p == "all") {
            default_experiment_ids().iter().map(|s| s.to_string()).collect()
        } else if args.positional.is_empty() {
            return Err("no experiment id given; try `daemon-sim list`".into());
        } else {
            args.positional.clone()
        };
        let out_dir = args.get("out").map(PathBuf::from);
        if let Some(d) = &out_dir {
            std::fs::create_dir_all(d).map_err(|e| format!("{}: {e}", d.display()))?;
        }

        // Observability: either output file switches its channel on; the
        // epoch only matters when telemetry is being recorded.
        let telemetry_out = args.get("telemetry-out").map(PathBuf::from);
        let trace_out = args.get("trace-out").map(PathBuf::from);
        let epoch = args.get_f64("telemetry-epoch", ObsSpec::DEFAULT_EPOCH_CYCLES)?;
        if epoch <= 0.0 {
            return Err("--telemetry-epoch must be a positive cycle count".into());
        }
        let obs_spec = if telemetry_out.is_some() || trace_out.is_some() {
            let mut spec = ObsSpec::enabled().with_epoch(epoch);
            spec.telemetry = telemetry_out.is_some();
            spec.trace = trace_out.is_some();
            Some(spec)
        } else {
            None
        };
        let want_stats = args.flag("stats");
        let want_progress = args.flag("progress");
        if shard.is_some() && (obs_spec.is_some() || want_progress) {
            return Err(
                "--telemetry-out/--trace-out/--progress require an unsharded run \
                 (recorders and live progress don't straddle shard files); drop --shard"
                    .into(),
            );
        }

        // CLI progress reporting only — never feeds simulated time.
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        let cache = TraceCache::global();
        match shard {
            None => {
                let progress: Option<Box<dyn Fn(usize, usize) + Sync>> = if want_progress {
                    Some(Box::new(move |done, total| {
                        eprintln!("[{done}/{total} cells, {:.1}s]", t0.elapsed().as_secs_f64());
                    }))
                } else {
                    None
                };
                let (sets, sobs) = orchestrator::sweep_obs(
                    &ids,
                    &runner,
                    cache,
                    runner.threads,
                    obs_spec.as_ref(),
                    progress.as_deref(),
                )?;
                emit_sets(&sets, &out_dir)?;
                if obs_spec.is_some() {
                    let cells: Vec<(String, Vec<&obs::Recorder>)> = sobs
                        .cells
                        .iter()
                        .map(|(label, recs)| (label.clone(), recs.iter().collect()))
                        .collect();
                    if let Some(p) = &telemetry_out {
                        write_artifact(p, &obs::telemetry_jsonl(&cells))?;
                    }
                    if let Some(p) = &trace_out {
                        write_artifact(p, &obs::chrome_trace(&cells).to_string())?;
                    }
                }
                let stats = cache.stats();
                eprintln!(
                    "[{} experiment(s), {:.1}s, {} jobs; traces: {} generated, {} reused]",
                    sets.len(),
                    t0.elapsed().as_secs_f64(),
                    runner.threads,
                    stats.misses,
                    stats.hits
                );
                if want_stats {
                    print_stats(cache, sobs.cells.len());
                }
            }
            Some(shard) => {
                let data =
                    orchestrator::sweep_shard(&ids, &runner, cache, shard, runner.threads)?;
                let fname = format!("shard-{}-of-{}.json", data.shard.index, data.shard.total);
                let path = out_dir.unwrap_or_else(|| PathBuf::from(".")).join(fname);
                std::fs::write(&path, data.to_json().to_string())
                    .map_err(|e| format!("write {}: {e}", path.display()))?;
                eprintln!(
                    "[shard {}/{}: {} of {} cells in {:.1}s -> {}]",
                    data.shard.index,
                    data.shard.total,
                    data.results.len(),
                    data.total_slots,
                    t0.elapsed().as_secs_f64(),
                    path.display()
                );
                if want_stats {
                    print_stats(cache, data.results.len());
                }
            }
        }
        Ok(0)
    };
    match inner() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_merge(args: &Args) -> i32 {
    let inner = || -> Result<i32, String> {
        if args.positional.is_empty() {
            return Err("merge: pass the shard JSON files to recombine".into());
        }
        let mut shards = Vec::new();
        for p in &args.positional {
            let text =
                std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
            let j = Json::parse(&text).map_err(|e| format!("{p}: bad JSON: {e}"))?;
            shards.push(ShardData::from_json(&j).map_err(|e| format!("{p}: {e}"))?);
        }
        let sets = orchestrator::merge_shards(&shards)?;
        let out_dir = args.get("out").map(PathBuf::from);
        if let Some(d) = &out_dir {
            std::fs::create_dir_all(d).map_err(|e| format!("{}: {e}", d.display()))?;
        }
        emit_sets(&sets, &out_dir)?;
        eprintln!("[merged {} shard file(s) into {} experiment(s)]", shards.len(), sets.len());
        if args.flag("stats") {
            let slots: usize = shards.iter().map(|s| s.results.len()).sum();
            eprintln!("[stats] shard slots merged: {slots}");
        }
        Ok(0)
    };
    match inner() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
