//! daemon-sim — CLI for the DaeMon disaggregated-system simulator.
//!
//! ```text
//! daemon-sim run --workload pr --scheme daemon [--switch-ns 100]
//!            [--bw-factor 4] [--cores 1] [--ratio 0.25] [--fifo]
//!            [--max-accesses N] [--estimator exact|pjrt] [--json]
//! daemon-sim experiment fig8 [fig9 ...] [--quick] [--out results/]
//! daemon-sim experiment all [--quick]
//! daemon-sim list
//! ```

use daemon_sim::config::{Replacement, SimConfig};
use daemon_sim::experiments::{run_experiment, Runner, ALL_EXPERIMENTS};
use daemon_sim::runtime::{ModelRunner, NetParams, PjrtOracle};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::util::cli::Args;
use daemon_sim::util::json::Json;
use daemon_sim::workloads::{by_name, Scale, ALL};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("list") => cmd_list(),
        _ => {
            eprintln!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
daemon-sim — DaeMon (SIGMETRICS'23) disaggregated-system simulator

USAGE:
  daemon-sim run --workload <wl> --scheme <s> [options]
  daemon-sim experiment <id>... | all [--quick] [--out DIR]
  daemon-sim list

RUN OPTIONS:
  --workload    one of kc tr pr nw bf bc ts sp sl hp pf dr rs
  --scheme      local | cache-line | remote | page-free |
                cache-line+page | lc | bp | pq | daemon
  --switch-ns   network switch latency, ns        [100]
  --bw-factor   DRAM-bandwidth / link-bandwidth   [4]
  --cores       cores in the compute component    [1]
  --ratio       line bandwidth partition ratio    [0.25]
  --memcomps    number of memory components       [1]
  --fifo        FIFO local-memory replacement (default LRU)
  --scale       test | paper                      [paper]
  --max-accesses trace cap                        [2000000]
  --estimator   exact | pjrt (AOT artifact)       [exact]
  --seed        RNG seed                          [3565]
  --json        machine-readable output
";

fn cmd_list() -> i32 {
    println!("workloads: {}", ALL.join(" "));
    println!(
        "schemes:   local cache-line remote page-free cache-line+page lc bp pq daemon"
    );
    println!("experiments: {}", ALL_EXPERIMENTS.join(" "));
    0
}

fn build_cfg(args: &Args) -> Result<SimConfig, String> {
    let mut cfg = SimConfig::default()
        .with_net(
            args.get_f64("switch-ns", 100.0)?,
            args.get_f64("bw-factor", 4.0)?,
        )
        .with_cores(args.get_usize("cores", 1)?)
        .with_partition_ratio(args.get_f64("ratio", 0.25)?)
        .with_seed(args.get_u64("seed", 3565)?);
    let n = args.get_usize("memcomps", 1)?;
    if n > 1 {
        let net0 = cfg.net[0];
        cfg = cfg.with_memory_components(vec![net0; n]);
    }
    if args.flag("fifo") {
        cfg = cfg.with_replacement(Replacement::Fifo);
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> i32 {
    let run_inner = || -> Result<i32, String> {
        let wl_name = args.get("workload").ok_or("missing --workload")?;
        let scheme_name = args.get("scheme").ok_or("missing --scheme")?;
        let kind = SchemeKind::by_name(scheme_name)
            .ok_or_else(|| format!("unknown scheme '{scheme_name}'"))?;
        let workload =
            by_name(wl_name).ok_or_else(|| format!("unknown workload '{wl_name}'"))?;
        let cfg = build_cfg(args)?;
        let scale = match args.get_or("scale", "paper") {
            "test" => Scale::Test,
            "paper" => Scale::Paper,
            other => return Err(format!("bad --scale '{other}'")),
        };
        let max = args.get_usize("max-accesses", 2_000_000)?;
        let trace = workload.generate(cfg.seed, scale).truncated(max);

        let oracle: Option<Box<dyn daemon_sim::system::SizeOracle>> =
            match args.get_or("estimator", "exact") {
                "exact" => None,
                "pjrt" => {
                    let runner =
                        ModelRunner::load_default().map_err(|e| format!("{e:#}"))?;
                    let mut params = NetParams::paper_default();
                    params.switch_cycles = (cfg.net[0].switch_latency_ns * 3.6) as f32;
                    params.partition_ratio = cfg.daemon.partition_ratio as f32;
                    Some(Box::new(PjrtOracle::new(
                        runner,
                        params,
                        cfg.seed,
                        vec![workload.profile(); cfg.cores],
                    )))
                }
                other => return Err(format!("bad --estimator '{other}'")),
            };

        let mut m = Machine::new(
            cfg.clone(),
            kind,
            trace.footprint_pages,
            vec![workload.profile(); cfg.cores],
            oracle,
        );
        let t0 = std::time::Instant::now();
        m.run(std::slice::from_ref(&trace));
        let wall = t0.elapsed().as_secs_f64();
        let metrics = &m.metrics;

        if args.flag("json") {
            let j = Json::obj(vec![
                ("workload", Json::str(wl_name)),
                ("scheme", Json::str(kind.name())),
                ("ipc", Json::num(metrics.ipc())),
                ("cycles", Json::num(metrics.cycles)),
                ("instructions", Json::num(metrics.instructions as f64)),
                ("access_cost_cycles", Json::num(metrics.mean_access_cost())),
                ("local_hit_ratio", Json::num(metrics.local_hit_ratio())),
                ("pages_moved", Json::num(metrics.pages_moved as f64)),
                ("lines_moved", Json::num(metrics.lines_moved as f64)),
                ("net_utilization", Json::num(metrics.net_utilization)),
                ("compression_ratio", Json::num(metrics.compression_ratio)),
                ("wall_seconds", Json::num(wall)),
            ]);
            println!("{j}");
        } else {
            println!("workload={wl_name} scheme={}", kind.name());
            println!("  IPC               {:.4}", metrics.ipc());
            println!("  cycles            {:.0}", metrics.cycles);
            println!("  instructions      {}", metrics.instructions);
            println!("  access cost       {:.1} cycles", metrics.mean_access_cost());
            println!("  local hit ratio   {:.3}", metrics.local_hit_ratio());
            println!("  pages moved       {}", metrics.pages_moved);
            println!("  lines moved       {}", metrics.lines_moved);
            println!("  net utilization   {:.2}", metrics.net_utilization);
            println!("  compression ratio {:.2}", metrics.compression_ratio);
            println!(
                "  simulated {:.2}M accesses in {:.2}s ({:.2}M acc/s)",
                trace.accesses.len() as f64 / 1e6,
                wall,
                trace.accesses.len() as f64 / 1e6 / wall
            );
        }
        Ok(0)
    };
    match run_inner() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let runner = if args.flag("quick") {
        Runner::quick()
    } else {
        Runner::paper()
    };
    let ids: Vec<String> = if args.positional.iter().any(|p| p == "all") {
        ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect()
    } else if args.positional.is_empty() {
        eprintln!("no experiment id given; try `daemon-sim list`");
        return 2;
    } else {
        args.positional.clone()
    };
    let out_dir = args.get("out").map(std::path::PathBuf::from);
    if let Some(d) = &out_dir {
        let _ = std::fs::create_dir_all(d);
    }
    for id in &ids {
        let t0 = std::time::Instant::now();
        match run_experiment(id, &runner) {
            None => {
                eprintln!("unknown experiment '{id}' — see `daemon-sim list`");
                return 1;
            }
            Some(tables) => {
                for t in &tables {
                    println!("{}", t.render());
                    if let Some(d) = &out_dir {
                        let fname = t
                            .title
                            .chars()
                            .map(|c| if c.is_alphanumeric() { c } else { '_' })
                            .collect::<String>();
                        let _ =
                            std::fs::write(d.join(format!("{fname}.csv")), t.to_csv());
                    }
                }
                eprintln!("[{id}: {:.1}s]", t0.elapsed().as_secs_f64());
            }
        }
    }
    0
}
