//! Fault injection and degraded-mode recovery — the "independent
//! failure-isolated components" axis of disaggregation (§1, §4.6).
//!
//! A [`FaultPlan`] is a plain-data list of fault windows, the same
//! shardable spec style as [`ScheduleSpec`](crate::config::ScheduleSpec):
//! module crash/recover windows (the module's fabric ports *and* its DRAM
//! engine go down), per-port link flaps (one tenant's path to one module),
//! and tenant kills (a compute component dies and stops issuing work).
//! [`crate::system::Cluster`] materializes the plan into per-resource
//! [`FaultTimeline`]s on the shared fabric and memory engines, and every
//! tenant `Machine` gets the cluster's [`RecoveryPolicy`].
//!
//! Failure semantics on a timeline-based resource (fabric port channel or
//! DRAM bus queue):
//!
//! * a request issued while the resource is down is **deferred** to the
//!   recovery edge (stall-until-recovery);
//! * a transfer whose issue→arrival interval overlaps a down window is
//!   **aborted** — the occupied wire/queue time is wasted (the bytes were
//!   in flight or queued at the component when it died) and the transfer
//!   is replayed from the recovery edge.  This covers queued work too:
//!   anything between issue and arrival is lost with the component.
//!
//! [`RecoveryPolicy`] decides what the *compute side* does about a dead
//! home module: `Stall` waits for recovery (every request pays the
//! deferral), `Refetch` re-routes requests to the next surviving module
//! (§4.6-style recovery from replicated data) so tenants keep making
//! progress — the failure-isolation property itself.  An empty plan and
//! the default `Stall` policy leave the no-fault timing byte-identical
//! (pinned by tests at every layer).

use crate::lifecycle::{Lifecycle, StateMachine, Transition};

/// What a fault window applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTarget {
    /// Whole memory module: all its fabric ports and its DRAM engine.
    Module { module: usize },
    /// One tenant's full-duplex port pair on one module (link flap).
    Link { module: usize, tenant: usize },
    /// A tenant's compute component dies at `from_cycle` (permanent:
    /// `to_cycle` is `f64::INFINITY`) and issues no further accesses.
    Tenant { tenant: usize },
}

/// One fault window: `target` is down during `[from_cycle, to_cycle)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultWindow {
    pub target: FaultTarget,
    pub from_cycle: f64,
    pub to_cycle: f64,
}

/// Plain-data fault-injection plan — carried by
/// [`ClusterConfig`](crate::config::ClusterConfig) and cluster cells so
/// the orchestrator can shard fault experiments like any figure.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub windows: Vec<FaultWindow>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Crash memory module `module` during `[from, to)` cycles.
    pub fn module_crash(mut self, module: usize, from: f64, to: f64) -> FaultPlan {
        self.windows.push(FaultWindow {
            target: FaultTarget::Module { module },
            from_cycle: from,
            to_cycle: to,
        });
        self
    }

    /// Flap tenant `tenant`'s link to module `module` during `[from, to)`.
    pub fn link_flap(mut self, module: usize, tenant: usize, from: f64, to: f64) -> FaultPlan {
        self.windows.push(FaultWindow {
            target: FaultTarget::Link { module, tenant },
            from_cycle: from,
            to_cycle: to,
        });
        self
    }

    /// Periodic link flaps: the port is down for the first `down_cycles`
    /// of every `period_cycles` until `horizon_cycles` (down first,
    /// matching the `ScheduleSpec` square-wave convention).
    pub fn link_flaps(
        mut self,
        module: usize,
        tenant: usize,
        period_cycles: f64,
        down_cycles: f64,
        horizon_cycles: f64,
    ) -> FaultPlan {
        assert!(
            period_cycles > 0.0 && down_cycles > 0.0 && down_cycles <= period_cycles,
            "flap down time must fit inside a positive period"
        );
        let mut t = 0.0;
        while t < horizon_cycles {
            self = self.link_flap(module, tenant, t, t + down_cycles);
            t += period_cycles;
        }
        self
    }

    /// Kill tenant `tenant`'s compute component at cycle `at` (permanent).
    pub fn tenant_kill(mut self, tenant: usize, at: f64) -> FaultPlan {
        self.windows.push(FaultWindow {
            target: FaultTarget::Tenant { tenant },
            from_cycle: at,
            to_cycle: f64::INFINITY,
        });
        self
    }

    /// Panic (with a clear message) on windows that reference resources
    /// outside a `modules` × `tenants` cluster or never recover.
    pub fn validate(&self, modules: usize, tenants: usize) {
        for w in &self.windows {
            assert!(
                w.from_cycle >= 0.0 && w.from_cycle.is_finite(),
                "fault window start must be finite and non-negative, got {}",
                w.from_cycle
            );
            assert!(
                w.to_cycle > w.from_cycle,
                "empty fault window [{}, {})",
                w.from_cycle,
                w.to_cycle
            );
            match w.target {
                FaultTarget::Module { module } => {
                    assert!(
                        module < modules,
                        "fault targets module {module} but the cluster has {modules}"
                    );
                    assert!(
                        w.to_cycle.is_finite(),
                        "module crash windows must recover (finite to_cycle)"
                    );
                }
                FaultTarget::Link { module, tenant } => {
                    assert!(
                        module < modules,
                        "link flap targets module {module} but the cluster has {modules}"
                    );
                    assert!(
                        tenant < tenants,
                        "link flap targets tenant {tenant} but the cluster has {tenants}"
                    );
                    assert!(
                        w.to_cycle.is_finite(),
                        "link flap windows must recover (finite to_cycle)"
                    );
                }
                FaultTarget::Tenant { tenant } => {
                    assert!(
                        tenant < tenants,
                        "fault kills tenant {tenant} but the cluster has {tenants}"
                    );
                }
            }
        }
    }

    /// Down timeline of tenant `tenant`'s port on module `module`: the
    /// module's crash windows plus that port's own link flaps, merged.
    pub fn port_timeline(&self, module: usize, tenant: usize) -> FaultTimeline {
        FaultTimeline::new(
            self.windows
                .iter()
                .filter(|w| match w.target {
                    FaultTarget::Module { module: m } => m == module,
                    FaultTarget::Link { module: m, tenant: t } => m == module && t == tenant,
                    FaultTarget::Tenant { .. } => false,
                })
                .map(|w| (w.from_cycle, w.to_cycle))
                .collect(),
        )
    }

    /// Down timeline of module `module`'s DRAM engine (crash windows
    /// only — link flaps leave the module itself serviceable).
    pub fn module_timeline(&self, module: usize) -> FaultTimeline {
        FaultTimeline::new(
            self.windows
                .iter()
                .filter(|w| w.target == FaultTarget::Module { module })
                .map(|w| (w.from_cycle, w.to_cycle))
                .collect(),
        )
    }

    /// Cycle at which tenant `tenant` is killed (`f64::INFINITY` when it
    /// never is).
    pub fn kill_time(&self, tenant: usize) -> f64 {
        self.windows
            .iter()
            .filter(|w| w.target == FaultTarget::Tenant { tenant })
            .map(|w| w.from_cycle)
            .fold(f64::INFINITY, f64::min)
    }
}

/// How a tenant machine treats remote accesses whose home module is down.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Wait for the home module to recover (every request to it is
    /// deferred to the recovery edge).
    #[default]
    Stall,
    /// Re-fetch from the next surviving module (§4.6-style recovery from
    /// replicated dirty data / a secondary home), falling back to the
    /// home module when every module is down.
    Refetch,
}

impl RecoveryPolicy {
    /// Canonical id — delegates to the registered
    /// [`RecoveryRoute`](crate::policy::RecoveryRoute), the single
    /// source of policy ids.
    pub fn name(&self) -> &'static str {
        crate::policy::recovery(*self).id()
    }
}

/// Observable lifecycle of a fabric port under fault injection (the
/// Up/Down/Recovering machine documented in DESIGN.md §"Lifecycles and
/// state machines"): `Down` inside a fault window; `Recovering` when up
/// again but still draining transfers a fault deferred or replayed;
/// `Up` otherwise.  Derived at query time by replaying the port's
/// [`FaultTimeline`] through the declared transition table
/// ([`FaultTimeline::port_state`]) — not recomputed ad hoc at call
/// sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortState {
    Up,
    Down,
    Recovering,
}

/// Events driving [`PortState`] — the edges a [`FaultTimeline`] replay
/// generates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortEvent {
    /// A fault window opens (`from_cycle`).
    GoDown,
    /// A fault window closes (`to_cycle`) with no backlog outstanding.
    Recover,
    /// Deferred/replayed transfers are still draining after recovery
    /// (`now < recovering_until`).
    Backlog,
    /// The fault backlog finishes draining (`recovering_until` passes).
    Drained,
}

impl Lifecycle for PortState {
    type Event = PortEvent;
    const NAME: &'static str = "fabric port";
    const STATES: &'static [PortState] =
        &[PortState::Up, PortState::Down, PortState::Recovering];
    const EVENTS: &'static [PortEvent] = &[
        PortEvent::GoDown,
        PortEvent::Recover,
        PortEvent::Backlog,
        PortEvent::Drained,
    ];
    const TABLE: &'static [Transition<PortState, PortEvent>] = &[
        Transition { from: PortState::Up, event: PortEvent::GoDown, to: PortState::Down },
        Transition { from: PortState::Down, event: PortEvent::Recover, to: PortState::Up },
        Transition { from: PortState::Up, event: PortEvent::Backlog, to: PortState::Recovering },
        Transition { from: PortState::Recovering, event: PortEvent::Drained, to: PortState::Up },
        Transition { from: PortState::Recovering, event: PortEvent::GoDown, to: PortState::Down },
    ];

    fn state_name(self) -> &'static str {
        match self {
            PortState::Up => "Up",
            PortState::Down => "Down",
            PortState::Recovering => "Recovering",
        }
    }
    fn event_name(event: PortEvent) -> &'static str {
        match event {
            PortEvent::GoDown => "GoDown",
            PortEvent::Recover => "Recover",
            PortEvent::Backlog => "Backlog",
            PortEvent::Drained => "Drained",
        }
    }
}

/// Fault bookkeeping of one resource: attempts lost to a mid-flight
/// crash and replayed, and attempts issued while down and deferred.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    pub aborted: u64,
    pub deferred: u64,
}

/// Sorted, merged down windows of one resource — the materialized form a
/// fabric port or memory engine holds.  An empty timeline short-circuits
/// to the exact no-fault code path (byte-identity pinned by tests).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultTimeline {
    /// Non-overlapping `(from, to)` windows sorted by `from`.
    windows: Vec<(f64, f64)>,
}

impl FaultTimeline {
    /// Build from arbitrary (possibly unsorted / overlapping) windows;
    /// empty and inverted windows are dropped, overlaps merged.
    pub fn new(mut windows: Vec<(f64, f64)>) -> FaultTimeline {
        windows.retain(|w| w.1 > w.0);
        windows.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut merged: Vec<(f64, f64)> = Vec::with_capacity(windows.len());
        for w in windows {
            match merged.last_mut() {
                Some(last) if w.0 <= last.1 => last.1 = last.1.max(w.1),
                _ => merged.push(w),
            }
        }
        FaultTimeline { windows: merged }
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    fn window_at(&self, t: f64) -> Option<(f64, f64)> {
        let i = self.windows.partition_point(|w| w.0 <= t);
        if i == 0 {
            return None;
        }
        let w = self.windows[i - 1];
        (t < w.1).then_some(w)
    }

    /// Whether the resource is down at `t` (windows are `[from, to)`).
    pub fn is_down(&self, t: f64) -> bool {
        self.window_at(t).is_some()
    }

    /// Earliest cycle at or after `t` at which the resource is up.
    pub fn release(&self, t: f64) -> f64 {
        self.window_at(t).map(|w| w.1).unwrap_or(t)
    }

    /// Recovery edge of the first down window overlapping `[start, end)`,
    /// `None` when the interval is fault-free.
    pub fn hit(&self, start: f64, end: f64) -> Option<f64> {
        let i = self.windows.partition_point(|w| w.1 <= start);
        let w = self.windows.get(i)?;
        (w.0 < end).then_some(w.1)
    }

    /// Run one attempt through the defer/abort/replay discipline — the
    /// single failure algorithm the fabric ports and memory engines
    /// share, so their semantics can never diverge.  `issue(at)`
    /// schedules the attempt at cycle `at` on the underlying resource
    /// and returns its completion.  Issue while down defers to the
    /// recovery edge; an attempt whose `[at, completion)` interval
    /// overlaps a later window is aborted (its occupied resource time is
    /// wasted) and replayed from that window's end.  Returns the
    /// surviving attempt's `(completion, start)` — the start feeds
    /// recovery bookkeeping — and counts deferrals/aborts into
    /// `counters`.  Terminates: every replay starts at a strictly later
    /// window's recovery edge, and windows are finitely many.
    pub fn replay(
        &self,
        now: f64,
        counters: &mut FaultCounters,
        mut issue: impl FnMut(f64) -> f64,
    ) -> (f64, f64) {
        let mut at = self.release(now);
        if at > now {
            counters.deferred += 1;
        }
        loop {
            let done = issue(at);
            match self.hit(at, done) {
                Some(end) => {
                    counters.aborted += 1;
                    at = end;
                }
                None => return (done, at),
            }
        }
    }

    /// The port's [`PortState`] at `now`, derived by replaying this
    /// timeline's edges through the declared lifecycle machine: every
    /// window with `from <= now` drives `GoDown` (returning mid-window),
    /// then `Recover`; after the walk, a booked fault backlog
    /// (`recovering_until` — the max deferred/replayed arrival the
    /// resource owner tracks) drives `Backlog`, and `Drained` once `now`
    /// passes it.
    pub fn port_state(&self, recovering_until: f64, now: f64) -> PortState {
        let mut m = StateMachine::new(PortState::Up);
        for w in self.windows.iter().take_while(|w| w.0 <= now) {
            m.transition(PortEvent::GoDown);
            if now < w.1 {
                return m.state();
            }
            m.transition(PortEvent::Recover);
        }
        if now < recovering_until {
            m.transition(PortEvent::Backlog);
        } else if recovering_until > 0.0 {
            // A backlog was booked at some point and has fully drained.
            m.transition(PortEvent::Backlog);
            m.transition(PortEvent::Drained);
        }
        m.state()
    }

    /// Total down time within `[0, horizon)`, cycles.
    pub fn downtime(&self, horizon: f64) -> f64 {
        self.windows
            .iter()
            .map(|w| (w.1.min(horizon) - w.0.max(0.0)).max(0.0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_merges_sorts_and_queries() {
        let t = FaultTimeline::new(vec![(300.0, 400.0), (100.0, 200.0), (150.0, 250.0)]);
        assert!(!t.is_empty());
        assert!(!t.is_down(50.0));
        assert!(t.is_down(100.0), "from_cycle is inclusive");
        assert!(t.is_down(249.0), "overlapping windows merged");
        assert!(!t.is_down(250.0), "to_cycle is exclusive");
        assert!(t.is_down(350.0));
        assert!(!t.is_down(400.0));
        assert_eq!(t.release(50.0), 50.0);
        assert_eq!(t.release(120.0), 250.0, "merged window releases at the max to");
        assert_eq!(t.release(400.0), 400.0);
        // Interval overlap: first window whose span intersects [start, end).
        assert_eq!(t.hit(0.0, 100.0), None, "half-open: ends exactly at from");
        assert_eq!(t.hit(0.0, 101.0), Some(250.0));
        assert_eq!(t.hit(250.0, 300.0), None, "gap between windows");
        assert_eq!(t.hit(250.0, 301.0), Some(400.0));
        assert_eq!(t.hit(500.0, 900.0), None, "past the last window");
        // Degenerate inputs: empty and inverted windows are dropped.
        assert!(FaultTimeline::new(vec![(5.0, 5.0), (9.0, 2.0)]).is_empty());
        assert!(!FaultTimeline::default().is_down(0.0));
        assert_eq!(FaultTimeline::default().release(7.0), 7.0);
    }

    #[test]
    fn replay_defers_aborts_and_counts() {
        let t = FaultTimeline::new(vec![(100.0, 500.0)]);
        let mut c = FaultCounters::default();
        // In flight at the crash (fixed 200-cycle service per attempt):
        // aborted at 100, replayed from 500, completes 700.
        let (done, at) = t.replay(0.0, &mut c, |at| at + 200.0);
        assert_eq!((done, at), (700.0, 500.0));
        assert_eq!(c, FaultCounters { aborted: 1, deferred: 0 });
        // Issued while down: deferred to the recovery edge.
        let (done, at) = t.replay(300.0, &mut c, |at| at + 10.0);
        assert_eq!((done, at), (510.0, 500.0));
        assert_eq!(c, FaultCounters { aborted: 1, deferred: 1 });
        // Clean past the window.
        let (done, at) = t.replay(600.0, &mut c, |at| at + 10.0);
        assert_eq!((done, at), (610.0, 600.0));
        assert_eq!(c, FaultCounters { aborted: 1, deferred: 1 });
    }

    #[test]
    fn downtime_clips_to_horizon() {
        let t = FaultTimeline::new(vec![(100.0, 200.0), (500.0, 700.0)]);
        assert_eq!(t.downtime(50.0), 0.0);
        assert!((t.downtime(150.0) - 50.0).abs() < 1e-9);
        assert!((t.downtime(400.0) - 100.0).abs() < 1e-9);
        assert!((t.downtime(1e6) - 300.0).abs() < 1e-9);
    }

    #[test]
    fn plan_builders_materialize_per_resource_timelines() {
        let plan = FaultPlan::new()
            .module_crash(1, 100.0, 200.0)
            .link_flap(0, 2, 50.0, 60.0)
            .tenant_kill(3, 500.0);
        plan.validate(2, 4);
        assert!(!plan.is_empty() && FaultPlan::new().is_empty());
        // Module 1's ports carry the crash for every tenant; only tenant
        // 2's module-0 port carries the flap; the kill hits no timeline.
        assert!(plan.port_timeline(1, 0).is_down(150.0));
        assert!(plan.port_timeline(1, 3).is_down(150.0));
        assert!(plan.port_timeline(0, 2).is_down(55.0));
        assert!(!plan.port_timeline(0, 0).is_down(55.0));
        assert!(plan.port_timeline(0, 0).is_empty());
        // The DRAM engine sees module crashes only, never link flaps.
        assert!(plan.module_timeline(1).is_down(150.0));
        assert!(plan.module_timeline(0).is_empty());
        assert_eq!(plan.kill_time(3), 500.0);
        assert_eq!(plan.kill_time(0), f64::INFINITY);
    }

    #[test]
    fn periodic_flaps_are_down_first() {
        let plan = FaultPlan::new().link_flaps(0, 0, 100.0, 25.0, 250.0);
        let t = plan.port_timeline(0, 0);
        assert!(t.is_down(0.0) && t.is_down(24.0));
        assert!(!t.is_down(25.0) && !t.is_down(99.0));
        assert!(t.is_down(100.0) && t.is_down(200.0));
        assert!(!t.is_down(300.0), "no flap past the horizon");
        assert!((t.downtime(1e6) - 75.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "targets module 2")]
    fn validate_rejects_out_of_range_module() {
        FaultPlan::new().module_crash(2, 0.0, 1.0).validate(2, 4);
    }

    #[test]
    #[should_panic(expected = "kills tenant 4")]
    fn validate_rejects_out_of_range_tenant() {
        FaultPlan::new().tenant_kill(4, 0.0).validate(2, 4);
    }

    #[test]
    #[should_panic(expected = "must recover")]
    fn validate_rejects_permanent_module_crash() {
        FaultPlan::new().module_crash(0, 0.0, f64::INFINITY).validate(2, 4);
    }

    #[test]
    fn recovery_policy_names() {
        assert_eq!(RecoveryPolicy::default(), RecoveryPolicy::Stall);
        assert_eq!(RecoveryPolicy::Stall.name(), "stall");
        assert_eq!(RecoveryPolicy::Refetch.name(), "refetch");
    }

    #[test]
    fn port_state_replays_the_declared_machine() {
        let t = FaultTimeline::new(vec![(100.0, 200.0), (400.0, 500.0)]);
        // No backlog booked: Up outside windows, Down inside.
        assert_eq!(t.port_state(0.0, 50.0), PortState::Up);
        assert_eq!(t.port_state(0.0, 100.0), PortState::Down);
        assert_eq!(t.port_state(0.0, 199.0), PortState::Down);
        assert_eq!(t.port_state(0.0, 300.0), PortState::Up);
        assert_eq!(t.port_state(0.0, 450.0), PortState::Down);
        // Backlog booked to 700: Recovering between recovery and drain,
        // Up once drained, and Down still wins inside a window.
        assert_eq!(t.port_state(700.0, 600.0), PortState::Recovering);
        assert_eq!(t.port_state(700.0, 700.0), PortState::Up);
        assert_eq!(t.port_state(700.0, 450.0), PortState::Down);
        // A booked backlog reads Recovering even before the first window
        // (the historical `recovering_until` quirk, kept bit-for-bit).
        assert_eq!(t.port_state(80.0, 60.0), PortState::Recovering);
        // Empty timeline with a booked backlog behaves the same way.
        let none = FaultTimeline::default();
        assert_eq!(none.port_state(0.0, 10.0), PortState::Up);
        assert_eq!(none.port_state(50.0, 10.0), PortState::Recovering);
        assert_eq!(none.port_state(50.0, 50.0), PortState::Up);
    }
}
