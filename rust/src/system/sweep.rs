//! Parallel experiment sweeps: run (workload x scheme x config) cells
//! across OS threads with `std::thread::scope` (the offline registry has
//! no rayon; a scoped fan-out is all a deterministic simulator needs).
//!
//! Traces come from the global [`TraceCache`] (generated once per key,
//! shared read-only) and results land in per-cell `OnceLock` slots — no
//! `Mutex` over the output vector.  For figure-grade sweeps with sharding
//! and JSON shard files, use `experiments::orchestrator` instead; this is
//! the lightweight ad-hoc grid API the examples use.

use crate::config::SimConfig;
use crate::experiments::orchestrator::{run_cell_spec, CellSpec};
use crate::experiments::Runner;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::workloads::cache::TraceCache;
use crate::workloads::Scale;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// One sweep cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub workload: String,
    pub scheme: SchemeKind,
    pub cfg: SimConfig,
    pub scale: Scale,
}

/// Cell result.
pub struct CellResult {
    pub cell: Cell,
    pub metrics: Metrics,
}

/// Run all cells, fanning out over up to `threads` OS threads.
pub fn run_cells(cells: Vec<Cell>, threads: usize) -> Vec<CellResult> {
    let n = cells.len();
    let slots: Vec<OnceLock<Metrics>> = (0..n).map(|_| OnceLock::new()).collect();
    let next = AtomicUsize::new(0);
    let cells_ref = &cells;

    std::thread::scope(|s| {
        for _ in 0..threads.max(1).min(n.max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let cell = &cells_ref[i];
                // Scale is per-cell here, so wrap it in a per-cell Runner
                // and reuse the orchestrator's single execution path.
                // Ad-hoc sweeps run the full trace (cap 0).
                let r = Runner { scale: cell.scale, max_accesses: 0, threads: 1 };
                let spec = CellSpec::new(&cell.workload, cell.scheme, cell.cfg.clone());
                let mut ms = run_cell_spec(&r, TraceCache::global(), &spec);
                let m = ms.pop().expect("single-machine cell yields one metrics");
                let _ = slots[i].set(m);
            });
        }
    });
    cells
        .into_iter()
        .zip(slots)
        .map(|(cell, s)| CellResult {
            cell,
            metrics: s.into_inner().expect("sweep slot left unfilled"),
        })
        .collect()
}

/// Default thread pool: physical parallelism minus a little headroom.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial() {
        let cfg = SimConfig::default().with_seed(3);
        let mk = |scheme| Cell {
            workload: "bf".to_string(),
            scheme,
            cfg: cfg.clone(),
            scale: Scale::Test,
        };
        let cells = vec![mk(SchemeKind::Remote), mk(SchemeKind::Daemon)];
        let par = run_cells(cells.clone(), 2);
        let ser = run_cells(cells, 1);
        for (a, b) in par.iter().zip(ser.iter()) {
            assert_eq!(a.metrics.instructions, b.metrics.instructions);
            assert!((a.metrics.cycles - b.metrics.cycles).abs() < 1e-6);
        }
    }

    #[test]
    fn results_keep_cell_order() {
        let cfg = SimConfig::default();
        let cells: Vec<Cell> = ["pr", "bf"]
            .iter()
            .map(|w| Cell {
                workload: w.to_string(),
                scheme: SchemeKind::Remote,
                cfg: cfg.clone(),
                scale: Scale::Test,
            })
            .collect();
        let rs = run_cells(cells, 4);
        assert_eq!(rs[0].cell.workload, "pr");
        assert_eq!(rs[1].cell.workload, "bf");
    }

    #[test]
    fn sweep_matches_run_workload_path() {
        use crate::system::machine::run_workload;
        use crate::workloads::by_name;
        let cfg = SimConfig::default().with_seed(11);
        let cells = vec![Cell {
            workload: "pr".to_string(),
            scheme: SchemeKind::Daemon,
            cfg: cfg.clone(),
            scale: Scale::Test,
        }];
        let swept = run_cells(cells, 1);
        let w = by_name("pr").unwrap();
        let direct = run_workload(&cfg, SchemeKind::Daemon, w.as_ref(), Scale::Test);
        assert_eq!(swept[0].metrics.instructions, direct.metrics.instructions);
        assert!((swept[0].metrics.cycles - direct.metrics.cycles).abs() < 1e-6);
    }
}
