//! Parallel experiment sweeps: run (workload x scheme x config) cells
//! across OS threads with `std::thread::scope` (the offline registry has
//! no rayon; a scoped fan-out is all a deterministic simulator needs).

use crate::config::SimConfig;
use crate::metrics::Metrics;
use crate::schemes::SchemeKind;
use crate::system::machine::run_workload;
use crate::workloads::{by_name, Scale};

/// One sweep cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub workload: String,
    pub scheme: SchemeKind,
    pub cfg: SimConfig,
    pub scale: Scale,
}

/// Cell result.
pub struct CellResult {
    pub cell: Cell,
    pub metrics: Metrics,
}

/// Run all cells, fanning out over up to `threads` OS threads.
pub fn run_cells(cells: Vec<Cell>, threads: usize) -> Vec<CellResult> {
    let threads = threads.max(1);
    let n = cells.len();
    let mut results: Vec<Option<CellResult>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let cells_ref = &cells;
    let results_mutex = std::sync::Mutex::new(&mut results);

    std::thread::scope(|s| {
        for _ in 0..threads.min(n.max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let cell = cells_ref[i].clone();
                let w = by_name(&cell.workload)
                    .unwrap_or_else(|| panic!("unknown workload {}", cell.workload));
                let r = run_workload(&cell.cfg, cell.scheme, w.as_ref(), cell.scale);
                let out = CellResult { cell, metrics: r.metrics };
                results_mutex.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Default thread pool: physical parallelism minus a little headroom.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial() {
        let cfg = SimConfig::default().with_seed(3);
        let mk = |scheme| Cell {
            workload: "bf".to_string(),
            scheme,
            cfg: cfg.clone(),
            scale: Scale::Test,
        };
        let cells = vec![mk(SchemeKind::Remote), mk(SchemeKind::Daemon)];
        let par = run_cells(cells.clone(), 2);
        let ser = run_cells(cells, 1);
        for (a, b) in par.iter().zip(ser.iter()) {
            assert_eq!(a.metrics.instructions, b.metrics.instructions);
            assert!((a.metrics.cycles - b.metrics.cycles).abs() < 1e-6);
        }
    }

    #[test]
    fn results_keep_cell_order() {
        let cfg = SimConfig::default();
        let cells: Vec<Cell> = ["pr", "bf"]
            .iter()
            .map(|w| Cell {
                workload: w.to_string(),
                scheme: SchemeKind::Remote,
                cfg: cfg.clone(),
                scale: Scale::Test,
            })
            .collect();
        let rs = run_cells(cells, 4);
        assert_eq!(rs[0].cell.workload, "pr");
        assert_eq!(rs[1].cell.workload, "bf");
    }
}
