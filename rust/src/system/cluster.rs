//! Multi-tenant cluster driver: C independent tenants — each with its own
//! trace, content profile, scheme, cores, caches, local memory and
//! compute engine — time-sliced over one shared [`RemoteMemory`] (the
//! switched fabric plus the per-module memory-side engines).  This is the
//! "pools of processors ... interconnected to pools of memory" scenario
//! of §6.7 and the prerequisite for every serving/QoS experiment.
//!
//! Sharing model: module bandwidth (fabric ports + DRAM bus queues) is
//! partitioned across tenants by weight under a [`SharingMode`].  The
//! default `Strict` mode is §4.1's reservation discipline applied to
//! tenants — a share is reserved even while its owner idles — which is
//! what yields QoS isolation and a well-defined per-tenant slowdown;
//! "contention" shows up as each tenant's reduced share, not as dynamic
//! interference.  `WorkConserving` trades some of that isolation for
//! throughput: capacity idle at request time (peer ports/queues, the
//! sibling class of a partitioned share) is redistributed by weight,
//! making the driver's global earliest-access ordering load-bearing —
//! the driver advances the tenant whose next access issues earliest
//! (global min over every tenant's cores; first tenant wins ties), so
//! interleaving, and therefore who borrows from whom, is deterministic.
//! A [`ScheduleSpec`](crate::config::ScheduleSpec) additionally applies
//! §6's time-varying bandwidth/latency conditions to every fabric port.
//! With a single tenant the cluster degenerates to exactly
//! `Machine::run` — pinned by the `single_tenant_cluster_matches_machine`
//! regression test.
//!
//! Failure isolation: a [`FaultPlan`](crate::system::fault::FaultPlan)
//! on the `ClusterConfig` installs
//! module-crash windows on the fabric ports and DRAM engines, link flaps
//! on individual ports, and tenant kills (the driver stops advancing a
//! killed tenant at its kill cycle).  The cluster's
//! [`RecoveryPolicy`](crate::system::fault::RecoveryPolicy) decides
//! whether tenants stall on a dead home module or re-fetch from a
//! surviving one.  Under strict sharing each tenant's resources are its
//! own, so tenants untouched by a fault reproduce their no-fault metrics
//! byte-identically (pinned by `tenant_kill_isolates_the_survivors`).

use crate::compress::synth::Profile;
use crate::config::{ClusterConfig, SimConfig, TenantShare};
use crate::daemon::EgressStats;
use crate::lifecycle::{Lifecycle, StateMachine, Transition};
use crate::metrics::Metrics;
use crate::net::NetSchedule;
use crate::obs::{Event, EventKind, ObsSpec, Recorder, Snapshot};
use crate::schemes::SchemeKind;
use crate::sim::MergeQueue;
use crate::system::controller::{Action, AdaptiveController};
use crate::system::machine::{Machine, RemoteMemory, SizeOracle};
use crate::workloads::Trace;
use std::sync::Arc;

/// Everything needed to instantiate one tenant.
pub struct TenantInit {
    /// Per-tenant knobs (cache sizes, cores, DaeMon parameters, seed).
    /// The `net` field is ignored — the cluster's fabric supplies links —
    /// and the shared-hardware fields (`dram_gbps`, `dram_latency_ns`,
    /// `interval_ns`) must agree across tenants (asserted): the memory
    /// modules are one physical pool.
    pub cfg: SimConfig,
    pub kind: SchemeKind,
    pub footprint_pages: usize,
    pub profiles: Vec<Profile>,
    pub oracle: Option<Box<dyn SizeOracle>>,
}

/// Tenant lifecycle (see DESIGN.md §"Lifecycles and state machines"):
/// a tenant is `Running` until its trace drains (`Finished`) or the
/// fault plan's kill cycle arrives first (`Killed`).  Both exits are
/// terminal — the driver never re-queues a terminal tenant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantState {
    Running,
    Killed,
    Finished,
}

/// Edge labels for the tenant machine: the fault plan's kill cycle
/// arriving first (`Kill`) or the trace draining (`Finish`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantEvent {
    Kill,
    Finish,
}

impl Lifecycle for TenantState {
    type Event = TenantEvent;
    const NAME: &'static str = "cluster tenant";
    const STATES: &'static [TenantState] =
        &[TenantState::Running, TenantState::Killed, TenantState::Finished];
    const EVENTS: &'static [TenantEvent] = &[TenantEvent::Kill, TenantEvent::Finish];
    const TABLE: &'static [Transition<TenantState, TenantEvent>] = &[
        Transition {
            from: TenantState::Running,
            event: TenantEvent::Kill,
            to: TenantState::Killed,
        },
        Transition {
            from: TenantState::Running,
            event: TenantEvent::Finish,
            to: TenantState::Finished,
        },
    ];

    fn state_name(self) -> &'static str {
        match self {
            TenantState::Running => "Running",
            TenantState::Killed => "Killed",
            TenantState::Finished => "Finished",
        }
    }

    fn event_name(event: TenantEvent) -> &'static str {
        match event {
            TenantEvent::Kill => "Kill",
            TenantEvent::Finish => "Finish",
        }
    }
}

pub struct Cluster {
    tenants: Vec<Machine>,
    remote: RemoteMemory,
    /// Per-tenant kill cycle from the fault plan (`f64::INFINITY` when a
    /// tenant is never killed): the driver issues no access at or after
    /// a tenant's kill cycle.
    kills: Vec<f64>,
    /// Per-tenant lifecycle machine, driven by [`Cluster::run`] as
    /// tenants leave the merge queue.  Every retirement flows through
    /// [`StateMachine::transition_with`], so terminal-never-reverts is
    /// structural rather than asserted at each call site.
    states: Vec<StateMachine<TenantState>>,
    /// Closed-loop feedback controller (`None` for every static
    /// configuration and for inert [`ControllerSpec`]s — inert specs
    /// never construct a controller, so static runs take the exact
    /// historical code path, byte for byte).
    ///
    /// [`ControllerSpec`]: crate::config::ControllerSpec
    controller: Option<AdaptiveController>,
}

impl Cluster {
    /// Build a cluster: one tenant [`Machine`] per init over one shared
    /// [`RemoteMemory`] sized by `ccfg`, with the fault plan (if any)
    /// materialized onto the fabric ports and DRAM engines.
    pub fn new(ccfg: &ClusterConfig, inits: Vec<TenantInit>) -> Cluster {
        assert!(!inits.is_empty(), "cluster needs at least one tenant");
        assert!(
            ccfg.weights.is_empty() || ccfg.weights.len() == inits.len(),
            "ClusterConfig carries {} weights for {} tenants",
            ccfg.weights.len(),
            inits.len()
        );
        let shares: Vec<TenantShare> = inits
            .iter()
            .enumerate()
            .map(|(i, t)| TenantShare {
                weight: ccfg.weights.get(i).copied().unwrap_or(1.0),
                partitioned: t.kind.policy().partitioned,
                line_ratio: t.cfg.daemon.partition_ratio,
            })
            .collect();
        let base = &inits[0].cfg;
        for t in &inits[1..] {
            assert!(
                t.cfg.dram_gbps == base.dram_gbps
                    && t.cfg.dram_latency_ns == base.dram_latency_ns
                    && t.cfg.interval_ns == base.interval_ns,
                "tenants must agree on the shared memory-hardware parameters \
                 (dram_gbps / dram_latency_ns / interval_ns)"
            );
        }
        let mut remote = RemoteMemory::new(
            &ccfg.nets(),
            base.dram_gbps,
            base.dram_latency_ns,
            &shares,
            ccfg.fabric_hop_ns,
            base.interval_ns,
            ccfg.sharing,
        );
        if let Some(spec) = &ccfg.schedule {
            let sched = Arc::new(NetSchedule::from_spec(spec));
            remote.fabric.set_schedule(|_, _| Some(sched.clone()));
        }
        if let Err(e) = ccfg.validate() {
            panic!("{e}");
        }
        if let Some(plan) = &ccfg.faults {
            plan.validate(ccfg.memory_modules.max(1), inits.len());
            remote.fabric.set_faults(plan);
            for (m, e) in remote.engines.iter_mut().enumerate() {
                e.set_faults(plan.module_timeline(m));
            }
        }
        let kills: Vec<f64> = (0..inits.len())
            .map(|t| ccfg.faults.as_ref().map_or(f64::INFINITY, |p| p.kill_time(t)))
            .collect();
        let tenants: Vec<Machine> = inits
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let mut m =
                    Machine::tenant(i, t.cfg, t.kind, t.footprint_pages, t.profiles, t.oracle);
                m.set_recovery(ccfg.recovery);
                m
            })
            .collect();
        let states = vec![StateMachine::new(TenantState::Running); tenants.len()];
        let controller = ccfg
            .controller
            .filter(|s| !s.is_inert())
            .map(|spec| AdaptiveController::new(spec, ccfg.sharing, &shares));
        Cluster { tenants, remote, kills, states, controller }
    }

    /// Number of tenants in the cluster.
    pub fn tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Lifecycle state of tenant `t` (`Running` until [`Cluster::run`]
    /// retires it).
    pub fn tenant_state(&self, t: usize) -> TenantState {
        self.states[t].state()
    }

    /// Attach an observability recorder to tenant `t` (before `run`).
    pub fn set_obs(&mut self, t: usize, rec: Recorder) {
        self.tenants[t].set_obs(rec);
    }

    /// Detach tenant `t`'s recorder (after `run`), if one was attached.
    pub fn take_obs(&mut self, t: usize) -> Option<Recorder> {
        self.tenants[t].take_obs()
    }

    /// Retire tenant `t` by driving its lifecycle machine.  The declared
    /// table has exactly two edges — Running −Kill→ Killed and
    /// Running −Finish→ Finished — so retiring a terminal tenant panics
    /// inside [`StateMachine::transition`] rather than silently
    /// reverting.  A kill also emits the `TenantKill` observability
    /// event (stamped with the tenant's kill cycle) from the transition
    /// hook, keeping the event tied to the state change itself.
    fn retire(&mut self, t: usize, event: TenantEvent) {
        let at = self.kills[t];
        let tenant = &mut self.tenants[t];
        self.states[t].transition_with(event, |_, _, to| {
            if to == TenantState::Killed {
                if let Some(rec) = tenant.obs_mut() {
                    rec.event(Event::instant(EventKind::TenantKill, t, None, 0, at));
                }
            }
        });
    }

    /// One closed-loop control step, fired when the driver's global
    /// clock crosses an observation-epoch boundary: sample every
    /// tenant's observation vector (the same [`Machine::observe`] the
    /// telemetry recorder uses), let the controller plan, apply the
    /// bounded actions.  Uses take/put-back on the controller so the
    /// tenant observations can borrow `self` freely.
    fn control_epoch(&mut self, now: f64) {
        let Some(mut ctl) = self.controller.take() else { return };
        if let Some(cycle) = ctl.epoch_crossed(now) {
            let obs: Vec<Snapshot> = self
                .tenants
                .iter()
                .map(|t| t.observe(&self.remote, cycle))
                .collect();
            for action in ctl.plan(&obs) {
                self.apply_action(&action, cycle);
            }
        }
        self.controller = Some(ctl);
    }

    /// Apply one controller action to the live system.  Actuation is
    /// fabric-side only (partition ratios, capacity weights) plus the
    /// per-tenant recovery-policy switch; rate changes affect only
    /// future transfers, so mid-run retuning stays deterministic.
    fn apply_action(&mut self, action: &Action, at: f64) {
        match action {
            Action::SetRatio { tenant, ratio } => {
                self.remote.fabric.retune_tenant_ratio(*tenant, *ratio);
                self.actuated(*tenant, action.law(), at);
            }
            Action::SetRecovery { tenant, policy } => {
                self.tenants[*tenant].set_recovery(*policy);
                self.actuated(*tenant, action.law(), at);
            }
            Action::SetWeights { weights } => {
                self.remote.fabric.retune_weights(weights);
                for t in 0..self.tenants.len() {
                    self.actuated(t, action.law(), at);
                }
            }
        }
    }

    /// Book one actuation against tenant `t`: bump its metrics counter
    /// and emit the `Actuate` observability event when a recorder is
    /// attached (the event's `detail` names the control law).
    fn actuated(&mut self, t: usize, law: &'static str, at: f64) {
        self.tenants[t].metrics.controller_actuations += 1;
        if let Some(rec) = self.tenants[t].obs_mut() {
            let mut ev = Event::instant(EventKind::Actuate, t, None, 0, at);
            ev.detail = Some(law);
            rec.event(ev);
        }
    }

    /// Run every tenant to completion over the shared fabric; one trace
    /// list per tenant (a tenant's cores cycle over its list exactly as
    /// in `Machine::run`).  Returns per-tenant metrics in tenant order.
    pub fn run(&mut self, traces: &[Vec<Arc<Trace>>]) -> Vec<Metrics> {
        assert_eq!(traces.len(), self.tenants.len(), "one trace list per tenant");
        // Under the recovery-switch law every tenant starts on Refetch
        // (the only reactive-safe initial state — see the controller
        // docs); the law relaxes it to Stall after a clean dwell.
        if let Some(p) = self.controller.as_ref().and_then(|c| c.initial_recovery()) {
            for t in self.tenants.iter_mut() {
                t.set_recovery(p);
            }
        }
        for (t, tr) in self.tenants.iter_mut().zip(traces) {
            t.prepare(tr);
        }
        // K-way merge over tenant clocks: one `(next issue time, tenant)`
        // entry per live tenant, min on time with ties to the lowest
        // tenant index — the exact order the seed driver's per-step
        // rescan of every tenant produced, in O(log tenants) per access.
        // Only the stepped tenant's clock moves, so entries never go
        // stale; a tenant is dropped (not re-pushed) once its trace
        // drains or its next issue would be at/after its kill cycle —
        // clocks are monotone, so neither condition can reverse.
        self.states = vec![StateMachine::new(TenantState::Running); self.tenants.len()];
        let mut q = MergeQueue::with_capacity(self.tenants.len());
        for i in 0..self.tenants.len() {
            match self.tenants[i].peek(&traces[i]) {
                Some((_, at)) if at < self.kills[i] => q.push(at, i),
                Some(_) => self.retire(i, TenantEvent::Kill),
                None => self.retire(i, TenantEvent::Finish),
            }
        }
        while let Some((i, at)) = q.pop() {
            // Pop times are globally non-decreasing (min-queue; only the
            // popped tenant's clock advances), so crossing an epoch here
            // fires the controller exactly once per boundary, at a
            // deterministic point in the access order.
            self.control_epoch(at);
            let (ci, _) = self.tenants[i]
                .peek(&traces[i])
                .expect("queued tenant must have work left");
            self.tenants[i].step_core(&mut self.remote, &traces[i], ci);
            match self.tenants[i].peek(&traces[i]) {
                Some((_, at)) if at < self.kills[i] => q.push(at, i),
                Some(_) => self.retire(i, TenantEvent::Kill),
                None => self.retire(i, TenantEvent::Finish),
            }
        }
        for t in self.tenants.iter_mut() {
            t.finish(&mut self.remote);
        }
        self.tenants.iter().map(|t| t.metrics.clone()).collect()
    }

    /// Split borrow for the request front-end
    /// ([`crate::system::frontend`]): tenant `t`'s machine and the
    /// shared remote memory, mutable at once — what `step_core` /
    /// `finish` need when a driver other than [`Cluster::run`] owns the
    /// event order.
    pub(crate) fn tenant_remote(&mut self, t: usize) -> (&mut Machine, &mut RemoteMemory) {
        (&mut self.tenants[t], &mut self.remote)
    }

    /// Finalize every tenant (drain + aggregate metrics) and return the
    /// per-tenant metrics in tenant order — the front-end's replacement
    /// for the tail of [`Cluster::run`].
    pub(crate) fn finish_all(&mut self) -> Vec<Metrics> {
        for t in self.tenants.iter_mut() {
            t.finish(&mut self.remote);
        }
        self.tenants.iter().map(|t| t.metrics.clone()).collect()
    }

    /// Memory-side link-compression stats for tenant `t`, aggregated over
    /// all memory modules.
    pub fn egress_stats(&self, t: usize) -> EgressStats {
        let mut total = EgressStats::default();
        for e in &self.remote.engines {
            total.merge(e.egress_stats(t));
        }
        total
    }
}

/// Build and run a cluster cell: one `(workload, scheme)` pair per tenant,
/// every tenant sharing `base_cfg`'s per-tenant knobs; `fetch` resolves a
/// workload name to its (cached) trace + content profile.  Returns
/// per-tenant metrics — the orchestrator's cluster-cell execution path.
pub fn run_cluster(
    ccfg: &ClusterConfig,
    base_cfg: &SimConfig,
    tenants: &[(String, SchemeKind)],
    fetch: impl Fn(&str) -> (Arc<Trace>, Profile),
) -> Vec<Metrics> {
    run_cluster_obs(ccfg, base_cfg, tenants, fetch, None).0
}

/// [`run_cluster`] with optional observability: when `obs` is set, every
/// tenant gets its own recorder, returned alongside the metrics in
/// tenant order (empty when `obs` is `None`).
pub fn run_cluster_obs(
    ccfg: &ClusterConfig,
    base_cfg: &SimConfig,
    tenants: &[(String, SchemeKind)],
    fetch: impl Fn(&str) -> (Arc<Trace>, Profile),
    obs: Option<&ObsSpec>,
) -> (Vec<Metrics>, Vec<Recorder>) {
    let mut inits = Vec::new();
    let mut traces = Vec::new();
    for (wl, kind) in tenants {
        let (trace, profile) = fetch(wl);
        inits.push(TenantInit {
            cfg: base_cfg.clone(),
            kind: *kind,
            footprint_pages: trace.footprint_pages,
            profiles: vec![profile; base_cfg.cores.max(1)],
            oracle: None,
        });
        traces.push(vec![trace]);
    }
    let mut cluster = Cluster::new(ccfg, inits);
    if let Some(spec) = obs {
        for t in 0..cluster.tenants() {
            cluster.set_obs(t, Recorder::new(*spec));
        }
    }
    let metrics = cluster.run(&traces);
    let recorders =
        (0..cluster.tenants()).filter_map(|t| cluster.take_obs(t)).collect();
    (metrics, recorders)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, SharingMode};
    use crate::workloads::{by_name, Scale};

    fn fetch_test(wl: &str, seed: u64) -> (Arc<Trace>, Profile) {
        let w = by_name(wl).unwrap();
        (Arc::new(w.generate(seed, Scale::Test)), w.profile())
    }

    #[test]
    fn single_tenant_cluster_matches_machine() {
        // Acceptance criterion: a 1-tenant cluster over M modules must
        // reproduce the existing Machine metrics for the same cell.
        let net = NetConfig::new(100.0, 4.0);
        for kind in [SchemeKind::Daemon, SchemeKind::Remote] {
            let cfg = SimConfig::test_scale();
            let (trace, profile) = fetch_test("pr", cfg.seed);
            let mut machine = Machine::new(
                cfg.clone().with_memory_components(vec![net; 2]),
                kind,
                trace.footprint_pages,
                vec![profile],
                None,
            );
            machine.run(std::slice::from_ref(&*trace));

            let ccfg = ClusterConfig::new(2).with_net(100.0, 4.0);
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg,
                    kind,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            let ms = cluster.run(&[vec![trace.clone()]]);
            assert_eq!(
                ms[0].to_json().to_string(),
                machine.metrics.to_json().to_string(),
                "{kind:?}: single-tenant cluster diverged from Machine"
            );
        }
    }

    #[test]
    fn tenants_slow_down_under_contention() {
        // 2 tenants on 1 module each get half the bandwidth: both finish
        // later than solo, instructions are preserved per tenant.
        let ccfg = ClusterConfig::new(1);
        let cfg = SimConfig::test_scale();
        let mk = |n: usize| {
            (0..n)
                .map(|_| {
                    let (trace, profile) = fetch_test("pr", cfg.seed);
                    (
                        TenantInit {
                            cfg: cfg.clone(),
                            kind: SchemeKind::Remote,
                            footprint_pages: trace.footprint_pages,
                            profiles: vec![profile],
                            oracle: None,
                        },
                        vec![trace],
                    )
                })
                .unzip::<_, _, Vec<_>, Vec<_>>()
        };
        let (solo_init, solo_traces) = mk(1);
        let solo = Cluster::new(&ccfg, solo_init).run(&solo_traces);
        let (shared_init, shared_traces) = mk(2);
        let shared = Cluster::new(&ccfg, shared_init).run(&shared_traces);
        assert_eq!(shared.len(), 2);
        for m in &shared {
            assert_eq!(m.instructions, solo[0].instructions);
            assert!(
                m.cycles > solo[0].cycles,
                "half-bandwidth tenant not slower: {} vs {}",
                m.cycles,
                solo[0].cycles
            );
        }
    }

    #[test]
    fn cluster_reports_memory_side_compression() {
        let ccfg = ClusterConfig::new(1);
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("sp", cfg.seed);
        let mut cluster = Cluster::new(
            &ccfg,
            vec![TenantInit {
                cfg: cfg.clone(),
                kind: SchemeKind::Daemon,
                footprint_pages: trace.footprint_pages,
                profiles: vec![profile],
                oracle: None,
            }],
        );
        let ms = cluster.run(&[vec![trace]]);
        let stats = cluster.egress_stats(0);
        assert!(stats.raw_bytes > 0, "no egress recorded");
        assert!(
            stats.ratio() > 1.5,
            "memory-side compression ratio {}",
            stats.ratio()
        );
        assert!(ms[0].pages_moved > 0);
    }

    #[test]
    fn run_cluster_helper_runs_mixed_schemes() {
        let ccfg = ClusterConfig::new(2);
        let cfg = SimConfig::test_scale();
        let tenants = vec![
            ("pr".to_string(), SchemeKind::Daemon),
            ("sp".to_string(), SchemeKind::Remote),
        ];
        let ms = run_cluster(&ccfg, &cfg, &tenants, |wl| fetch_test(wl, cfg.seed));
        assert_eq!(ms.len(), 2);
        assert!(ms.iter().all(|m| m.instructions > 0));
    }

    #[test]
    fn work_conserving_single_tenant_matches_strict() {
        // With one (unpartitioned) tenant there is nothing to borrow, so
        // the work-conserving scheduler must be byte-identical to strict
        // — the regression pin that the sharing plumbing leaves the
        // historical strict path untouched.
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = |sharing: SharingMode| {
            let ccfg = ClusterConfig::new(2).with_sharing(sharing);
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Remote,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0)
        };
        let strict = run(SharingMode::Strict);
        let wc = run(SharingMode::WorkConserving);
        assert_eq!(
            strict.to_json().to_string(),
            wc.to_json().to_string(),
            "work-conserving with no idle peers diverged from strict"
        );
        assert_eq!(wc.reclaimed_bytes, 0);
    }

    #[test]
    fn work_conserving_raises_aggregate_goodput() {
        // Acceptance criterion: in the contention cell (4 tenants x 2
        // shared modules) work-conserving sharing must strictly beat
        // strict sharing on aggregate goodput — idle capacity (tenants
        // finishing early, bursty gaps) is reclaimed instead of burned.
        let cfg = SimConfig::test_scale();
        let tenants: Vec<(String, SchemeKind)> = ["pr", "nw", "sp", "hp"]
            .iter()
            .map(|w| (w.to_string(), SchemeKind::Remote))
            .collect();
        let run = |sharing: SharingMode| {
            let ccfg = ClusterConfig::new(2).with_sharing(sharing);
            run_cluster(&ccfg, &cfg, &tenants, |wl| fetch_test(wl, cfg.seed))
        };
        let strict = run(SharingMode::Strict);
        let wc = run(SharingMode::WorkConserving);
        let agg = |ms: &[Metrics]| ms.iter().map(Metrics::goodput).sum::<f64>();
        assert!(
            agg(&wc) > agg(&strict),
            "work-conserving aggregate goodput {} !> strict {}",
            agg(&wc),
            agg(&strict)
        );
        assert!(strict.iter().all(|m| m.reclaimed_bytes == 0), "strict must never borrow");
        assert!(
            wc.iter().map(|m| m.reclaimed_bytes).sum::<u64>() > 0,
            "work-conserving run reclaimed nothing"
        );
        // Same work either way.
        for (s, w) in strict.iter().zip(&wc) {
            assert_eq!(s.instructions, w.instructions);
        }
    }

    #[test]
    fn degraded_schedule_slows_the_cluster() {
        use crate::config::ScheduleSpec;
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = |schedule: Option<ScheduleSpec>| {
            let mut ccfg = ClusterConfig::new(1);
            if let Some(s) = schedule {
                ccfg = ccfg.with_schedule(s);
            }
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Remote,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0)
        };
        let steady = run(None);
        // Quarter bandwidth + 200ns extra switch latency, everywhere,
        // for 1e12 cycles (the whole run).
        let degraded = run(Some(ScheduleSpec {
            period_cycles: 1e12,
            rate_scale: 0.25,
            extra_latency_ns: 200.0,
            horizon_cycles: 1e12,
        }));
        assert_eq!(steady.instructions, degraded.instructions);
        assert!(
            degraded.cycles > steady.cycles,
            "degraded link conditions must cost cycles: {} vs {}",
            degraded.cycles,
            steady.cycles
        );
    }

    #[test]
    fn empty_fault_plan_is_byte_identical_to_no_faults() {
        // The no-fault pin: an installed-but-empty plan (and the refetch
        // policy with nothing down) must take the exact historical code
        // path, byte for byte.
        use crate::system::fault::{FaultPlan, RecoveryPolicy};
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = |ccfg: ClusterConfig| {
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Daemon,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0).to_json().to_string()
        };
        let clean = run(ClusterConfig::new(2));
        let faultless = run(
            ClusterConfig::new(2)
                .with_faults(FaultPlan::new())
                .with_recovery(RecoveryPolicy::Refetch),
        );
        assert_eq!(clean, faultless, "empty fault plan diverged from the no-fault path");
    }

    #[test]
    fn tenant_kill_isolates_the_survivors() {
        use crate::system::fault::FaultPlan;
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let mk_init = || TenantInit {
            cfg: cfg.clone(),
            kind: SchemeKind::Remote,
            footprint_pages: trace.footprint_pages,
            profiles: vec![profile],
            oracle: None,
        };
        let traces = vec![vec![trace.clone()], vec![trace.clone()]];
        let base = Cluster::new(&ClusterConfig::new(1), vec![mk_init(), mk_init()])
            .run(&traces);
        let ccfg = ClusterConfig::new(1).with_faults(FaultPlan::new().tenant_kill(1, 1e5));
        let killed = Cluster::new(&ccfg, vec![mk_init(), mk_init()]).run(&traces);
        // The killed tenant stops mid-run but had committed work.
        assert!(
            killed[1].instructions < base[1].instructions,
            "kill at 1e5 cycles must truncate the run: {} vs {}",
            killed[1].instructions,
            base[1].instructions
        );
        assert!(killed[1].instructions > 0, "kill is not at time zero");
        // Failure isolation under strict sharing: the surviving tenant's
        // metrics are byte-identical to the no-fault run.
        assert_eq!(
            killed[0].to_json().to_string(),
            base[0].to_json().to_string(),
            "survivor perturbed by a peer tenant's death"
        );
    }

    #[test]
    fn tenant_lifecycle_states_track_kills_and_completion() {
        use crate::system::fault::FaultPlan;
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let mk_init = || TenantInit {
            cfg: cfg.clone(),
            kind: SchemeKind::Remote,
            footprint_pages: trace.footprint_pages,
            profiles: vec![profile],
            oracle: None,
        };
        let traces = vec![vec![trace.clone()], vec![trace.clone()]];
        let ccfg = ClusterConfig::new(1).with_faults(FaultPlan::new().tenant_kill(1, 1e5));
        let mut cluster = Cluster::new(&ccfg, vec![mk_init(), mk_init()]);
        assert_eq!(cluster.tenant_state(0), TenantState::Running);
        assert_eq!(cluster.tenant_state(1), TenantState::Running);
        cluster.run(&traces);
        assert_eq!(cluster.tenant_state(0), TenantState::Finished, "survivor drains");
        assert_eq!(cluster.tenant_state(1), TenantState::Killed, "victim retired at 1e5");
    }

    #[test]
    #[should_panic(expected = "requires SharingMode::Strict")]
    fn cluster_rejects_faults_under_work_conserving_sharing() {
        use crate::system::fault::FaultPlan;
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let ccfg = ClusterConfig::new(1)
            .with_sharing(SharingMode::WorkConserving)
            .with_faults(FaultPlan::new().module_crash(0, 0.0, 10.0));
        let _ = Cluster::new(
            &ccfg,
            vec![TenantInit {
                cfg,
                kind: SchemeKind::Remote,
                footprint_pages: trace.footprint_pages,
                profiles: vec![profile],
                oracle: None,
            }],
        );
    }

    #[test]
    fn inert_controller_specs_run_the_historical_path() {
        // The no-op-controller pin at the unit level: epoch 0 and
        // all-laws-off specs never construct a controller, so the run is
        // byte-identical to the same config with no controller at all.
        use crate::config::ControllerSpec;
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = |ccfg: ClusterConfig| {
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Daemon,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0).to_json().to_string()
        };
        let baseline = run(ClusterConfig::new(2));
        let zero_epoch =
            run(ClusterConfig::new(2).with_controller(ControllerSpec::all(0.0)));
        let laws_off = run(ClusterConfig::new(2).with_controller(ControllerSpec {
            epoch_cycles: 25_000.0,
            tune_ratio: false,
            switch_recovery: false,
            rebalance_shares: false,
        }));
        assert_eq!(baseline, zero_epoch, "epoch-0 controller perturbed the run");
        assert_eq!(baseline, laws_off, "all-laws-off controller perturbed the run");
    }

    #[test]
    fn closed_loop_controller_actuates_under_degraded_conditions() {
        // A live controller over a persistently degraded schedule must
        // observe distress and actuate (ratio-tune steps the daemon
        // tenant's partition toward the law max), booking the actuations
        // in the metrics counter; work is preserved either way.
        use crate::config::{ControllerSpec, ScheduleSpec};
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = |ctl: Option<ControllerSpec>| {
            let mut ccfg = ClusterConfig::new(2).with_schedule(ScheduleSpec {
                period_cycles: 1e12,
                rate_scale: 0.25,
                extra_latency_ns: 0.0,
                horizon_cycles: 1e12,
            });
            if let Some(s) = ctl {
                ccfg = ccfg.with_controller(s);
            }
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Daemon,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0)
        };
        let fixed = run(None);
        let closed = run(Some(ControllerSpec::all(25_000.0)));
        assert_eq!(fixed.instructions, closed.instructions, "same work either way");
        assert_eq!(fixed.controller_actuations, 0, "static runs never actuate");
        assert!(
            closed.controller_actuations >= 2,
            "persistent distress must drive at least the two ratio-tune steps \
             toward the law max, got {}",
            closed.controller_actuations
        );
    }

    #[test]
    fn closed_loop_runs_repeat_byte_identically() {
        use crate::config::{ControllerSpec, ScheduleSpec};
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let run = || {
            let ccfg = ClusterConfig::new(2)
                .with_schedule(ScheduleSpec {
                    period_cycles: 2e5,
                    rate_scale: 0.25,
                    extra_latency_ns: 0.0,
                    horizon_cycles: 1e12,
                })
                .with_controller(ControllerSpec::all(25_000.0));
            let mut cluster = Cluster::new(
                &ccfg,
                vec![TenantInit {
                    cfg: cfg.clone(),
                    kind: SchemeKind::Daemon,
                    footprint_pages: trace.footprint_pages,
                    profiles: vec![profile],
                    oracle: None,
                }],
            );
            cluster.run(&[vec![trace.clone()]]).remove(0).to_json().to_string()
        };
        assert_eq!(run(), run(), "closed-loop run is not deterministic");
    }

    #[test]
    #[should_panic(expected = "weights for")]
    fn cluster_rejects_mismatched_weights() {
        let ccfg = ClusterConfig::new(1).with_weights(vec![1.0, 2.0]);
        let cfg = SimConfig::test_scale();
        let (trace, profile) = fetch_test("pr", cfg.seed);
        let _ = Cluster::new(
            &ccfg,
            vec![TenantInit {
                cfg,
                kind: SchemeKind::Remote,
                footprint_pages: trace.footprint_pages,
                profiles: vec![profile],
                oracle: None,
            }],
        );
    }
}
