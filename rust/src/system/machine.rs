//! The disaggregated machine: cores + cache hierarchy + local memory on
//! the compute component, a switched fabric to one or more memory
//! modules (each with a memory-side engine), and the DaeMon compute
//! engine — driven by workload traces under a data-movement scheme.
//!
//! Timing model: resource timelines (bandwidth channels) + an arrival
//! event queue + an interval-style OoO core (gap instructions at base CPI;
//! long-latency misses overlapped across a bounded MLP window).  This is
//! the same abstraction level as the paper's Sniper setup — IPC
//! differences between schemes arise only from memory stall cycles.
//!
//! A solo `Machine` owns its [`RemoteMemory`] (fabric + memory engines,
//! single tenant, zero fabric hop — timing-identical to the old
//! point-to-point links).  A [`crate::system::Cluster`] instead builds
//! one shared `RemoteMemory` for C tenants and drives each tenant
//! `Machine` through the public stepping API (`prepare` / `peek` /
//! `step_core` / `finish`) in global earliest-access order — the
//! ordering that keeps cluster results well-defined independent of
//! tenant count (and becomes load-bearing once the fabric gains
//! work-conserving sharing modes on top of today's strict shares).

use crate::compress::{synth::Profile, Compressor};
use crate::config::{
    ns_to_cycles, NetConfig, SharingMode, SimConfig, TenantShare, CORE_GHZ, LINE_BYTES,
    PAGE_BYTES,
};
use crate::daemon::{ComputeEngine, DirtyOutcome, MemoryEngine, PageArrival};
use crate::mem::{Access as CacheAccess, Cache, DramBus, LocalMemory};
use crate::metrics::Metrics;
use crate::net::{Class, Disturbance, Fabric, ScheduleHandle};
use crate::obs::{Event, EventKind, ModuleSample, Recorder, Snapshot};
use crate::schemes::{Policy, SchemeKind};
use crate::sim::{EventQueue, MergeQueue};
use crate::system::fault::RecoveryPolicy;
use crate::workloads::{Scale, Trace, Workload};

/// Oracle for compressed page sizes — `Exact` (native algorithms) or the
/// PJRT-backed estimator from `runtime`.
pub trait SizeOracle {
    fn page_size(&mut self, core: usize, page: u64) -> u32;
    /// Achieved ratio so far (raw/compressed).
    fn ratio(&self) -> f64;
}

/// Exact oracle: one memoizing [`Compressor`] per core (each job has its
/// own content profile).
pub struct ExactOracle {
    comps: Vec<Compressor>,
}

impl ExactOracle {
    pub fn new(seed: u64, profiles: &[Profile], algo: crate::compress::Algo) -> Self {
        // Guard the `comps.len() - 1` in `page_size`: an empty profile
        // list would underflow there with an opaque panic.
        assert!(
            !profiles.is_empty(),
            "ExactOracle requires at least one content profile"
        );
        Self {
            comps: profiles
                .iter()
                .enumerate()
                .map(|(i, p)| Compressor::new(seed ^ (i as u64) << 32, *p, algo))
                .collect(),
        }
    }
}

impl SizeOracle for ExactOracle {
    fn page_size(&mut self, core: usize, page: u64) -> u32 {
        // A core index past the profile list means the caller built the
        // oracle with fewer profiles than cores — surface the mismatch
        // instead of silently reusing the last profile.
        debug_assert!(
            core < self.comps.len(),
            "core {core} has no content profile ({} configured)",
            self.comps.len()
        );
        let i = core.min(self.comps.len() - 1);
        self.comps[i].size_of(page)
    }

    fn ratio(&self) -> f64 {
        let raw: u64 = self.comps.iter().map(|c| c.raw_bytes).sum();
        let cmp: u64 = self.comps.iter().map(|c| c.compressed_bytes).sum();
        if cmp == 0 {
            1.0
        } else {
            raw as f64 / cmp as f64
        }
    }
}

/// The shared remote-memory subsystem: the switched [`Fabric`] plus one
/// memory-side [`MemoryEngine`] per module.
pub struct RemoteMemory {
    pub fabric: Fabric,
    pub engines: Vec<MemoryEngine>,
}

impl RemoteMemory {
    pub fn new(
        nets: &[NetConfig],
        dram_gbps: f64,
        dram_latency_ns: f64,
        shares: &[TenantShare],
        hop_ns: f64,
        interval_ns: f64,
        sharing: SharingMode,
    ) -> RemoteMemory {
        let interval = ns_to_cycles(interval_ns);
        let fabric =
            Fabric::new(nets, dram_gbps, shares, ns_to_cycles(hop_ns), interval, sharing);
        let engines = nets
            .iter()
            .map(|_| {
                MemoryEngine::new(
                    dram_gbps / CORE_GHZ,
                    ns_to_cycles(dram_latency_ns),
                    shares,
                    interval,
                    sharing,
                )
            })
            .collect();
        RemoteMemory { fabric, engines }
    }

    /// The single-tenant subsystem a solo [`Machine`] owns.  Always
    /// strict: with one tenant there are no peers to reclaim from, and
    /// §4.1's class partitions stay the reservation the paper specifies.
    pub fn for_config(cfg: &SimConfig, policy: Policy) -> RemoteMemory {
        let share = TenantShare {
            weight: 1.0,
            partitioned: policy.partitioned,
            line_ratio: cfg.daemon.partition_ratio,
        };
        RemoteMemory::new(
            &cfg.net,
            cfg.dram_gbps,
            cfg.dram_latency_ns,
            &[share],
            0.0,
            cfg.interval_ns,
            SharingMode::Strict,
        )
    }

    pub fn modules(&self) -> usize {
        self.engines.len()
    }
}

/// Arrival events applied as core time advances.
enum Arrival {
    Page { page: u64 },
    Line { page: u64, offset: u8, addr: u64 },
}

struct Core {
    time: f64,
    l1: Cache,
    l2: Cache,
    /// Completion times of outstanding long-latency misses (MLP window).
    outstanding: Vec<f64>,
    instructions: u64,
    /// Cursor into its trace.
    pos: usize,
}

pub struct Machine {
    cfg: SimConfig,
    policy: Policy,
    kind: SchemeKind,
    /// Tenant index on the shared fabric (0 for a solo machine).
    id: usize,
    /// Solo machines own their remote subsystem; cluster tenants get it
    /// passed into the stepping API instead.
    remote: Option<RemoteMemory>,
    cores: Vec<Core>,
    llc: Cache,
    local: LocalMemory,
    local_bus: DramBus,
    engine: ComputeEngine,
    arrivals: EventQueue<Arrival>,
    /// K-way merge over core clocks — one `(next issue time, core)` entry
    /// per core with trace left.  Built by [`Machine::prepare`]; while
    /// absent (or invalidated by an out-of-order [`Machine::step_core`])
    /// the driver falls back to the historical linear scan.
    run_queue: Option<MergeQueue>,
    oracle: Box<dyn SizeOracle>,
    pub metrics: Metrics,
    interval_cycles: f64,
    /// Per-core address-space tag shift.
    core_tag_shift: u32,
    /// Degraded-mode policy while a home module's port is down (only
    /// meaningful when the shared fabric carries a
    /// [`crate::system::fault::FaultPlan`]; default `Stall`).
    recovery: RecoveryPolicy,
    /// Observability recorder (telemetry epochs + event ring).  `None`
    /// — the default — is the exact historical code path: every hook is
    /// one `Option` check, and a recorder only ever *reads* simulation
    /// state (see `crate::obs`).
    obs: Option<Recorder>,
}

impl Machine {
    /// Build a solo machine for `traces` (one per core) with content
    /// `profiles` (one per core).
    pub fn new(
        cfg: SimConfig,
        kind: SchemeKind,
        footprint_pages: usize,
        profiles: Vec<Profile>,
        oracle: Option<Box<dyn SizeOracle>>,
    ) -> Machine {
        let remote = RemoteMemory::for_config(&cfg, kind.policy());
        Machine::build(0, Some(remote), cfg, kind, footprint_pages, profiles, oracle)
    }

    /// Build a tenant machine for a [`crate::system::Cluster`]: it shares
    /// an external [`RemoteMemory`] (passed into `step_next`/`finish`)
    /// instead of owning one, and `id` selects its fabric/engine ports.
    pub fn tenant(
        id: usize,
        cfg: SimConfig,
        kind: SchemeKind,
        footprint_pages: usize,
        profiles: Vec<Profile>,
        oracle: Option<Box<dyn SizeOracle>>,
    ) -> Machine {
        Machine::build(id, None, cfg, kind, footprint_pages, profiles, oracle)
    }

    fn build(
        id: usize,
        remote: Option<RemoteMemory>,
        cfg: SimConfig,
        kind: SchemeKind,
        footprint_pages: usize,
        profiles: Vec<Profile>,
        oracle: Option<Box<dyn SizeOracle>>,
    ) -> Machine {
        assert!(
            !profiles.is_empty(),
            "Machine::new requires at least one content profile (one per core)"
        );
        let policy = kind.policy();
        let interval_cycles = ns_to_cycles(cfg.interval_ns);
        let local_pages = if policy.local_only {
            footprint_pages + 1
        } else {
            ((footprint_pages as f64 * cfg.local_mem_fraction).ceil() as usize).max(1)
        };
        let algo = cfg.daemon.compress.unwrap_or(crate::compress::Algo::Lz);
        let oracle = oracle
            .unwrap_or_else(|| Box::new(ExactOracle::new(cfg.seed, &profiles, algo)));

        // Non-selection schemes get effectively unbounded inflight
        // buffers (they have no selection unit; dedup still applies).
        let mut dp = cfg.daemon;
        if !policy.selection {
            dp.inflight_page_buf = usize::MAX / 2;
            dp.inflight_subblock_buf = usize::MAX / 2;
            dp.dirty_data_buf = usize::MAX / 2;
            dp.dirty_flush_threshold = usize::MAX / 2;
        }

        let cores = (0..cfg.cores)
            .map(|_| Core {
                time: 0.0,
                l1: Cache::new(&cfg.l1d, LINE_BYTES),
                l2: Cache::new(&cfg.l2, LINE_BYTES),
                outstanding: Vec::with_capacity(cfg.core_mlp),
                instructions: 0,
                pos: 0,
            })
            .collect();

        Machine {
            llc: Cache::new(&cfg.llc, LINE_BYTES),
            local: LocalMemory::new(local_pages, cfg.replacement),
            local_bus: DramBus::shared(
                cfg.dram_bytes_per_cycle(),
                ns_to_cycles(cfg.dram_latency_ns),
                interval_cycles,
            ),
            engine: ComputeEngine::new(dp),
            arrivals: EventQueue::new(),
            run_queue: None,
            oracle,
            metrics: Metrics::new(),
            interval_cycles,
            core_tag_shift: 40,
            recovery: RecoveryPolicy::Stall,
            obs: None,
            cores,
            cfg,
            policy,
            kind,
            id,
            remote,
        }
    }

    /// Degraded-mode policy for remote accesses whose home module is
    /// down (a [`crate::system::Cluster`] sets this from its
    /// `ClusterConfig`); the default `Stall` leaves routing untouched.
    pub fn set_recovery(&mut self, policy: RecoveryPolicy) {
        self.recovery = policy;
    }

    /// Install network disturbance phases on every memory-module port
    /// (solo machines only; a cluster owns the shared fabric).
    pub fn set_disturbance(&mut self, mk: impl Fn(f64) -> Disturbance) {
        self.remote
            .as_mut()
            .expect("set_disturbance drives a solo machine's own fabric")
            .fabric
            .set_disturbance(mk);
    }

    /// Install time-varying link conditions on every memory-module port
    /// (solo machines only; a cluster owns the shared fabric).
    pub fn set_net_schedule(&mut self, mk: impl Fn(usize, usize) -> Option<ScheduleHandle>) {
        self.remote
            .as_mut()
            .expect("set_net_schedule drives a solo machine's own fabric")
            .fabric
            .set_schedule(mk);
    }

    /// Attach an observability recorder.  Attach before `prepare`/`run`;
    /// take it back with [`Machine::take_obs`] after `finish`.
    pub fn set_obs(&mut self, rec: Recorder) {
        self.obs = Some(rec);
    }

    /// Detach and return the recorder (with its telemetry and trace).
    pub fn take_obs(&mut self) -> Option<Recorder> {
        self.obs.take()
    }

    /// The attached recorder, if any (a cluster uses this to stamp
    /// tenant lifecycle events).
    pub fn obs_mut(&mut self) -> Option<&mut Recorder> {
        self.obs.as_mut()
    }

    #[inline]
    fn placement(&self, remote: &RemoteMemory, page: u64) -> usize {
        let n = remote.modules();
        if n == 1 {
            0
        } else if self.cfg.placement_round_robin {
            (page as usize) % n
        } else {
            // Multiplicative hash "random" placement.
            ((page.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % n
        }
    }

    /// Module serving `page` at `now`: the placement-home module, except
    /// under [`RecoveryPolicy::Refetch`] when that module's port is down
    /// — then the next surviving module serves the request (§4.6-style
    /// re-fetch from replicated data), falling back to the home module
    /// when every module is down.  Under the default `Stall` policy this
    /// is exactly [`Machine`]'s historical placement.
    ///
    /// Routing is decided at issue time — failure detection is not
    /// retroactive.  A request dispatched toward a module that fails
    /// between issue and service (or one the engine/fabric only reaches
    /// inside a window that opens after `now`) rides that resource's
    /// defer/abort semantics instead of re-routing, so Refetch can still
    /// report a few deferrals around a window's opening edge.
    #[inline]
    fn route(&self, remote: &RemoteMemory, page: u64, now: f64) -> usize {
        let home = self.placement(remote, page);
        crate::policy::recovery(self.recovery).route(home, remote.modules(), &|m| {
            remote.fabric.port_up(m, self.id, now)
        })
    }

    #[inline]
    fn page_of(addr: u64) -> u64 {
        addr >> 12
    }

    #[inline]
    fn offset_of(addr: u64) -> u8 {
        ((addr >> 6) & 63) as u8
    }

    /// Core owning an address (multi-workload address tagging).
    #[inline]
    fn owner_core(&self, page: u64) -> usize {
        ((page << 12) >> self.core_tag_shift) as usize % self.cores.len().max(1)
    }

    /// Schedule a page migration; returns its (start, arrival) cycles.
    fn schedule_page(&mut self, remote: &mut RemoteMemory, page: u64, now: f64) -> (f64, f64) {
        let compress = self.policy.compress;
        let owner = self.owner_core(page);
        let bytes = if compress {
            self.oracle.page_size(owner, page) as u64
        } else {
            PAGE_BYTES
        };
        let m = self.route(remote, page, now);
        remote.fabric.advance_disturbance(m, self.id, now);
        // Request propagation (control message) + HW translation + DRAM
        // page read at the memory module.
        let t0 = now + remote.fabric.request_latency(m);
        let t1 = remote.engines[m].access(self.id, t0, 8, Class::Page); // translation lookup
        let mut t2 = remote.engines[m].access(self.id, t1, PAGE_BYTES, Class::Page);
        if compress {
            t2 += self.cfg.daemon.compress_cycles; // MXT compression
        }
        // Link transfer (page class when partitioned) + switch latency.
        let t3 = remote.fabric.send_down(m, self.id, t2, bytes, Class::Page);
        remote.engines[m].note_egress(self.id, PAGE_BYTES, bytes);
        let mut t4 = t3;
        if compress {
            t4 += self.cfg.daemon.compress_cycles; // decompression
        }
        // Write into local memory through the local DRAM bus.
        let arrive = self.local_bus.access(t4, PAGE_BYTES, Class::Page);
        self.metrics.net_bytes_in += bytes;
        if let Some(rec) = self.obs.as_mut() {
            rec.event(Event::span(
                EventKind::PageMove,
                self.id,
                Some(m),
                page,
                bytes,
                now,
                arrive - now,
            ));
        }
        // Transfer enters link service at t2 (start of serialization).
        (t2, arrive)
    }

    /// Estimated arrival time of a line request issued now — the quantity
    /// the selection unit's queue-occupancy comparison approximates.
    fn line_eta(&self, remote: &RemoteMemory, page: u64, now: f64) -> f64 {
        let m = self.route(remote, page, now);
        let bus_rate = remote.engines[m].rate(self.id, Class::Line);
        let link_rate = remote.fabric.down_rate(m, self.id, Class::Line);
        now + 2.0 * remote.fabric.request_latency(m)
            + remote.engines[m].backlog(self.id, now, Class::Line)
            + 2.0 * remote.engines[m].latency_cycles(self.id)
            + (8.0 + LINE_BYTES as f64) / bus_rate
            + remote.fabric.down_backlog(m, self.id, now, Class::Line)
            + LINE_BYTES as f64 / link_rate
    }

    /// Schedule a cache-line movement; returns its arrival cycle.
    fn schedule_line(&mut self, remote: &mut RemoteMemory, addr: u64, now: f64) -> f64 {
        let page = Self::page_of(addr);
        let m = self.route(remote, page, now);
        remote.fabric.advance_disturbance(m, self.id, now);
        let t0 = now + remote.fabric.request_latency(m);
        let t1 = remote.engines[m].access(self.id, t0, 8, Class::Line); // translation
        let t2 = remote.engines[m].access(self.id, t1, LINE_BYTES, Class::Line);
        let t3 = remote.fabric.send_down(m, self.id, t2, LINE_BYTES, Class::Line);
        remote.engines[m].note_egress(self.id, LINE_BYTES, LINE_BYTES);
        self.metrics.net_bytes_in += LINE_BYTES;
        if let Some(rec) = self.obs.as_mut() {
            rec.event(Event::span(
                EventKind::LineFetch,
                self.id,
                Some(m),
                page,
                LINE_BYTES,
                now,
                t3 - now,
            ));
        }
        t3
    }

    /// Write a dirty line back to remote memory (asynchronous).  §4.6:
    /// with `dirty_replicas > 1`, the write goes to multiple memory
    /// modules (replica ACKs are off the critical path; the bandwidth
    /// cost is modeled on each replica's port and bus).
    fn writeback_line(&mut self, remote: &mut RemoteMemory, addr: u64, now: f64) {
        let page = Self::page_of(addr);
        let home = self.route(remote, page, now);
        let n = remote.modules();
        let replicas = self.cfg.dirty_replicas.min(n);
        for k in 0..replicas.max(1) {
            let m = (home + k) % n;
            let t1 = remote.fabric.send_up(m, self.id, now, LINE_BYTES, Class::Line);
            let t2 = remote.engines[m].access(self.id, t1, 8, Class::Line); // translation
            remote.engines[m].access(self.id, t2, LINE_BYTES, Class::Line);
            self.metrics.writeback_bytes += LINE_BYTES;
        }
    }

    /// Write a dirty page back to remote memory (asynchronous, on local
    /// memory eviction).
    fn writeback_page(&mut self, remote: &mut RemoteMemory, page: u64, now: f64) {
        let compress = self.policy.compress;
        let owner = self.owner_core(page);
        let bytes = if compress {
            self.oracle.page_size(owner, page) as u64
        } else {
            PAGE_BYTES
        };
        let m = self.route(remote, page, now);
        let mut t0 = now;
        if compress {
            t0 += self.cfg.daemon.compress_cycles;
        }
        let t1 = remote.fabric.send_up(m, self.id, t0, bytes, Class::Page);
        let t2 = remote.engines[m].access(self.id, t1, 8, Class::Page);
        remote.engines[m].access(self.id, t2, PAGE_BYTES, Class::Page);
        self.metrics.writeback_bytes += bytes;
    }

    /// Local memory access cost for one line (metadata lookup + DRAM).
    fn local_access(&mut self, now: f64, write: bool) -> f64 {
        let _ = write;
        let meta = ns_to_cycles(self.cfg.local_meta_ns);
        self.local_bus.access(now + meta, LINE_BYTES, Class::Line)
    }

    /// Apply all arrivals due at or before `now`.
    fn apply_arrivals(&mut self, remote: &mut RemoteMemory, now: f64) {
        while let Some((at, ev)) = self.arrivals.pop_due(now) {
            match ev {
                Arrival::Page { page } => match self.engine.page_arrived(page) {
                    PageArrival::Install { parked_dirty_lines } => {
                        self.metrics.pages_moved += 1;
                        if let Some(rec) = self.obs.as_mut() {
                            rec.event(Event::instant(
                                EventKind::PageInstall,
                                self.id,
                                None,
                                page,
                                at,
                            ));
                        }
                        if let Some(ev) = self.local.install(page, at) {
                            if ev.dirty {
                                self.writeback_page(remote, ev.page, at);
                            }
                        }
                        if parked_dirty_lines > 0 {
                            self.local.mark_dirty(page);
                        }
                    }
                    PageArrival::ThrottledRerequest => {
                        if let Some(rec) = self.obs.as_mut() {
                            rec.event(Event::instant(
                                EventKind::Rerequest,
                                self.id,
                                None,
                                page,
                                at,
                            ));
                        }
                        let (start, arrive) = self.schedule_page(remote, page, at);
                        self.engine.note_page_scheduled(page, start, arrive);
                        self.arrivals.push(arrive, Arrival::Page { page });
                    }
                    PageArrival::Unknown => {}
                },
                Arrival::Line { page, offset, addr } => {
                    if self.engine.line_arrived(page, offset) {
                        self.metrics.lines_moved += 1;
                        // Critical line goes straight to LLC through the
                        // coherent path (§4.1) — handle the LLC victim.
                        if let Some(victim) = self.llc.install(addr) {
                            self.handle_dirty_victim(remote, victim, at);
                        }
                    }
                    // Stale packet (page arrived first): ignored, §4.3(i).
                }
            }
        }
    }

    /// Epoch-gated observability sampling: when `now` crosses the next
    /// epoch boundary, capture a telemetry snapshot and check port-state
    /// edges, stamped at the boundary cycle.  One comparison when no
    /// recorder (or no boundary) is due.
    fn sample_obs(&mut self, remote: &RemoteMemory, now: f64) {
        let Some(rec) = self.obs.as_mut() else { return };
        let Some(cycle) = rec.epoch_crossed(now) else { return };
        self.obs_capture(remote, cycle);
    }

    /// Capture one observability sample at `cycle`.  Observation-only by
    /// construction: every fabric/engine/local accessor used here takes
    /// `&self`, so a recorder can never perturb simulation state.  The
    /// recorder is taken out and put back so [`Machine::observe`] can
    /// borrow `&self` for the snapshot itself.
    fn obs_capture(&mut self, remote: &RemoteMemory, cycle: f64) {
        let Some(mut rec) = self.obs.take() else { return };
        if rec.wants_trace() {
            for m in 0..remote.modules() {
                rec.port_edge(m, remote.fabric.port_state(m, self.id, cycle), cycle, self.id);
            }
        }
        if rec.wants_telemetry() {
            rec.push_snapshot(self.observe(remote, cycle));
        }
        self.obs = Some(rec);
    }

    /// Build this tenant's telemetry [`Snapshot`] at `cycle` — the
    /// observation vector shared by the recorder and the closed-loop
    /// [`AdaptiveController`](crate::system::controller::AdaptiveController).
    /// Pure observation (`&self` throughout), so sampling can never
    /// perturb simulation state.
    pub fn observe(&self, remote: &RemoteMemory, cycle: f64) -> Snapshot {
        let id = self.id;
        let modules = (0..remote.modules())
            .map(|m| {
                let egress = remote.engines[m].egress_stats(id);
                let (fa, fd) = remote.fabric.fault_counts(m, id);
                let (ea, ed) = remote.engines[m].fault_counts(id);
                ModuleSample {
                    module: m,
                    port: remote.fabric.port_state(m, id, cycle),
                    link_backlog_pages: remote.fabric.down_backlog(m, id, cycle, Class::Page),
                    link_backlog_lines: remote.fabric.down_backlog(m, id, cycle, Class::Line),
                    engine_backlog_pages: remote.engines[m].backlog(id, cycle, Class::Page),
                    engine_backlog_lines: remote.engines[m].backlog(id, cycle, Class::Line),
                    egress_raw_bytes: egress.raw_bytes,
                    egress_sent_bytes: egress.sent_bytes,
                    reclaimed_bytes: remote.fabric.reclaimed_bytes(m, id)
                        + remote.engines[m].reclaimed_bytes(id),
                    aborted: fa + ea,
                    deferred: fd + ed,
                    link_rate_scale: remote.fabric.down_rate_scale(m, id, cycle),
                }
            })
            .collect();
        Snapshot {
            cycle,
            tenant: id,
            inflight_pages: self.engine.inflight_pages(),
            inflight_lines: self.engine.inflight_lines(),
            dirty_buffered: self.engine.dirty_buffered(),
            page_buf_util: self.engine.page_util(),
            line_buf_util: self.engine.line_util(),
            local_pages: self.local.len(),
            local_capacity: self.local.capacity(),
            local_hit_rate: self.local.hit_rate(),
            pages_moved: self.metrics.pages_moved,
            lines_moved: self.metrics.lines_moved,
            pages_throttled: self.metrics.pages_throttled,
            net_bytes_in: self.metrics.net_bytes_in,
            compression_ratio: if self.policy.compress { self.oracle.ratio() } else { 1.0 },
            modules,
        }
    }

    /// §4.3 dirty-data handling for a dirty line evicted from the LLC.
    fn handle_dirty_victim(&mut self, remote: &mut RemoteMemory, addr: u64, now: f64) {
        let page = Self::page_of(addr);
        // Hits local memory: write it there.
        if self.local.present(page, now) && !self.policy.local_only {
            self.local.access(page, true, now);
            self.local_bus.access(now, LINE_BYTES, Class::Line);
            return;
        }
        if self.policy.local_only {
            self.local_bus.access(now, LINE_BYTES, Class::Line);
            return;
        }
        let offset = Self::offset_of(addr);
        match self.engine.dirty_evict(page, offset, now) {
            DirtyOutcome::WriteRemote => self.writeback_line(remote, addr, now),
            DirtyOutcome::Parked => {}
            DirtyOutcome::FlushAllAndThrottle { parked_flushed } => {
                // Flush all parked lines plus this one to remote.
                for _ in 0..=parked_flushed {
                    self.writeback_line(remote, addr, now);
                }
            }
        }
    }

    /// Service an LLC-miss demand access; returns its completion time.
    fn memory_access(
        &mut self,
        remote: &mut RemoteMemory,
        addr: u64,
        write: bool,
        now: f64,
    ) -> f64 {
        let page = Self::page_of(addr);
        let offset = Self::offset_of(addr);

        if self.policy.local_only {
            self.local.access(page, write, now);
            self.metrics.local_hits += 1;
            return self.local_access(now, write);
        }

        // Pure cache-line scheme bypasses local memory entirely.
        if !self.policy.move_pages && self.policy.move_lines {
            if let Some(arr) = self.engine.inflight_line(page, offset) {
                return arr;
            }
            let arr = self.schedule_line(remote, addr, now);
            self.engine.note_line_scheduled(page, offset, arr);
            self.arrivals.push(arr, Arrival::Line { page, offset, addr });
            return arr;
        }

        // Local memory lookup.  Hit-ratio accounting follows Fig. 10's
        // semantics — "a measure of the page movement benefits": an access
        // covered by an inflight page migration counts as page-served
        // even though its data races the core (the fast-progress schemes
        // would otherwise be *penalized* in the metric for overlapping
        // page transfers with execution, which is the opposite of what
        // the figure measures).
        let interval = (now / self.interval_cycles) as usize;
        if self.local.access(page, write, now) {
            self.metrics.local_hits += 1;
            self.metrics.bump_interval_local(interval, true);
            return self.local_access(now, write);
        }
        if self.policy.move_pages && self.engine.inflight_page(page).is_some() {
            self.metrics.local_hits += 1;
            self.metrics.bump_interval_local(interval, true);
        } else {
            self.metrics.local_misses += 1;
            self.metrics.bump_interval_local(interval, false);
        }

        // PageFree idealization (Fig. 3): the access costs one cache-line
        // remote latency; the page materializes in local memory for free.
        if self.policy.free_pages {
            if let Some(ev) = self.local.install(page, now) {
                if ev.dirty {
                    self.writeback_page(remote, ev.page, now);
                }
            }
            self.metrics.pages_moved += 1;
            return self.schedule_line(remote, addr, now);
        }

        let line_eta = self.line_eta(remote, page, now);
        let decision = self
            .engine
            .decide(page, offset, now, self.policy.selection, line_eta);

        let mut page_arr: Option<f64> = self.engine.inflight_page(page).map(|e| e.arrive);
        let mut line_arr: Option<f64> = self.engine.inflight_line(page, offset);

        if self.policy.move_pages && page_arr.is_none() {
            if decision.send_page {
                // Blocking (fault-based) schemes pay the kernel fault
                // overhead on the requesting side.
                let req_at = if self.policy.blocking_pages {
                    now + ns_to_cycles(self.cfg.fault_overhead_ns)
                } else {
                    now
                };
                let (start, arrive) = self.schedule_page(remote, page, req_at);
                self.engine.note_page_scheduled(page, start, arrive);
                self.arrivals.push(arrive, Arrival::Page { page });
                page_arr = Some(arrive);
                // §4.7: next-page prefetcher — sequential successors go
                // through the same selection path (DaeMon can throttle
                // them when the page buffer is under pressure).
                for k in 1..=self.cfg.prefetch_pages {
                    let next = page + k as u64;
                    if self.local.present(next, now)
                        || self.engine.inflight_page(next).is_some()
                    {
                        continue;
                    }
                    let d = self.engine.decide(next, 0, now, self.policy.selection, f64::MAX);
                    if !d.send_page {
                        break; // buffer pressure: stop prefetching
                    }
                    let (s, a) = self.schedule_page(remote, next, now);
                    self.engine.note_page_scheduled(next, s, a);
                    self.arrivals.push(a, Arrival::Page { page: next });
                }
            } else {
                self.engine.note_page_buffer_full();
                self.metrics.pages_throttled += 1;
                if let Some(rec) = self.obs.as_mut() {
                    rec.event(Event::instant(EventKind::Throttle, self.id, None, page, now));
                }
            }
        }

        if self.policy.move_lines && !self.policy.blocking_pages && line_arr.is_none() {
            if decision.send_line {
                let arr = self.schedule_line(remote, addr, now);
                self.engine.note_line_scheduled(page, offset, arr);
                self.arrivals.push(arr, Arrival::Line { page, offset, addr });
                line_arr = Some(arr);
            } else {
                self.engine.note_line_suppressed();
                if let Some(rec) = self.obs.as_mut() {
                    rec.event(Event::instant(EventKind::Suppress, self.id, None, page, now));
                }
            }
        }

        match (line_arr, page_arr) {
            (Some(l), Some(p)) => l.min(p),
            (Some(l), None) => l,
            (None, Some(p)) => p,
            (None, None) => {
                // Both buffers saturated with nothing inflight for this
                // address: fall back to an (overcommitted) line request.
                let arr = self.schedule_line(remote, addr, now);
                self.arrivals.push(arr, Arrival::Line { page, offset, addr });
                arr
            }
        }
    }

    /// Process one trace access on core `ci`.
    fn step(&mut self, remote: &mut RemoteMemory, ci: usize, addr: u64, write: bool, gap: u32) {
        let tagged = addr | ((ci as u64) << self.core_tag_shift);
        let now0 = self.cores[ci].time;
        self.apply_arrivals(remote, now0);
        self.sample_obs(remote, now0);

        // Gap instructions + the access instruction itself.
        let instrs = gap as u64 + 1;
        self.cores[ci].instructions += instrs;
        self.cores[ci].time += instrs as f64 * self.cfg.base_cpi;
        let now = self.cores[ci].time;
        let interval = (now / self.interval_cycles) as usize;
        if interval < 100_000 {
            self.metrics.bump_interval(interval, instrs);
        }

        // Cache hierarchy (L1 hits are pipeline-hidden).
        if self.cores[ci].l1.access(tagged, write) == CacheAccess::Hit {
            return;
        }
        if self.cores[ci].l2.access(tagged, write) == CacheAccess::Hit {
            self.cores[ci].time += self.cfg.l2.latency_cycles / self.cfg.issue_width as f64;
            return;
        }
        match self.llc.access(tagged, write) {
            CacheAccess::Hit => {
                self.cores[ci].time +=
                    self.cfg.llc.latency_cycles / self.cfg.issue_width as f64;
            }
            CacheAccess::Miss { dirty_victim } => {
                let done = self.memory_access(remote, tagged, write, now);
                self.metrics.access_cost.add(done - now);
                self.metrics.access_hist.add(done - now);
                // MLP window: block when full on the oldest completion.
                // Blocking-page schemes go through the kernel fault path,
                // which sustains far fewer concurrent outstanding misses.
                let mlp = if self.policy.blocking_pages {
                    self.cfg.fault_mlp
                } else {
                    self.cfg.core_mlp
                };
                let core = &mut self.cores[ci];
                if core.outstanding.len() >= mlp {
                    // Pop min completion.
                    let (idx, _) = core
                        .outstanding
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, v)| (i, *v))
                        .unwrap();
                    let oldest = core.outstanding.swap_remove(idx);
                    if oldest > core.time {
                        self.metrics.stall_cycles += oldest - core.time;
                        core.time = oldest;
                    }
                }
                core.outstanding.push(done);
                if let Some(victim) = dirty_victim {
                    self.handle_dirty_victim(remote, victim, now);
                }
            }
        }
    }

    /// Pre-run setup: local-only schemes preinstall every page, and the
    /// core merge-queue is (re)built so `peek`/`next_core`/`step_core`
    /// run in O(log cores) instead of rescanning every core per access.
    /// Part of the stepping API a [`crate::system::Cluster`] drives
    /// directly.  The queue snapshots which cores have work against
    /// *these* traces — every later stepping call must pass the same
    /// trace list (all drivers do); to switch lists, call `prepare`
    /// again.
    pub fn prepare<T: std::borrow::Borrow<Trace>>(&mut self, traces: &[T]) {
        assert!(!traces.is_empty());
        let mut q = MergeQueue::with_capacity(self.cores.len());
        for ci in 0..self.cores.len() {
            let t: &Trace = traces[ci % traces.len()].borrow();
            if self.cores[ci].pos < t.accesses.len() {
                q.push(self.cores[ci].time, ci);
            }
        }
        self.run_queue = Some(q);
        if self.policy.local_only {
            for (ci, t) in traces.iter().enumerate().take(self.cores.len()) {
                for a in &t.borrow().accesses {
                    let page =
                        Self::page_of(a.addr | ((ci as u64) << self.core_tag_shift));
                    self.local.install(page, 0.0);
                }
            }
            // Also cover cores cycling over the same trace.
            if self.cores.len() > traces.len() {
                for ci in traces.len()..self.cores.len() {
                    let t: &Trace = traces[ci % traces.len()].borrow();
                    for a in &t.accesses {
                        let page =
                            Self::page_of(a.addr | ((ci as u64) << self.core_tag_shift));
                        self.local.install(page, 0.0);
                    }
                }
            }
        }
    }

    /// The core the driver advances next: smallest time with work left
    /// (first core wins ties, matching the legacy run loop).  O(1) off
    /// the merge-queue after [`Machine::prepare`]; the pre-`prepare`
    /// fallback is the historical linear scan.
    pub fn next_core<T: std::borrow::Borrow<Trace>>(&self, traces: &[T]) -> Option<usize> {
        if let Some(q) = &self.run_queue {
            return q.peek().map(|(ci, _)| ci);
        }
        let mut best: Option<(usize, f64)> = None;
        for ci in 0..self.cores.len() {
            let t: &Trace = traces[ci % traces.len()].borrow();
            if self.cores[ci].pos < t.accesses.len() {
                let time = self.cores[ci].time;
                if best.map(|(_, bt)| time < bt).unwrap_or(true) {
                    best = Some((ci, time));
                }
            }
        }
        best.map(|(ci, _)| ci)
    }

    /// Next core and its issue time — what a cluster compares across
    /// tenants to advance the globally-earliest access; the core index
    /// goes straight back into [`Machine::step_core`] so the winner is
    /// not rescanned.
    pub fn peek<T: std::borrow::Borrow<Trace>>(&self, traces: &[T]) -> Option<(usize, f64)> {
        self.next_core(traces).map(|ci| (ci, self.cores[ci].time))
    }

    /// Advance one access on core `ci` (as returned by `peek`/`next_core`)
    /// over `remote`.
    pub fn step_core<T: std::borrow::Borrow<Trace>>(
        &mut self,
        remote: &mut RemoteMemory,
        traces: &[T],
        ci: usize,
    ) {
        let t: &Trace = traces[ci % traces.len()].borrow();
        let a = t.accesses[self.cores[ci].pos];
        self.cores[ci].pos += 1;
        let remaining = self.cores[ci].pos < t.accesses.len();
        // Merge-queue maintenance: by the peek/next_core contract the
        // stepped core is the queue head — drop its entry and re-enter it
        // at its advanced clock below.  Stepping any *other* core leaves
        // the queue stale, so it is invalidated (linear-scan fallback)
        // rather than silently misordering.
        let head = self.run_queue.as_ref().and_then(MergeQueue::peek).map(|(i, _)| i);
        let queued = if head == Some(ci) {
            self.run_queue.as_mut().unwrap().pop();
            true
        } else {
            self.run_queue = None;
            false
        };
        self.step(remote, ci, a.addr, a.write, a.gap);
        if queued && remaining {
            self.run_queue
                .as_mut()
                .expect("merge queue present while maintained")
                .push(self.cores[ci].time, ci);
        }
    }

    /// Advance one access on the next core over `remote`; returns false
    /// once every core has drained its trace.
    pub fn step_next<T: std::borrow::Borrow<Trace>>(
        &mut self,
        remote: &mut RemoteMemory,
        traces: &[T],
    ) -> bool {
        let Some(ci) = self.next_core(traces) else {
            return false;
        };
        self.step_core(remote, traces, ci);
        true
    }

    /// Rewind every core's trace cursor for the next service burst and
    /// advance idle cores to `at` (clocks are monotone — a core already
    /// past `at` keeps its time).  Part of the burst-driving API the
    /// request front-end ([`crate::system::frontend`]) uses between
    /// [`Machine::prepare`] calls; trace-driven runs never call this,
    /// so the historical path is untouched.
    pub fn begin_burst(&mut self, at: f64) {
        for c in self.cores.iter_mut() {
            c.pos = 0;
            if c.time < at {
                c.time = at;
            }
        }
        self.run_queue = None;
    }

    /// Drain in-flight misses into stall cycles (the same drain
    /// [`Machine::finish`] performs at end of run) and return the burst
    /// completion time — the max core clock after the drain.  Clears
    /// the outstanding sets so a later `finish` never double-drains.
    pub fn drain_outstanding(&mut self) -> f64 {
        for ci in 0..self.cores.len() {
            let max_out = self.cores[ci]
                .outstanding
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
            if max_out > self.cores[ci].time {
                self.metrics.stall_cycles += max_out - self.cores[ci].time;
                self.cores[ci].time = max_out;
            }
            self.cores[ci].outstanding.clear();
        }
        self.cores.iter().map(|c| c.time).fold(0.0f64, f64::max)
    }

    /// Drain outstanding misses + arrivals and finalize the metrics.
    pub fn finish(&mut self, remote: &mut RemoteMemory) {
        for ci in 0..self.cores.len() {
            let max_out = self.cores[ci]
                .outstanding
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
            if max_out > self.cores[ci].time {
                self.metrics.stall_cycles += max_out - self.cores[ci].time;
                self.cores[ci].time = max_out;
            }
        }
        let end = self
            .cores
            .iter()
            .map(|c| c.time)
            .fold(0.0f64, f64::max);
        self.apply_arrivals(remote, end + 1e12);

        self.metrics.instructions = self.cores.iter().map(|c| c.instructions).sum();
        let horizon = end.max(1.0);
        self.metrics.cycles = horizon;
        self.metrics.net_utilization = {
            let u: f64 = (0..remote.modules())
                .map(|m| remote.fabric.down_utilization(m, self.id, horizon))
                .sum();
            u / remote.modules() as f64
        };
        // Per-interval downlink utilization, averaged over this tenant's
        // ports across all modules (the variability time-series input).
        // Collected per module first so the accumulator is allocated once
        // at its final length instead of growing as modules report.
        self.metrics.net_util_series = {
            let per_module: Vec<Vec<f64>> = (0..remote.modules())
                .map(|m| remote.fabric.down_series(m, self.id, horizon))
                .collect();
            let len = per_module.iter().map(Vec::len).max().unwrap_or(0);
            let mut series = vec![0.0; len];
            for s in &per_module {
                for (i, v) in s.iter().enumerate() {
                    series[i] += v;
                }
            }
            let n = remote.modules() as f64;
            series.iter_mut().for_each(|v| *v /= n);
            series
        };
        // Capacity this tenant served on borrowed (idle peer /
        // sibling-class) shares — zero in strict mode by construction.
        self.metrics.reclaimed_bytes = (0..remote.modules())
            .map(|m| {
                remote.fabric.reclaimed_bytes(m, self.id)
                    + remote.engines[m].reclaimed_bytes(self.id)
            })
            .sum();
        // Failure accounting: this tenant's worst-port down time within
        // the horizon (max over its module ports — a single-module
        // outage reports its full length) and fault-deferred /
        // aborted-and-replayed work summed over the fabric ports and the
        // memory engines — all zero when no fault plan is installed.
        self.metrics.downtime_cycles = (0..remote.modules())
            .map(|m| remote.fabric.port_downtime(m, self.id, horizon))
            .fold(0.0f64, f64::max);
        let (mut aborted, mut deferred) = (0u64, 0u64);
        for m in 0..remote.modules() {
            let (fa, fd) = remote.fabric.fault_counts(m, self.id);
            let (ea, ed) = remote.engines[m].fault_counts(self.id);
            aborted += fa + ea;
            deferred += fd + ed;
        }
        self.metrics.aborted_transfers = aborted;
        self.metrics.deferred_requests = deferred;
        self.metrics.compression_ratio = if self.policy.compress {
            self.oracle.ratio()
        } else {
            1.0
        };
        // Final observability sample pinned at the horizon, so every
        // enabled run carries at least one snapshot and the last
        // port-state edge is never lost to epoch quantization.
        self.obs_capture(remote, horizon);
    }

    /// Run the traces to completion (one per core, cycled if fewer).
    /// Generic over `Borrow<Trace>` so callers can hand in owned traces or
    /// `Arc<Trace>`s shared from the trace cache without cloning.
    pub fn run<T: std::borrow::Borrow<Trace>>(&mut self, traces: &[T]) -> &Metrics {
        let mut remote = self
            .remote
            .take()
            .expect("this Machine is a cluster tenant; drive it through Cluster::run");
        self.prepare(traces);
        while self.step_next(&mut remote, traces) {}
        self.finish(&mut remote);
        self.remote = Some(remote);
        &self.metrics
    }

    pub fn scheme(&self) -> SchemeKind {
        self.kind
    }

    pub fn engine_stats(&self) -> &ComputeEngine {
        &self.engine
    }

    /// Per-interval utilization of the first memory module's downlink,
    /// clipped at the finished run's horizon (solo machines only, after
    /// `run()`).
    pub fn link_utilization_series(&self) -> Vec<f64> {
        self.remote
            .as_ref()
            .expect("link_utilization_series reads a solo machine's own fabric")
            .fabric
            .down_series(0, self.id, self.metrics.cycles.max(1.0))
    }

    pub fn local_hit_rate(&self) -> f64 {
        self.metrics.local_hit_ratio()
    }
}

/// Convenience: run one workload under one scheme.
pub struct RunResult {
    pub metrics: Metrics,
    pub scheme: SchemeKind,
    pub workload: &'static str,
}

pub fn run_workload(
    cfg: &SimConfig,
    kind: SchemeKind,
    workload: &dyn Workload,
    scale: Scale,
) -> RunResult {
    let trace = workload.generate(cfg.seed, scale);
    let mut machine = Machine::new(
        cfg.clone(),
        kind,
        trace.footprint_pages,
        vec![workload.profile(); cfg.cores.max(1)],
        None,
    );
    machine.run(std::slice::from_ref(&trace));
    RunResult {
        metrics: machine.metrics.clone(),
        scheme: kind,
        workload: workload.name(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    fn quick_cfg() -> SimConfig {
        SimConfig::test_scale().with_seed(7)
    }

    fn run(kind: SchemeKind, workload: &str) -> Metrics {
        let w = by_name(workload).unwrap();
        run_workload(&quick_cfg(), kind, w.as_ref(), Scale::Test).metrics
    }

    #[test]
    fn local_is_fastest_remote_slowest() {
        let local = run(SchemeKind::Local, "pr");
        let remote = run(SchemeKind::Remote, "pr");
        assert!(local.ipc() > remote.ipc() * 1.5,
            "Local {} vs Remote {}", local.ipc(), remote.ipc());
    }

    #[test]
    fn daemon_beats_remote_on_low_locality() {
        let daemon = run(SchemeKind::Daemon, "pr");
        let remote = run(SchemeKind::Remote, "pr");
        assert!(
            daemon.ipc() > remote.ipc() * 1.2,
            "DaeMon {} vs Remote {}",
            daemon.ipc(),
            remote.ipc()
        );
    }

    #[test]
    fn daemon_reduces_access_cost_vs_naive_both() {
        // Same hardware request path, so the comparison is robust at Test
        // scale: DaeMon's partitioning + selection + compression must beat
        // naively requesting both granularities on a shared link.
        let daemon = run(SchemeKind::Daemon, "pr");
        let naive = run(SchemeKind::CacheLinePage, "pr");
        assert!(
            daemon.mean_access_cost() < naive.mean_access_cost(),
            "DaeMon {} vs cache-line+page {}",
            daemon.mean_access_cost(),
            naive.mean_access_cost()
        );
    }

    #[test]
    fn remote_has_high_local_hit_ratio() {
        // Paper Fig. 10: Remote ~97.7% average, >=90% everywhere.
        for wl in ["pr", "sp", "hp"] {
            let m = run(SchemeKind::Remote, wl);
            assert!(
                m.local_hit_ratio() > 0.85,
                "{wl}: hit ratio {}",
                m.local_hit_ratio()
            );
        }
    }

    #[test]
    fn page_free_close_to_local() {
        let local = run(SchemeKind::Local, "sp");
        let pf = run(SchemeKind::PageFree, "sp");
        assert!(
            pf.ipc() > local.ipc() * 0.5,
            "page-free {} vs local {}",
            pf.ipc(),
            local.ipc()
        );
    }

    #[test]
    fn compression_ratio_reported_only_when_compressing() {
        let lc = run(SchemeKind::Lc, "sp");
        assert!(lc.compression_ratio > 1.5, "ratio {}", lc.compression_ratio);
        let pq = run(SchemeKind::Pq, "sp");
        assert_eq!(pq.compression_ratio, 1.0);
    }

    #[test]
    fn pq_throttles_pages_on_low_locality() {
        let pq = run(SchemeKind::Pq, "pr");
        assert!(pq.pages_throttled > 0 || pq.lines_moved > 0);
        let remote = run(SchemeKind::Remote, "pr");
        assert!(pq.pages_moved <= remote.pages_moved);
    }

    #[test]
    fn instructions_preserved_across_schemes() {
        let a = run(SchemeKind::Remote, "bf");
        let b = run(SchemeKind::Daemon, "bf");
        assert_eq!(a.instructions, b.instructions);
    }

    #[test]
    fn multicore_shares_bandwidth() {
        let w = by_name("pr").unwrap();
        let one = run_workload(&quick_cfg(), SchemeKind::Remote, w.as_ref(), Scale::Test);
        let cfg8 = quick_cfg().with_cores(4);
        let eight = run_workload(&cfg8, SchemeKind::Remote, w.as_ref(), Scale::Test);
        // 4 cores re-running the same trace move ~4x the instructions.
        assert!(eight.metrics.instructions > 3 * one.metrics.instructions);
        // Per-core progress is slower than the single-core run.
        assert!(eight.metrics.cycles > one.metrics.cycles);
    }

    #[test]
    #[should_panic(expected = "at least one content profile")]
    fn machine_rejects_empty_profiles() {
        let _ = Machine::new(quick_cfg(), SchemeKind::Remote, 128, vec![], None);
    }

    #[test]
    #[should_panic(expected = "at least one content profile")]
    fn exact_oracle_rejects_empty_profiles() {
        let _ = ExactOracle::new(1, &[], crate::compress::Algo::Lz);
    }

    #[test]
    fn exact_oracle_selects_per_core_profile() {
        use crate::compress::Algo;
        let (a, b) = (Profile::high(), Profile::uniform_mix(1.0));
        let mut oracle = ExactOracle::new(7, &[a, b], Algo::Lz);
        // Each core's sizes must match a compressor built with that core's
        // profile and per-core seed — not the last profile for everyone.
        let mut ca = Compressor::new(7, a, Algo::Lz);
        let mut cb = Compressor::new(7 ^ 1u64 << 32, b, Algo::Lz);
        for page in [1u64, 99, 4242] {
            assert_eq!(oracle.page_size(0, page), ca.size_of(page), "core 0 @ {page}");
            assert_eq!(oracle.page_size(1, page), cb.size_of(page), "core 1 @ {page}");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "has no content profile")]
    fn exact_oracle_rejects_out_of_range_core() {
        let mut oracle = ExactOracle::new(7, &[Profile::high()], crate::compress::Algo::Lz);
        let _ = oracle.page_size(1, 42); // only core 0 has a profile
    }

    #[test]
    fn solo_net_schedule_degrades_throughput() {
        use crate::net::NetSchedule;
        use std::sync::Arc;
        let w = by_name("pr").unwrap();
        let cfg = quick_cfg();
        let trace = w.generate(cfg.seed, Scale::Test);
        let mk = || {
            Machine::new(
                cfg.clone(),
                SchemeKind::Remote,
                trace.footprint_pages,
                vec![w.profile()],
                None,
            )
        };
        let mut steady = mk();
        steady.run(std::slice::from_ref(&trace));
        let mut degraded = mk();
        // Quarter bandwidth on every port for 1e12 cycles (whole run).
        degraded.set_net_schedule(|_, _| {
            Some(Arc::new(NetSchedule::square_wave(1e12, 0.25, 0.0, 1e12)))
        });
        degraded.run(std::slice::from_ref(&trace));
        assert_eq!(steady.metrics.instructions, degraded.metrics.instructions);
        assert!(
            degraded.metrics.cycles > steady.metrics.cycles,
            "degraded solo fabric must cost cycles: {} vs {}",
            degraded.metrics.cycles,
            steady.metrics.cycles
        );
    }

    #[test]
    fn run_accepts_shared_arc_traces() {
        use std::sync::Arc;
        let w = by_name("pr").unwrap();
        let cfg = quick_cfg();
        let trace = Arc::new(w.generate(cfg.seed, Scale::Test));
        let mut m = Machine::new(
            cfg.clone(),
            SchemeKind::Daemon,
            trace.footprint_pages,
            vec![w.profile()],
            None,
        );
        m.run(std::slice::from_ref(&trace));
        let owned = run(SchemeKind::Daemon, "pr");
        assert_eq!(m.metrics.instructions, owned.instructions);
        assert!((m.metrics.cycles - owned.cycles).abs() < 1e-6);
    }

    #[test]
    fn multiple_memory_components_increase_aggregate_bandwidth() {
        use crate::config::NetConfig;
        let w = by_name("pr").unwrap();
        let one = run_workload(&quick_cfg(), SchemeKind::Remote, w.as_ref(), Scale::Test);
        let cfg4 = quick_cfg().with_memory_components(vec![NetConfig::new(100.0, 4.0); 4]);
        let four = run_workload(&cfg4, SchemeKind::Remote, w.as_ref(), Scale::Test);
        assert!(
            four.metrics.ipc() > one.metrics.ipc(),
            "4 comps {} vs 1 comp {}",
            four.metrics.ipc(),
            one.metrics.ipc()
        );
    }

    #[test]
    fn stepping_api_matches_run() {
        // prepare/step_next/finish (what a Cluster drives) must replay the
        // exact run() sequence.
        let w = by_name("bf").unwrap();
        let cfg = quick_cfg();
        let trace = w.generate(cfg.seed, Scale::Test);
        let mk = || {
            Machine::new(
                cfg.clone(),
                SchemeKind::Daemon,
                trace.footprint_pages,
                vec![w.profile()],
                None,
            )
        };
        let mut a = mk();
        a.run(std::slice::from_ref(&trace));
        let mut b = mk();
        let mut remote = RemoteMemory::for_config(&cfg, SchemeKind::Daemon.policy());
        b.prepare(std::slice::from_ref(&trace));
        while b.step_next(&mut remote, std::slice::from_ref(&trace)) {}
        b.finish(&mut remote);
        assert_eq!(
            a.metrics.to_json().to_string(),
            b.metrics.to_json().to_string(),
            "stepping API diverged from run()"
        );
    }
}
