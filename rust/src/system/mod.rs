//! System coordinator: the disaggregated machine driver, the multi-tenant
//! cluster driver, multi-workload execution, and parallel experiment
//! sweeps.

pub mod cluster;
pub mod machine;
pub mod sweep;

pub use cluster::{run_cluster, Cluster, TenantInit};
pub use machine::{run_workload, ExactOracle, Machine, RemoteMemory, RunResult, SizeOracle};
