//! System coordinator: the disaggregated machine driver, multi-workload
//! execution, and parallel experiment sweeps.

pub mod machine;
pub mod sweep;

pub use machine::{run_workload, ExactOracle, Machine, RunResult, SizeOracle};
