//! System coordinator: the disaggregated machine driver, the multi-tenant
//! cluster driver, fault injection / degraded-mode recovery,
//! multi-workload execution, and parallel experiment sweeps.

pub mod cluster;
pub mod controller;
pub mod fault;
pub mod frontend;
pub mod machine;
pub mod sweep;

pub use cluster::{run_cluster, Cluster, TenantEvent, TenantInit, TenantState};
pub use frontend::{run_service, run_service_obs, RequestEvent, RequestState};
pub use controller::{Action, AdaptiveController};
pub use fault::{
    FaultCounters, FaultPlan, FaultTarget, FaultTimeline, FaultWindow, PortState, RecoveryPolicy,
};
pub use machine::{run_workload, ExactOracle, Machine, RemoteMemory, RunResult, SizeOracle};
