//! Closed-loop adaptive controller (ROADMAP item 3): a deterministic
//! per-epoch feedback loop over the per-tenant observation vector the
//! telemetry layer already samples ([`Machine::observe`]).
//!
//! Control model: at every crossed observation epoch (the same collapsing
//! boundary rule as `obs::Recorder::epoch_crossed`, so controller runs
//! ride the PR-7 sampling cadence) the cluster driver hands the
//! controller one [`Snapshot`] per tenant; [`AdaptiveController::plan`]
//! returns the bounded [`Action`]s to apply.  `plan` is a **pure
//! function of (internal state, observations)** — no clocks, no
//! randomness, no map-iteration order — so identical observation streams
//! produce identical action sequences (fuzz-pinned below), and runs stay
//! byte-identical across `--jobs` counts and repeats.
//!
//! The three laws are registered in [`crate::policy::adaptive`] with
//! their actuation bounds; every action is clamped to its law's declared
//! range before it is emitted:
//!
//! * **`ratio-tune`** — steps each partitioned tenant's §4.1 line/page
//!   ratio toward the law maximum under observed link distress (degraded
//!   schedule phase or a non-Up port) and back toward the scheme's
//!   static default when clean, damped by the law's `max_step` per
//!   epoch.
//! * **`recovery-switch`** — holds tenants on `Refetch` while any
//!   distress is observed (routing around a dead module is decided at
//!   issue time, so switching *into* `Refetch` after a crash cannot
//!   un-strand already-deferred accesses; starting there is the only
//!   reactive-safe initial state — see DESIGN.md §"Closed-loop
//!   control") and relaxes to `Stall` only after a full clean dwell of
//!   [`CLEAN_DWELL_EPOCHS`] consecutive distress-free samples.
//! * **`share-rebalance`** — under work-conserving sharing, drops
//!   tenants observed idle (no new downlink bytes and empty in-flight
//!   buffers) for [`IDLE_DWELL_EPOCHS`] consecutive epochs to the law's
//!   weight floor and hands the slack to active tenants proportionally
//!   to their configured base weights; emitted weight vectors always
//!   sum to 1.0.  The dwell keeps one quiet epoch (a burst gap, a
//!   high-hit-rate phase) from being misread as retirement; a tenant
//!   that moves bytes again is restored to its base weight at the next
//!   epoch.
//!
//! Actuation is fabric-side only: port partition ratios and port
//! capacity weights.  The memory-engine DRAM bus keeps its static
//! carve — the fabric link is `bandwidth_factor`× scarcer and is the
//! binding resource, and retuning one timeline keeps the actuation
//! surface small (documented simplification).

use crate::config::{ControllerSpec, SharingMode, TenantShare};
use crate::obs::Snapshot;
use crate::policy::adaptive::{control_law, ControlLawDef};
use crate::system::fault::{PortState, RecoveryPolicy};

/// Clean observation epochs required before `recovery-switch` relaxes a
/// tenant from `Refetch` back to `Stall` (dwell hysteresis: distress
/// resets the count).  At the default controller epoch this is on the
/// order of 10^6 cycles of continuously nominal conditions.
pub const CLEAN_DWELL_EPOCHS: u32 = 40;

/// Consecutive quiet observation epochs (zero downlink-byte delta and
/// empty in-flight buffers) before `share-rebalance` treats a tenant as
/// idle and floors its weight.
pub const IDLE_DWELL_EPOCHS: u32 = 2;

/// One bounded actuation emitted by [`AdaptiveController::plan`].
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// Re-split `tenant`'s partitioned fabric ports to reserve `ratio`
    /// for cache lines (`ratio-tune`).
    SetRatio { tenant: usize, ratio: f64 },
    /// Switch `tenant`'s degraded-mode policy (`recovery-switch`).
    SetRecovery { tenant: usize, policy: RecoveryPolicy },
    /// Re-carve fabric port capacity across all tenants
    /// (`share-rebalance`); normalized, sums to 1.0.
    SetWeights { weights: Vec<f64> },
}

impl Action {
    /// Registry id of the control law that produced this action.
    pub fn law(&self) -> &'static str {
        match self {
            Action::SetRatio { .. } => "ratio-tune",
            Action::SetRecovery { .. } => "recovery-switch",
            Action::SetWeights { .. } => "share-rebalance",
        }
    }
}

/// Deterministic per-epoch feedback controller — see the module docs.
pub struct AdaptiveController {
    spec: ControllerSpec,
    sharing: SharingMode,
    /// Configured base weights, normalized to sum 1.0.
    base_weights: Vec<f64>,
    /// Each tenant's static partition ratio (the clean-conditions target).
    default_ratio: Vec<f64>,
    /// Which tenants have class-partitioned ports (ratio-tunable).
    partitioned: Vec<bool>,
    /// Next unsampled epoch boundary (same collapsing rule as the
    /// telemetry recorder).
    next_epoch: f64,
    /// Current actuated state, mirrored so `plan` emits only changes.
    ratio: Vec<f64>,
    recovery: Vec<RecoveryPolicy>,
    clean_epochs: Vec<u32>,
    /// Consecutive quiet epochs per tenant (idle-dwell counter).
    idle_epochs: Vec<u32>,
    weights: Vec<f64>,
    /// Downlink byte counters at the previous epoch (idle detection).
    prev_bytes: Vec<u64>,
}

fn law(id: &str) -> &'static ControlLawDef {
    control_law(id).expect("control law registered")
}

/// A tenant observes distress when any of its module ports is not `Up`
/// or any downlink schedule is in a degraded phase.  The schedule signal
/// is the *scale* (1.0 = nominal), deliberately invariant under the
/// controller's own rate actuation.
fn distressed(s: &Snapshot) -> bool {
    s.modules
        .iter()
        .any(|m| m.port != PortState::Up || m.link_rate_scale < 0.999)
}

impl AdaptiveController {
    /// Build a controller for `shares.len()` tenants.  `spec` must not
    /// be inert (the cluster driver gates on [`ControllerSpec::is_inert`]
    /// so inert configs never construct a controller at all and stay on
    /// the exact historical code path).
    pub fn new(spec: ControllerSpec, sharing: SharingMode, shares: &[TenantShare]) -> Self {
        assert!(!spec.is_inert(), "inert controller specs must not be constructed");
        assert!(!shares.is_empty(), "controller needs at least one tenant");
        let wsum: f64 = shares.iter().map(|s| s.weight).sum();
        let base_weights: Vec<f64> = shares.iter().map(|s| s.weight / wsum).collect();
        let default_ratio: Vec<f64> = shares.iter().map(|s| s.line_ratio).collect();
        let partitioned: Vec<bool> = shares.iter().map(|s| s.partitioned).collect();
        let n = shares.len();
        AdaptiveController {
            spec,
            sharing,
            ratio: default_ratio.clone(),
            recovery: vec![Self::initial_recovery_for(&spec); n],
            clean_epochs: vec![0; n],
            idle_epochs: vec![0; n],
            weights: base_weights.clone(),
            prev_bytes: vec![0; n],
            next_epoch: spec.epoch_cycles,
            base_weights,
            default_ratio,
            partitioned,
        }
    }

    fn initial_recovery_for(spec: &ControllerSpec) -> RecoveryPolicy {
        if spec.switch_recovery {
            // Refetch probes the home port first, so it is byte-identical
            // to Stall while conditions are clean — and it is the only
            // state that still routes around a module that dies before
            // the first distressed sample (stall-deferred accesses park
            // until recovery regardless of later switches).
            RecoveryPolicy::Refetch
        } else {
            RecoveryPolicy::Stall
        }
    }

    /// The recovery policy every tenant should start under when this
    /// controller runs the `recovery-switch` law (`None` = leave the
    /// configured static policy alone).
    pub fn initial_recovery(&self) -> Option<RecoveryPolicy> {
        self.spec.switch_recovery.then_some(RecoveryPolicy::Refetch)
    }

    /// Number of tenants under control.
    pub fn tenants(&self) -> usize {
        self.base_weights.len()
    }

    /// Latest unsampled epoch boundary at or before `now`, advancing the
    /// cadence past `now` — the exact collapsing rule of
    /// `obs::Recorder::epoch_crossed`, so controller epochs ride the
    /// telemetry sampling boundary.
    pub fn epoch_crossed(&mut self, now: f64) -> Option<f64> {
        if now < self.next_epoch {
            return None;
        }
        let e = self.spec.epoch_cycles;
        let at = self.next_epoch + ((now - self.next_epoch) / e).floor() * e;
        self.next_epoch = at + e;
        Some(at)
    }

    /// One control step: observations in (tenant order), bounded actions
    /// out.  Pure function of `(self, obs)`; emits only *changes*, so a
    /// steady system converges to an empty action stream.  Action order
    /// is fixed (ratio per tenant asc, recovery per tenant asc, weights
    /// last) — part of the determinism contract.
    pub fn plan(&mut self, obs: &[Snapshot]) -> Vec<Action> {
        assert_eq!(obs.len(), self.tenants(), "one snapshot per tenant");
        let mut actions = Vec::new();
        if self.spec.tune_ratio {
            let l = law("ratio-tune");
            for (t, s) in obs.iter().enumerate() {
                if !self.partitioned[t] {
                    continue;
                }
                let target = if distressed(s) {
                    l.max
                } else {
                    self.default_ratio[t].clamp(l.min, l.max)
                };
                let step = (target - self.ratio[t]).clamp(-l.max_step, l.max_step);
                let next = (self.ratio[t] + step).clamp(l.min, l.max);
                if next != self.ratio[t] {
                    self.ratio[t] = next;
                    actions.push(Action::SetRatio { tenant: t, ratio: next });
                }
            }
        }
        if self.spec.switch_recovery {
            for (t, s) in obs.iter().enumerate() {
                if distressed(s) {
                    self.clean_epochs[t] = 0;
                    if self.recovery[t] != RecoveryPolicy::Refetch {
                        self.recovery[t] = RecoveryPolicy::Refetch;
                        actions.push(Action::SetRecovery {
                            tenant: t,
                            policy: RecoveryPolicy::Refetch,
                        });
                    }
                } else {
                    self.clean_epochs[t] = self.clean_epochs[t].saturating_add(1);
                    if self.clean_epochs[t] >= CLEAN_DWELL_EPOCHS
                        && self.recovery[t] != RecoveryPolicy::Stall
                    {
                        self.recovery[t] = RecoveryPolicy::Stall;
                        actions.push(Action::SetRecovery {
                            tenant: t,
                            policy: RecoveryPolicy::Stall,
                        });
                    }
                }
            }
        }
        if self.spec.rebalance_shares && self.sharing == SharingMode::WorkConserving {
            let l = law("share-rebalance");
            for (t, s) in obs.iter().enumerate() {
                let quiet = s.net_bytes_in == self.prev_bytes[t]
                    && s.inflight_pages == 0
                    && s.inflight_lines == 0;
                self.idle_epochs[t] =
                    if quiet { self.idle_epochs[t].saturating_add(1) } else { 0 };
            }
            let idle: Vec<bool> =
                self.idle_epochs.iter().map(|&e| e >= IDLE_DWELL_EPOCHS).collect();
            let n_idle = idle.iter().filter(|&&b| b).count();
            let slack = 1.0 - l.min * n_idle as f64;
            let mut w = self.base_weights.clone();
            if n_idle > 0 && n_idle < w.len() && slack > 0.0 {
                let active_base: f64 = self
                    .base_weights
                    .iter()
                    .zip(&idle)
                    .filter(|(_, &i)| !i)
                    .map(|(b, _)| b)
                    .sum();
                for t in 0..w.len() {
                    w[t] = if idle[t] {
                        l.min
                    } else {
                        slack * self.base_weights[t] / active_base
                    };
                }
            }
            if w != self.weights {
                self.weights = w.clone();
                actions.push(Action::SetWeights { weights: w });
            }
        }
        for (t, s) in obs.iter().enumerate() {
            self.prev_bytes[t] = s.net_bytes_in;
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ModuleSample, Snapshot};

    fn shares(n: usize) -> Vec<TenantShare> {
        (0..n)
            .map(|_| TenantShare { weight: 1.0, partitioned: true, line_ratio: 0.25 })
            .collect()
    }

    fn spec() -> ControllerSpec {
        ControllerSpec::all(25_000.0)
    }

    fn sample(port: PortState, scale: f64) -> ModuleSample {
        ModuleSample {
            module: 0,
            port,
            link_backlog_pages: 0.0,
            link_backlog_lines: 0.0,
            engine_backlog_pages: 0.0,
            engine_backlog_lines: 0.0,
            egress_raw_bytes: 0,
            egress_sent_bytes: 0,
            reclaimed_bytes: 0,
            aborted: 0,
            deferred: 0,
            link_rate_scale: scale,
        }
    }

    fn snap(tenant: usize, cycle: f64, port: PortState, scale: f64, bytes: u64) -> Snapshot {
        let mut s = Snapshot::empty(tenant, cycle);
        s.net_bytes_in = bytes;
        s.modules.push(sample(port, scale));
        s
    }

    fn clean(tenant: usize, cycle: f64, bytes: u64) -> Snapshot {
        snap(tenant, cycle, PortState::Up, 1.0, bytes)
    }

    #[test]
    fn epoch_crossing_matches_the_recorder_rule() {
        let mut c = AdaptiveController::new(
            ControllerSpec::all(100.0),
            SharingMode::Strict,
            &shares(1),
        );
        assert_eq!(c.epoch_crossed(50.0), None);
        assert_eq!(c.epoch_crossed(100.0), Some(100.0));
        assert_eq!(c.epoch_crossed(150.0), None);
        assert_eq!(c.epoch_crossed(1234.0), Some(1200.0));
        assert_eq!(c.epoch_crossed(1299.0), None);
        assert_eq!(c.epoch_crossed(1300.0), Some(1300.0));
    }

    #[test]
    fn ratio_tune_is_damped_clamped_and_reverts() {
        let mut c = AdaptiveController::new(spec(), SharingMode::Strict, &shares(1));
        let distress = |cy: f64, b| vec![snap(0, cy, PortState::Up, 0.25, b)];
        // 0.25 -> 0.45 -> 0.60 under persistent distress (max_step 0.2).
        assert_eq!(
            c.plan(&distress(1e4, 10)),
            vec![Action::SetRatio { tenant: 0, ratio: 0.45 }]
        );
        assert_eq!(
            c.plan(&distress(2e4, 20)),
            vec![Action::SetRatio { tenant: 0, ratio: 0.6 }]
        );
        // Saturated at the law max: no further action.
        assert_eq!(c.plan(&distress(3e4, 30)), vec![]);
        // Clean conditions step back toward the static default and stop.
        assert_eq!(
            c.plan(&[clean(0, 4e4, 40)]),
            vec![Action::SetRatio { tenant: 0, ratio: 0.4 }]
        );
        let back = c.plan(&[clean(0, 5e4, 50)]);
        assert_eq!(back, vec![Action::SetRatio { tenant: 0, ratio: 0.25 }]);
        assert_eq!(c.plan(&[clean(0, 6e4, 60)]), vec![], "converged = silent");
    }

    #[test]
    fn unpartitioned_tenants_are_never_ratio_tuned() {
        let sh =
            vec![TenantShare { weight: 1.0, partitioned: false, line_ratio: 0.25 }];
        let mut c = AdaptiveController::new(spec(), SharingMode::Strict, &sh);
        let acts = c.plan(&[snap(0, 1e4, PortState::Up, 0.25, 10)]);
        assert!(
            !acts.iter().any(|a| matches!(a, Action::SetRatio { .. })),
            "{acts:?}"
        );
    }

    #[test]
    fn recovery_switch_starts_refetch_with_dwell_hysteresis() {
        let mut c = AdaptiveController::new(spec(), SharingMode::Strict, &shares(1));
        assert_eq!(c.initial_recovery(), Some(RecoveryPolicy::Refetch));
        // Distress: already Refetch, nothing to emit.
        let acts = c.plan(&[snap(0, 1e4, PortState::Down, 1.0, 0)]);
        assert!(!acts.iter().any(|a| matches!(a, Action::SetRecovery { .. })));
        // One epoch short of the dwell: still Refetch.
        let mut bytes = 0;
        for k in 0..CLEAN_DWELL_EPOCHS - 1 {
            bytes += 10;
            let acts = c.plan(&[clean(0, 2e4 + k as f64 * 1e4, bytes)]);
            assert!(
                !acts.iter().any(|a| matches!(a, Action::SetRecovery { .. })),
                "epoch {k}: {acts:?}"
            );
        }
        // The dwell completes: relax to Stall exactly once.
        let acts = c.plan(&[clean(0, 9e5, bytes + 10)]);
        assert_eq!(
            acts,
            vec![Action::SetRecovery { tenant: 0, policy: RecoveryPolicy::Stall }]
        );
        // Any distress snaps straight back to Refetch (ratio-tune also
        // reacts to the same distress; recovery actions order after it).
        let acts = c.plan(&[snap(0, 1e6, PortState::Recovering, 1.0, bytes + 10)]);
        assert!(
            acts.contains(&Action::SetRecovery {
                tenant: 0,
                policy: RecoveryPolicy::Refetch
            }),
            "{acts:?}"
        );
    }

    #[test]
    fn share_rebalance_floors_idle_tenants_after_the_dwell() {
        let mut c = AdaptiveController::new(spec(), SharingMode::WorkConserving, &shares(2));
        // Epoch 1: both moved bytes since the (zero) baseline — no change.
        assert_eq!(c.plan(&[clean(0, 1e4, 100), clean(1, 1e4, 100)]), vec![]);
        // Epoch 2: tenant 1 quiet, but the 2-epoch idle dwell holds fire.
        assert_eq!(c.plan(&[clean(0, 2e4, 200), clean(1, 2e4, 100)]), vec![]);
        // Epoch 3: still quiet — floored, actives take the slack.
        let acts = c.plan(&[clean(0, 3e4, 300), clean(1, 3e4, 100)]);
        assert_eq!(acts, vec![Action::SetWeights { weights: vec![0.95, 0.05] }]);
        // Tenant 1 wakes up: base weights restored immediately.
        let acts = c.plan(&[clean(0, 4e4, 400), clean(1, 4e4, 150)]);
        assert_eq!(acts, vec![Action::SetWeights { weights: vec![0.5, 0.5] }]);
        // Everyone idle past the dwell: base weights kept (nothing to
        // reclaim toward), so the converged loop goes silent.
        for k in 5..8 {
            let cy = k as f64 * 1e4;
            assert_eq!(c.plan(&[clean(0, cy, 400), clean(1, cy, 150)]), vec![]);
        }
    }

    #[test]
    fn share_rebalance_is_inert_under_strict_sharing() {
        let mut c = AdaptiveController::new(spec(), SharingMode::Strict, &shares(2));
        let acts = c.plan(&[clean(0, 1e4, 100), clean(1, 1e4, 100)]);
        let acts2 = c.plan(&[clean(0, 2e4, 200), clean(1, 2e4, 100)]);
        for a in acts.iter().chain(&acts2) {
            assert!(!matches!(a, Action::SetWeights { .. }), "{a:?}");
        }
    }

    /// Satellite: randomized observation streams through the seed-replay
    /// harness.  Identical streams must produce identical action
    /// sequences (determinism), and no action may ever leave its law's
    /// registry-declared bounds.
    #[test]
    fn fuzz_controller_is_deterministic_and_bounded() {
        use crate::policy::adaptive::control_law;
        let ratio_law = control_law("ratio-tune").unwrap();
        let share_law = control_law("share-rebalance").unwrap();
        crate::util::proptest::check(0xC0_11, 60, |rng| {
            let n = 2 + rng.index(3); // 2..=4 tenants
            let modules = 1 + rng.index(2);
            let epochs = 5 + rng.index(40);
            let sharing = if rng.below(2) == 0 {
                SharingMode::Strict
            } else {
                SharingMode::WorkConserving
            };
            // Pre-generate the whole observation stream so two fresh
            // controllers replay the exact same inputs.
            let mut bytes = vec![0u64; n];
            let stream: Vec<Vec<Snapshot>> = (0..epochs)
                .map(|e| {
                    (0..n)
                        .map(|t| {
                            bytes[t] += rng.below(3) * (1 + rng.below(5000));
                            let mut s =
                                Snapshot::empty(t, (e + 1) as f64 * 25_000.0);
                            s.net_bytes_in = bytes[t];
                            s.inflight_pages = rng.index(3);
                            s.inflight_lines = rng.index(3);
                            for m in 0..modules {
                                let port = match rng.index(4) {
                                    0 => PortState::Down,
                                    1 => PortState::Recovering,
                                    _ => PortState::Up,
                                };
                                let scale =
                                    if rng.below(3) == 0 { 0.25 } else { 1.0 };
                                let mut ms = sample(port, scale);
                                ms.module = m;
                                s.modules.push(ms);
                            }
                            s
                        })
                        .collect()
                })
                .collect();
            let run = |stream: &[Vec<Snapshot>]| -> Vec<Vec<Action>> {
                let mut c = AdaptiveController::new(spec(), sharing, &shares(n));
                stream.iter().map(|obs| c.plan(obs)).collect()
            };
            let a = run(&stream);
            let b = run(&stream);
            assert_eq!(a, b, "identical streams must replay identical actions");
            // Bounds: every action inside its law's declared range.
            let mut ratio = vec![0.25; n];
            for acts in &a {
                for act in acts {
                    match act {
                        Action::SetRatio { tenant, ratio: r } => {
                            assert!(
                                (ratio_law.min..=ratio_law.max).contains(r),
                                "ratio {r} outside [{}, {}]",
                                ratio_law.min,
                                ratio_law.max
                            );
                            assert!(
                                (r - ratio[*tenant]).abs()
                                    <= ratio_law.max_step + 1e-12,
                                "ratio step {} exceeds {}",
                                (r - ratio[*tenant]).abs(),
                                ratio_law.max_step
                            );
                            ratio[*tenant] = *r;
                        }
                        Action::SetRecovery { .. } => {}
                        Action::SetWeights { weights } => {
                            assert_eq!(weights.len(), n);
                            let sum: f64 = weights.iter().sum();
                            assert!(
                                (sum - 1.0).abs() < 1e-9,
                                "weights sum {sum} != 1.0"
                            );
                            for w in weights {
                                assert!(
                                    *w >= share_law.min - 1e-12 && *w <= 1.0,
                                    "weight {w} outside [{}, 1.0]",
                                    share_law.min
                                );
                            }
                            assert_eq!(
                                sharing,
                                SharingMode::WorkConserving,
                                "share-rebalance actuated under strict sharing"
                            );
                        }
                    }
                }
            }
        });
    }
}
