//! Request-serving front-end with an SLO robustness stack: drives the
//! [`Cluster`]'s tenant machines burst-by-burst from a deterministic
//! event loop instead of a single merged trace.
//!
//! Each generated request ([`crate::workloads::service`]) arrives at its
//! open-loop cycle, is admitted or shed at a backlog watermark, and is
//! served as one access burst on the least-loaded server machine via
//! the stepping API (`begin_burst` / `prepare` / `step_next` /
//! `drain_outstanding`).  Bursts execute synchronously when an attempt
//! is issued — the server's clock advances to the burst completion, and
//! later arrivals queue behind it (FCFS per server) — while the event
//! heap keeps *decisions* (admission, hedge issue, timeout, retry,
//! completion bookkeeping) in global time order with a deterministic
//! `(cycle, sequence)` tie-break.  Fine-grained cross-server access
//! interleaving is approximated (each burst runs to completion once
//! issued), which keeps the robustness stack simple and replay-exact;
//! shared-fabric contention, disturbance schedules and fault windows
//! still apply per transfer because every burst flows through the same
//! [`RemoteMemory`](crate::system::machine::RemoteMemory) timelines.
//!
//! The robustness stack (all knobs on [`ServiceSpec`], each
//! independently inert):
//! - **Deadline + retry**: an attempt outstanding past `timeout_cycles`
//!   is abandoned at its deadline and re-issued after exponential
//!   backoff with deterministic jitter, at most `max_retries` times;
//!   exhaustion marks the request `TimedOut`.
//! - **Hedging**: once the attempt-latency histogram has enough mass, a
//!   request still outstanding at the `hedge_percentile` latency is
//!   issued a second time on another server; the first completion wins.
//! - **Load shedding**: an arrival is refused outright when even the
//!   least-loaded server's busy backlog exceeds
//!   `shed_watermark_cycles` — bounded-latency partial service instead
//!   of collapse under overload.
//!
//! Request-level results (completion/timeout/shed/retry/hedge counters
//! and the end-to-end latency histogram) are booked on **tenant 0**'s
//! [`Metrics`] — the front-end's own ledger — while each server keeps
//! its ordinary per-tenant machine metrics.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::compress::synth::Profile;
use crate::config::{ClusterConfig, ServiceSpec, SimConfig};
use crate::lifecycle::{Lifecycle, StateMachine, Transition};
use crate::metrics::Metrics;
use crate::obs::{Event, EventKind, ObsSpec, Recorder};
use crate::schemes::SchemeKind;
use crate::system::cluster::{Cluster, TenantInit};
use crate::util::rng::SplitMix;
use crate::util::stats::LogHistogram;
use crate::workloads::service::{
    backoff_delay, burst_trace, class_trace, gen_requests, Request, CLASSES,
};
use crate::workloads::Trace;

/// Request lifecycle (see DESIGN.md §"Request serving & SLO model"): a
/// request is `Admitted` on arrival, then either `Shed` at the
/// watermark or `Issued` to a server; an issued attempt may be
/// `Hedged`, complete, or time out into `Retrying`, which re-issues
/// until the retry budget exhausts into `TimedOut`.  `Completed`,
/// `TimedOut` and `Shed` are terminal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Admitted,
    Issued,
    Hedged,
    Retrying,
    Completed,
    TimedOut,
    Shed,
}

/// Edge labels for the request machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestEvent {
    /// An attempt is issued to a server (first issue or retry).
    Issue,
    /// Admission control refused the request at the backlog watermark.
    Shed,
    /// A hedged second attempt was issued for the outstanding request.
    Hedge,
    /// An attempt completed within its deadline.
    Complete,
    /// The outstanding attempt crossed its deadline.
    Timeout,
    /// The retry budget is spent.
    Exhaust,
}

impl Lifecycle for RequestState {
    type Event = RequestEvent;
    const NAME: &'static str = "service request";
    const STATES: &'static [RequestState] = &[
        RequestState::Admitted,
        RequestState::Issued,
        RequestState::Hedged,
        RequestState::Retrying,
        RequestState::Completed,
        RequestState::TimedOut,
        RequestState::Shed,
    ];
    const EVENTS: &'static [RequestEvent] = &[
        RequestEvent::Issue,
        RequestEvent::Shed,
        RequestEvent::Hedge,
        RequestEvent::Complete,
        RequestEvent::Timeout,
        RequestEvent::Exhaust,
    ];
    const TABLE: &'static [Transition<RequestState, RequestEvent>] = &[
        Transition {
            from: RequestState::Admitted,
            event: RequestEvent::Issue,
            to: RequestState::Issued,
        },
        Transition {
            from: RequestState::Admitted,
            event: RequestEvent::Shed,
            to: RequestState::Shed,
        },
        Transition {
            from: RequestState::Issued,
            event: RequestEvent::Hedge,
            to: RequestState::Hedged,
        },
        Transition {
            from: RequestState::Issued,
            event: RequestEvent::Complete,
            to: RequestState::Completed,
        },
        Transition {
            from: RequestState::Issued,
            event: RequestEvent::Timeout,
            to: RequestState::Retrying,
        },
        Transition {
            from: RequestState::Hedged,
            event: RequestEvent::Complete,
            to: RequestState::Completed,
        },
        Transition {
            from: RequestState::Hedged,
            event: RequestEvent::Timeout,
            to: RequestState::Retrying,
        },
        Transition {
            from: RequestState::Retrying,
            event: RequestEvent::Issue,
            to: RequestState::Issued,
        },
        Transition {
            from: RequestState::Retrying,
            event: RequestEvent::Complete,
            to: RequestState::Completed,
        },
        Transition {
            from: RequestState::Retrying,
            event: RequestEvent::Exhaust,
            to: RequestState::TimedOut,
        },
    ];

    fn state_name(self) -> &'static str {
        match self {
            RequestState::Admitted => "Admitted",
            RequestState::Issued => "Issued",
            RequestState::Hedged => "Hedged",
            RequestState::Retrying => "Retrying",
            RequestState::Completed => "Completed",
            RequestState::TimedOut => "TimedOut",
            RequestState::Shed => "Shed",
        }
    }

    fn event_name(event: RequestEvent) -> &'static str {
        match event {
            RequestEvent::Issue => "Issue",
            RequestEvent::Shed => "Shed",
            RequestEvent::Hedge => "Hedge",
            RequestEvent::Complete => "Complete",
            RequestEvent::Timeout => "Timeout",
            RequestEvent::Exhaust => "Exhaust",
        }
    }
}

/// A scheduled front-end decision.
#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Request arrival: admission check, then first issue.
    Arrive(usize),
    /// Retry issue after backoff (first issues happen inline at arrive).
    Issue(usize),
    /// Hedged second issue for a still-outstanding request.
    HedgeIssue(usize),
    /// Deadline of attempt number `.1` (1-based) of request `.0`.
    Timeout(usize, u32),
    /// An attempt finished within its deadline; `hedged` marks which
    /// attempt so hedge wins are counted at completion.
    Complete { req: usize, hedged: bool },
}

/// Heap key: cycle with an insertion-sequence tie-break, so identical
/// timestamps process in scheduling order on every run and job count.
#[derive(Clone, Copy, Debug)]
struct Pending {
    at: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at).is_eq() && self.seq == other.seq
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.total_cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Per-request runtime bookkeeping around the lifecycle machine.
struct ReqCtl {
    sm: StateMachine<RequestState>,
    arrive: f64,
    class_idx: usize,
    /// Start index of this request's burst window in its class trace.
    window: usize,
    /// Attempts issued so far (retries re-issue the same window).
    attempts: u32,
    hedged: bool,
    /// Terminal (Completed / TimedOut / Shed) — stale events skip.
    done: bool,
    last_server: usize,
}

/// The event-loop driver.  Owns the request ledger and the per-server
/// busy horizon; borrows the cluster per dispatched event.
struct Driver<'a> {
    spec: &'a ServiceSpec,
    class_traces: &'a [Trace],
    reqs: Vec<ReqCtl>,
    /// Per-server clock horizon: when its last drained burst completes.
    busy: Vec<f64>,
    heap: BinaryHeap<Reverse<Pending>>,
    seq: u64,
    jitter: SplitMix,
    /// Observed per-attempt latencies — the hedge-threshold input.
    attempt_hist: LogHistogram,
    /// End-to-end latency of completed requests (arrival → completion).
    request_hist: LogHistogram,
    completed: u64,
    timed_out: u64,
    shed: u64,
    retries: u64,
    hedges: u64,
    hedge_wins: u64,
    slo_good: u64,
}

impl<'a> Driver<'a> {
    fn new(
        spec: &'a ServiceSpec,
        class_traces: &'a [Trace],
        requests: &[Request],
        servers: usize,
    ) -> Driver<'a> {
        let root = SplitMix::new(spec.seed);
        let mut windows = root.split(3);
        let mut d = Driver {
            spec,
            class_traces,
            reqs: Vec::with_capacity(requests.len()),
            busy: vec![0.0; servers],
            heap: BinaryHeap::with_capacity(requests.len() * 2),
            seq: 0,
            jitter: root.split(4),
            attempt_hist: LogHistogram::new(),
            request_hist: LogHistogram::new(),
            completed: 0,
            timed_out: 0,
            shed: 0,
            retries: 0,
            hedges: 0,
            hedge_wins: 0,
            slo_good: 0,
        };
        for r in requests {
            let class_idx = r.class as usize;
            d.reqs.push(ReqCtl {
                sm: StateMachine::new(RequestState::Admitted),
                arrive: r.at,
                class_idx,
                window: windows.index(class_traces[class_idx].accesses.len()),
                attempts: 0,
                hedged: false,
                done: false,
                last_server: 0,
            });
            d.push(r.at, Ev::Arrive(r.id));
        }
        d
    }

    fn push(&mut self, at: f64, ev: Ev) {
        self.heap.push(Reverse(Pending { at, seq: self.seq, ev }));
        self.seq += 1;
    }

    /// Least-loaded server (tie: lowest index), optionally excluding
    /// one — the hedge goes to a *different* server when there is one.
    fn pick_server(&self, exclude: Option<usize>) -> usize {
        let mut best = usize::MAX;
        let mut best_busy = f64::INFINITY;
        for (s, &b) in self.busy.iter().enumerate() {
            if Some(s) == exclude && self.busy.len() > 1 {
                continue;
            }
            if b < best_busy {
                best = s;
                best_busy = b;
            }
        }
        best
    }

    /// Hedge threshold: the configured percentile of observed attempt
    /// latencies, once the histogram carries enough mass to be a
    /// threshold at all.
    fn hedge_delay(&self) -> Option<f64> {
        (self.spec.has_hedge() && self.attempt_hist.total >= 16)
            .then(|| self.attempt_hist.value_at(self.spec.hedge_percentile))
    }

    /// Execute one burst attempt on `server` starting no earlier than
    /// `now`: rewind the machine's cursors, run the request window
    /// through the stepping API over the shared remote memory, drain,
    /// and advance the server's busy horizon to the completion cycle.
    fn run_burst(&mut self, cluster: &mut Cluster, server: usize, r: usize, now: f64) -> f64 {
        let req = &self.reqs[r];
        let burst = burst_trace(
            &self.class_traces[req.class_idx],
            req.window,
            self.spec.burst_accesses,
        );
        let start = now.max(self.busy[server]);
        let (m, remote) = cluster.tenant_remote(server);
        m.begin_burst(start);
        let traces = [burst];
        m.prepare(&traces);
        while m.step_next(remote, &traces) {}
        let done = m.drain_outstanding();
        self.busy[server] = done;
        done
    }

    /// Record a request-lifecycle observability event on the front-end
    /// ledger (tenant 0); `page` carries the request id.
    fn emit(&mut self, cluster: &mut Cluster, kind: EventKind, r: usize, at: f64) {
        let (m, _) = cluster.tenant_remote(0);
        if let Some(rec) = m.obs_mut() {
            rec.event(Event::instant(kind, 0, None, r as u64, at));
        }
    }

    /// Issue one attempt (first or retry) at `now`: run the burst, then
    /// schedule the outcome — completion within the deadline, or the
    /// deadline itself — plus a hedge probe when the stack hedges.
    fn issue_attempt(&mut self, cluster: &mut Cluster, r: usize, now: f64) {
        self.reqs[r].attempts += 1;
        let attempt = self.reqs[r].attempts;
        let server = self.pick_server(None);
        self.reqs[r].last_server = server;
        // The hedge threshold is read before this attempt reports, i.e.
        // from exactly the history available at issue time.
        let hedge_at = self.hedge_delay().map(|d| now + d);
        let done_at = self.run_burst(cluster, server, r, now);
        let lat = done_at - now;
        self.attempt_hist.add(lat);
        let t = self.spec.timeout_cycles;
        if self.spec.has_timeouts() && lat > t {
            self.push(now + t, Ev::Timeout(r, attempt));
        } else {
            self.push(done_at, Ev::Complete { req: r, hedged: false });
        }
        if let Some(h) = hedge_at {
            // Hedge only while the attempt is still outstanding and the
            // probe would fire before its deadline abandons it anyway.
            if !self.reqs[r].hedged && done_at > h && (!self.spec.has_timeouts() || h < now + t)
            {
                self.push(h, Ev::HedgeIssue(r));
            }
        }
    }

    fn dispatch(&mut self, cluster: &mut Cluster, p: Pending) {
        match p.ev {
            Ev::Arrive(r) => {
                if self.spec.has_shed() {
                    // Watermark rule: refuse when even the least-loaded
                    // server is busy past the watermark beyond now.
                    let least = self.busy[self.pick_server(None)];
                    if least - p.at > self.spec.shed_watermark_cycles {
                        self.reqs[r].sm.transition(RequestEvent::Shed);
                        self.reqs[r].done = true;
                        self.shed += 1;
                        self.emit(cluster, EventKind::Shed, r, p.at);
                        return;
                    }
                }
                self.reqs[r].sm.transition(RequestEvent::Issue);
                self.issue_attempt(cluster, r, p.at);
            }
            Ev::Issue(r) => {
                if self.reqs[r].done {
                    return;
                }
                self.reqs[r].sm.transition(RequestEvent::Issue);
                self.retries += 1;
                self.emit(cluster, EventKind::Retry, r, p.at);
                self.issue_attempt(cluster, r, p.at);
            }
            Ev::HedgeIssue(r) => {
                if self.reqs[r].done
                    || self.reqs[r].hedged
                    || self.reqs[r].sm.state() != RequestState::Issued
                {
                    return;
                }
                self.reqs[r].sm.transition(RequestEvent::Hedge);
                self.reqs[r].hedged = true;
                self.hedges += 1;
                self.emit(cluster, EventKind::Hedge, r, p.at);
                let exclude =
                    (self.busy.len() > 1).then_some(self.reqs[r].last_server);
                let server = self.pick_server(exclude);
                let done_at = self.run_burst(cluster, server, r, p.at);
                let lat = done_at - p.at;
                self.attempt_hist.add(lat);
                if !self.spec.has_timeouts() || lat <= self.spec.timeout_cycles {
                    self.push(done_at, Ev::Complete { req: r, hedged: true });
                }
            }
            Ev::Timeout(r, attempt) => {
                if self.reqs[r].done || self.reqs[r].attempts != attempt {
                    return;
                }
                self.reqs[r].sm.transition(RequestEvent::Timeout);
                if self.reqs[r].attempts <= self.spec.max_retries {
                    let d = backoff_delay(
                        self.spec.backoff_base_cycles,
                        self.spec.backoff_cap_cycles,
                        self.spec.jitter_frac,
                        self.reqs[r].attempts - 1,
                        &mut self.jitter,
                    );
                    self.push(p.at + d, Ev::Issue(r));
                } else {
                    self.reqs[r].sm.transition(RequestEvent::Exhaust);
                    self.reqs[r].done = true;
                    self.timed_out += 1;
                }
            }
            Ev::Complete { req: r, hedged } => {
                if self.reqs[r].done {
                    return;
                }
                self.reqs[r].sm.transition(RequestEvent::Complete);
                self.reqs[r].done = true;
                self.completed += 1;
                let lat = p.at - self.reqs[r].arrive;
                self.request_hist.add(lat);
                if lat <= self.spec.slo_cycles {
                    self.slo_good += 1;
                }
                if hedged {
                    self.hedge_wins += 1;
                }
            }
        }
    }

    /// Drain the event heap, finalize every server, and fold the
    /// request ledger into tenant 0's metrics.
    fn run(mut self, cluster: &mut Cluster) -> Vec<Metrics> {
        while let Some(Reverse(p)) = self.heap.pop() {
            self.dispatch(cluster, p);
        }
        debug_assert!(self.reqs.iter().all(|r| r.done), "request leaked the event loop");
        let mut metrics = cluster.finish_all();
        let front = &mut metrics[0];
        front.requests_completed = self.completed;
        front.requests_timed_out = self.timed_out;
        front.requests_shed = self.shed;
        front.request_retries = self.retries;
        front.request_hedges = self.hedges;
        front.request_hedge_wins = self.hedge_wins;
        front.requests_slo_good = self.slo_good;
        front.request_hist = self.request_hist;
        metrics
    }
}

/// Build and run a service cell: one server [`Machine`] per `(name,
/// scheme)` tenant over the shared fabric described by `ccfg`, serving
/// `spec`'s request stream.  `fetch` resolves the three request
/// classes' base workloads; tenant names only label servers.  Returns
/// per-tenant metrics with the request ledger on tenant 0 — the
/// orchestrator's service-cell execution path.
///
/// [`Machine`]: crate::system::machine::Machine
pub fn run_service(
    ccfg: &ClusterConfig,
    base_cfg: &SimConfig,
    tenants: &[(String, SchemeKind)],
    spec: &ServiceSpec,
    fetch: impl Fn(&str) -> (Arc<Trace>, Profile),
) -> Vec<Metrics> {
    run_service_obs(ccfg, base_cfg, tenants, spec, fetch, None).0
}

/// [`run_service`] with optional observability: every server gets its
/// own recorder; request-lifecycle events (Retry / Hedge / Shed) land
/// on tenant 0's.
pub fn run_service_obs(
    ccfg: &ClusterConfig,
    base_cfg: &SimConfig,
    tenants: &[(String, SchemeKind)],
    spec: &ServiceSpec,
    fetch: impl Fn(&str) -> (Arc<Trace>, Profile),
    obs: Option<&ObsSpec>,
) -> (Vec<Metrics>, Vec<Recorder>) {
    assert!(spec.requests > 0, "a service run needs requests");
    assert!(spec.burst_accesses > 0, "a request burst needs accesses");
    let mut class_traces = Vec::with_capacity(CLASSES.len());
    let mut class_profiles = Vec::with_capacity(CLASSES.len());
    for c in CLASSES {
        let (base, profile) = fetch(c.base_workload());
        class_traces.push(class_trace(&base, c));
        class_profiles.push(profile);
    }
    // One address region per class on every server, so the local store
    // is sized for the union of what the request mix can touch.
    let footprint: usize = class_traces.iter().map(|t| t.footprint_pages).sum();
    let cores = base_cfg.cores.max(1);
    let inits: Vec<TenantInit> = tenants
        .iter()
        .map(|(_, kind)| TenantInit {
            cfg: base_cfg.clone(),
            kind: *kind,
            footprint_pages: footprint,
            profiles: (0..cores).map(|i| class_profiles[i % class_profiles.len()]).collect(),
            oracle: None,
        })
        .collect();
    let mut cluster = Cluster::new(ccfg, inits);
    if let Some(s) = obs {
        for t in 0..cluster.tenants() {
            cluster.set_obs(t, Recorder::new(*s));
        }
    }
    let requests = gen_requests(spec);
    let driver = Driver::new(spec, &class_traces, &requests, cluster.tenants());
    let metrics = driver.run(&mut cluster);
    let recorders = (0..cluster.tenants()).filter_map(|t| cluster.take_obs(t)).collect();
    (metrics, recorders)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrivalPattern;
    use crate::system::fault::FaultPlan;
    use crate::workloads::{by_name, Scale};

    fn fetch_test(wl: &str) -> (Arc<Trace>, Profile) {
        let w = by_name(wl).unwrap();
        (Arc::new(w.generate(11, Scale::Test).truncated(20_000)), w.profile())
    }

    fn servers(n: usize, kind: SchemeKind) -> Vec<(String, SchemeKind)> {
        (0..n).map(|i| (format!("srv{i}"), kind)).collect()
    }

    fn base_spec() -> ServiceSpec {
        ServiceSpec::naive(ArrivalPattern::Steady, 120, 150, 40_000.0, 1.0, 400_000.0)
    }

    fn run_json(spec: &ServiceSpec) -> String {
        let ccfg = ClusterConfig::new(2);
        let cfg = SimConfig::test_scale();
        let ms = run_service(&ccfg, &cfg, &servers(2, SchemeKind::Daemon), spec, fetch_test);
        ms.iter().map(|m| m.to_json().to_string()).collect::<Vec<_>>().join("\n")
    }

    #[test]
    fn service_runs_repeat_byte_identically() {
        let spec = base_spec().with_retry(120_000.0, 2, 10_000.0, 80_000.0, 0.3);
        assert_eq!(run_json(&spec), run_json(&spec), "service replay diverged");
    }

    #[test]
    fn every_request_reaches_a_terminal_state() {
        let ccfg = ClusterConfig::new(2);
        let cfg = SimConfig::test_scale();
        let spec = base_spec()
            .with_retry(100_000.0, 1, 10_000.0, 40_000.0, 0.2)
            .with_hedge(0.95)
            .with_shed(600_000.0);
        let ms =
            run_service(&ccfg, &cfg, &servers(2, SchemeKind::Daemon), &spec, fetch_test);
        let front = &ms[0];
        assert_eq!(
            front.requests_completed + front.requests_timed_out + front.requests_shed,
            spec.requests as u64,
            "request ledger does not cover every request"
        );
        assert_eq!(front.request_hist.total, front.requests_completed);
        assert!(front.requests_slo_good <= front.requests_completed);
        assert!(front.request_hedge_wins <= front.request_hedges);
        // Servers did real memory work.
        assert!(ms.iter().all(|m| m.instructions > 0));
    }

    #[test]
    fn naive_stack_never_times_out_or_sheds() {
        let ccfg = ClusterConfig::new(2);
        let cfg = SimConfig::test_scale();
        let ms = run_service(
            &ccfg,
            &cfg,
            &servers(2, SchemeKind::Daemon),
            &base_spec(),
            fetch_test,
        );
        let front = &ms[0];
        assert_eq!(front.requests_completed, 120);
        assert_eq!(front.requests_timed_out, 0);
        assert_eq!(front.requests_shed, 0);
        assert_eq!(front.request_retries, 0);
        assert_eq!(front.request_hedges, 0);
    }

    #[test]
    fn shedding_bounds_the_backlog_under_a_crash() {
        // One memory module, crashed for the first 3e5 cycles under
        // Stall recovery: every early burst stalls to the crash end, so
        // the backlog watermark is guaranteed to trip — no dependence
        // on estimated service times.
        use crate::system::fault::RecoveryPolicy;
        let ccfg = ClusterConfig::new(1)
            .with_faults(FaultPlan::new().module_crash(0, 0.0, 3e5))
            .with_recovery(RecoveryPolicy::Stall);
        let cfg = SimConfig::test_scale();
        let mut spec = ServiceSpec::naive(ArrivalPattern::Steady, 120, 150, 8_000.0, 2.0, 200_000.0);
        spec.seed = 0xDAE_51;
        let naive =
            run_service(&ccfg, &cfg, &servers(2, SchemeKind::Daemon), &spec, fetch_test);
        let shed_spec = spec
            .with_retry(120_000.0, 2, 10_000.0, 40_000.0, 0.2)
            .with_shed(60_000.0);
        let shedding = run_service(
            &ccfg,
            &cfg,
            &servers(2, SchemeKind::Daemon),
            &shed_spec,
            fetch_test,
        );
        assert!(shedding[0].requests_shed > 0, "crash backlog never hit the watermark");
        // Naive serving never refuses or abandons anything — it pays
        // with unbounded queueing instead — while the shed stack keeps
        // the offered ledger complete.
        assert_eq!(naive[0].requests_completed, spec.requests as u64);
        assert_eq!(
            shedding[0].requests_completed
                + shedding[0].requests_timed_out
                + shedding[0].requests_shed,
            spec.requests as u64
        );
        // The shed stack's completions all beat the watermark+timeout
        // bound, so its observed p99 cannot exceed naive's crash-era
        // queueing tail.
        assert!(
            shedding[0].request_hist.value_at(0.99)
                <= naive[0].request_hist.value_at(0.99),
            "bounded stack p99 {} must not exceed naive p99 {}",
            shedding[0].request_hist.value_at(0.99),
            naive[0].request_hist.value_at(0.99)
        );
    }

    #[test]
    fn module_crash_with_retries_still_terminates() {
        // A crash window across the run start: requests during the
        // outage retry/time out, the run still drains deterministically.
        let ccfg = ClusterConfig::new(2)
            .with_faults(FaultPlan::new().module_crash(0, 0.0, 3e5));
        let cfg = SimConfig::test_scale();
        let spec = base_spec().with_retry(150_000.0, 2, 20_000.0, 100_000.0, 0.25);
        let ms =
            run_service(&ccfg, &cfg, &servers(2, SchemeKind::Daemon), &spec, fetch_test);
        let front = &ms[0];
        assert_eq!(
            front.requests_completed + front.requests_timed_out + front.requests_shed,
            spec.requests as u64
        );
    }

    #[test]
    fn request_events_land_on_the_front_ledger() {
        // The crashed window forces early attempts past their deadline,
        // so at least one Retry (and with the tight watermark, Shed)
        // event is guaranteed on the ledger.
        let ccfg = ClusterConfig::new(1)
            .with_faults(FaultPlan::new().module_crash(0, 0.0, 2e5));
        let cfg = SimConfig::test_scale();
        let mut spec = base_spec().with_retry(60_000.0, 2, 10_000.0, 40_000.0, 0.2);
        spec.load = 5.0;
        let spec = spec.with_hedge(0.90).with_shed(100_000.0);
        let (ms, recs) = run_service_obs(
            &ccfg,
            &cfg,
            &servers(2, SchemeKind::Daemon),
            &spec,
            fetch_test,
            Some(&ObsSpec::enabled()),
        );
        let lifecycle: Vec<EventKind> = recs[0]
            .trace
            .events()
            .filter(|e| {
                matches!(e.kind, EventKind::Retry | EventKind::Hedge | EventKind::Shed)
            })
            .map(|e| e.kind)
            .collect();
        let front = &ms[0];
        let counted = front.request_retries + front.request_hedges + front.requests_shed;
        assert_eq!(lifecycle.len() as u64, counted, "events must mirror the counters");
        assert!(counted > 0, "overload run produced no lifecycle events");
    }
}
