//! Local memory of a compute component (§2.1).
//!
//! A page-granularity store sized to ~20% of the working set, treated as an
//! inclusive cache of remote memory with a local virtual→physical mapping
//! (MIND-style, the paper's assumed option).  Supports approximate-LRU and
//! FIFO replacement (Fig. 16), dirty bits, and "installed_at" times so a
//! page scheduled by DaeMon only serves requests after it arrives.
//!
//! Replacement is an intrusive doubly-linked recency list threaded through
//! a slab of nodes (`prev`/`next` are slab indices), with an Fx-hashed
//! page→slot index: access-touch, install and evict are all O(1).  The
//! seed design kept a lazy-deleted `VecDeque` of (stamp, page) pairs that
//! grew by one entry per LRU touch until an eviction drained the stale
//! prefix — the list replaces it with the same victim semantics:
//!
//! * LRU: hits on resident pages move the node to the MRU tail; the
//!   victim is the head (least recently touched).
//! * FIFO: nothing moves on a hit; list order is ascending install stamp
//!   and the victim is the oldest resident install.  A page removed via
//!   [`LocalMemory::remove`] (invalidation) keeps its stamp: reinstalling
//!   it re-enters the queue at its original position, matching the seed's
//!   lazy queue, whose stale entry survived the removal and would have
//!   evicted the page where it first installed.  Eviction retires the
//!   stamp, so an evicted page re-enters at the back.
//!
//! The equivalence is pinned by `matches_naive_reference_model_property`
//! against a brute-force model.

use crate::config::Replacement;
use crate::util::hash::FxHashMap;

/// Slab null index.
const NIL: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct Node {
    page: u64,
    dirty: bool,
    /// Simulation time at which the page's data is resident.
    installed_at: f64,
    /// Monotone install stamp; under FIFO the list is kept in ascending
    /// stamp order and the stamp survives [`LocalMemory::remove`].
    stamp: u64,
    prev: u32,
    next: u32,
}

pub struct LocalMemory {
    capacity_pages: usize,
    /// page → slab slot of its node.
    index: FxHashMap<u64, u32>,
    slab: Vec<Node>,
    /// Recycled slab slots (bounded by capacity, so the slab never grows
    /// past capacity + 1 nodes).
    free: Vec<u32>,
    /// Least-recently-used end (eviction victim).
    head: u32,
    /// Most-recently-used end.
    tail: u32,
    /// Next fresh install stamp.
    next_stamp: u64,
    /// FIFO only: stamps of pages removed via [`LocalMemory::remove`],
    /// restored if the page is reinstalled (bounded by distinct removed
    /// pages; `remove` has no hot simulation callers).
    removed: FxHashMap<u64, u64>,
    policy: Replacement,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Result of an eviction: the victim page and whether it was dirty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Evicted {
    pub page: u64,
    pub dirty: bool,
}

impl LocalMemory {
    pub fn new(capacity_pages: usize, policy: Replacement) -> Self {
        Self {
            capacity_pages: capacity_pages.max(1),
            index: FxHashMap::default(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            next_stamp: 0,
            removed: FxHashMap::default(),
            policy,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_pages
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Unlink slot `i` from the recency list (does not free it).
    #[inline]
    fn unlink(&mut self, i: u32) {
        let Node { prev, next, .. } = self.slab[i as usize];
        match prev {
            NIL => self.head = next,
            p => self.slab[p as usize].next = next,
        }
        match next {
            NIL => self.tail = prev,
            n => self.slab[n as usize].prev = prev,
        }
    }

    /// Link slot `i` at the MRU tail.
    #[inline]
    fn push_tail(&mut self, i: u32) {
        self.slab[i as usize].prev = self.tail;
        self.slab[i as usize].next = NIL;
        match self.tail {
            NIL => self.head = i,
            t => self.slab[t as usize].next = i,
        }
        self.tail = i;
    }

    /// Link slot `i` into the list in ascending stamp order (FIFO).
    fn push_sorted(&mut self, i: u32) {
        let stamp = self.slab[i as usize].stamp;
        // Fast path: a fresh stamp is the newest and goes to the tail.
        if self.tail == NIL || self.slab[self.tail as usize].stamp <= stamp {
            self.push_tail(i);
            return;
        }
        // Reinstall with a preserved (older) stamp: walk from the head to
        // the first resident with a newer stamp and insert before it.
        let mut j = self.head;
        while self.slab[j as usize].stamp < stamp {
            j = self.slab[j as usize].next;
        }
        let prev = self.slab[j as usize].prev;
        self.slab[i as usize].prev = prev;
        self.slab[i as usize].next = j;
        self.slab[j as usize].prev = i;
        match prev {
            NIL => self.head = i,
            p => self.slab[p as usize].next = i,
        }
    }

    /// Is `page` resident (data arrived) at time `now`?
    pub fn present(&self, page: u64, now: f64) -> bool {
        self.index
            .get(&page)
            .map(|&i| self.slab[i as usize].installed_at <= now)
            .unwrap_or(false)
    }

    /// Access `page` at `now`; returns true on hit.  Touches recency under
    /// LRU (FIFO order is insertion-only).
    pub fn access(&mut self, page: u64, write: bool, now: f64) -> bool {
        if let Some(&i) = self.index.get(&page) {
            if self.slab[i as usize].installed_at <= now {
                self.slab[i as usize].dirty |= write;
                if self.policy == Replacement::Lru && self.tail != i {
                    self.unlink(i);
                    self.push_tail(i);
                }
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Install `page` arriving at time `installed_at`.  Returns the evicted
    /// victim if capacity was exceeded.  Installing an already-present page
    /// refreshes its arrival time only if earlier data was still in flight.
    pub fn install(&mut self, page: u64, installed_at: f64) -> Option<Evicted> {
        if let Some(&i) = self.index.get(&page) {
            let at = &mut self.slab[i as usize].installed_at;
            *at = at.min(installed_at);
            return None;
        }
        let mut victim = None;
        if self.index.len() >= self.capacity_pages {
            victim = self.evict();
        }
        let preserved = if self.policy == Replacement::Fifo {
            self.removed.remove(&page)
        } else {
            None
        };
        let stamp = preserved.unwrap_or_else(|| {
            let s = self.next_stamp;
            self.next_stamp += 1;
            s
        });
        let node = Node { page, dirty: false, installed_at, stamp, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.slab[i as usize] = node;
                i
            }
            None => {
                self.slab.push(node);
                (self.slab.len() - 1) as u32
            }
        };
        self.index.insert(page, i);
        if self.policy == Replacement::Fifo {
            self.push_sorted(i);
        } else {
            self.push_tail(i);
        }
        victim
    }

    /// Mark a page dirty (e.g. dirty-line flush from the DaeMon dirty
    /// buffer after the page arrives).
    pub fn mark_dirty(&mut self, page: u64) {
        if let Some(&i) = self.index.get(&page) {
            self.slab[i as usize].dirty = true;
        }
    }

    /// Remove a specific page (invalidate).  Under FIFO the page's install
    /// stamp is preserved: a later reinstall re-enters the queue at its
    /// original position rather than at the back.
    pub fn remove(&mut self, page: u64) -> Option<Evicted> {
        let i = self.index.remove(&page)?;
        self.unlink(i);
        self.free.push(i);
        let n = self.slab[i as usize];
        if self.policy == Replacement::Fifo {
            self.removed.insert(page, n.stamp);
        }
        Some(Evicted { page, dirty: n.dirty })
    }

    fn evict(&mut self) -> Option<Evicted> {
        let i = self.head;
        if i == NIL {
            return None;
        }
        let n = self.slab[i as usize];
        self.unlink(i);
        self.free.push(i);
        self.index.remove(&n.page);
        self.evictions += 1;
        Some(Evicted { page: n.page, dirty: n.dirty })
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_after_install() {
        let mut m = LocalMemory::new(4, Replacement::Lru);
        assert!(!m.access(1, false, 0.0));
        m.install(1, 10.0);
        assert!(!m.present(1, 5.0), "not arrived yet");
        assert!(m.present(1, 10.0));
        assert!(m.access(1, false, 11.0));
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = LocalMemory::new(2, Replacement::Lru);
        m.install(1, 0.0);
        m.install(2, 0.0);
        m.access(1, false, 1.0); // 1 is now MRU
        let ev = m.install(3, 2.0).unwrap();
        assert_eq!(ev.page, 2);
        assert!(m.present(1, 2.0) && m.present(3, 2.0));
    }

    #[test]
    fn fifo_evicts_first_installed_regardless_of_touches() {
        let mut m = LocalMemory::new(2, Replacement::Fifo);
        m.install(1, 0.0);
        m.install(2, 0.0);
        m.access(1, false, 1.0); // touching must not save page 1 under FIFO
        let ev = m.install(3, 2.0).unwrap();
        assert_eq!(ev.page, 1);
    }

    #[test]
    fn dirty_propagates_to_eviction() {
        let mut m = LocalMemory::new(1, Replacement::Lru);
        m.install(1, 0.0);
        m.access(1, true, 1.0);
        let ev = m.install(2, 2.0).unwrap();
        assert_eq!(ev, Evicted { page: 1, dirty: true });
    }

    #[test]
    fn mark_dirty_externally() {
        let mut m = LocalMemory::new(1, Replacement::Lru);
        m.install(1, 0.0);
        m.mark_dirty(1);
        let ev = m.install(2, 1.0).unwrap();
        assert!(ev.dirty);
    }

    #[test]
    fn reinstall_keeps_earliest_arrival() {
        let mut m = LocalMemory::new(2, Replacement::Lru);
        m.install(1, 10.0);
        m.install(1, 5.0);
        assert!(m.present(1, 6.0));
    }

    #[test]
    fn remove_unlinks_and_recycles() {
        let mut m = LocalMemory::new(2, Replacement::Lru);
        m.install(1, 0.0);
        m.install(2, 0.0);
        assert_eq!(m.remove(1), Some(Evicted { page: 1, dirty: false }));
        assert_eq!(m.remove(1), None, "double remove");
        assert_eq!(m.len(), 1);
        // Capacity freed: two more installs evict only page 2.
        m.install(3, 1.0);
        let ev = m.install(4, 2.0).unwrap();
        assert_eq!(ev.page, 2);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn fifo_remove_then_reinstall_keeps_original_position() {
        let mut m = LocalMemory::new(3, Replacement::Fifo);
        m.install(1, 0.0);
        m.install(2, 0.0);
        m.install(3, 0.0);
        m.remove(2);
        m.install(4, 1.0);
        // Reinstalling 2 restores its stamp: it slots back in ahead of 3
        // and 4, so it (not 3) is the next victim after 1.
        assert_eq!(m.install(2, 2.0).unwrap().page, 1);
        let ev = m.install(5, 3.0).unwrap();
        assert_eq!(ev.page, 2, "reinstalled page lost its FIFO position");
        // Eviction retires the stamp: a fresh install of 2 joins the back,
        // so the next victim is 4, not 2.
        assert_eq!(m.install(2, 4.0).unwrap().page, 3);
        let ev = m.install(6, 5.0).unwrap();
        assert_eq!(ev.page, 4, "evicted page kept a stale stamp");
    }

    #[test]
    fn capacity_never_exceeded_property() {
        crate::util::proptest::check(0x10CA1, 30, |rng| {
            let cap = 1 + rng.index(8);
            let policy = if rng.chance(0.5) {
                Replacement::Lru
            } else {
                Replacement::Fifo
            };
            let mut m = LocalMemory::new(cap, policy);
            for t in 0..300u64 {
                let page = rng.below(32);
                if rng.chance(0.5) {
                    m.access(page, rng.chance(0.3), t as f64);
                } else {
                    m.install(page, t as f64);
                }
                assert!(m.len() <= cap, "len {} > cap {cap}", m.len());
                assert!(m.slab.len() <= cap + 1, "slab leak: {}", m.slab.len());
            }
        });
    }

    #[test]
    fn eviction_victims_were_resident_property() {
        crate::util::proptest::check(0x10CA2, 20, |rng| {
            let mut m = LocalMemory::new(4, Replacement::Lru);
            let mut resident: crate::util::hash::FxHashSet<u64> =
                crate::util::hash::FxHashSet::default();
            for t in 0..200u64 {
                let page = rng.below(16);
                if let Some(ev) = m.install(page, t as f64) {
                    assert!(resident.remove(&ev.page), "phantom victim {}", ev.page);
                }
                resident.insert(page);
            }
        });
    }

    /// Brute-force reference model: a plain `Vec` ordered LRU→MRU with
    /// linear scans — the semantics the intrusive list must reproduce
    /// exactly (victim identity, dirty bit, arrival gating, counters).
    struct NaiveLocal {
        cap: usize,
        policy: Replacement,
        /// (page, dirty, installed_at, stamp), index 0 = next victim;
        /// FIFO keeps ascending stamp order, LRU keeps recency order.
        entries: Vec<(u64, bool, f64, u64)>,
        next_stamp: u64,
        /// FIFO stamps preserved across `remove`.
        removed: FxHashMap<u64, u64>,
        hits: u64,
        misses: u64,
        evictions: u64,
    }

    impl NaiveLocal {
        fn new(cap: usize, policy: Replacement) -> Self {
            Self {
                cap,
                policy,
                entries: Vec::new(),
                next_stamp: 0,
                removed: FxHashMap::default(),
                hits: 0,
                misses: 0,
                evictions: 0,
            }
        }

        fn access(&mut self, page: u64, write: bool, now: f64) -> bool {
            if let Some(i) = self.entries.iter().position(|e| e.0 == page) {
                if self.entries[i].2 <= now {
                    self.entries[i].1 |= write;
                    if self.policy == Replacement::Lru {
                        let e = self.entries.remove(i);
                        self.entries.push(e);
                    }
                    self.hits += 1;
                    return true;
                }
            }
            self.misses += 1;
            false
        }

        fn install(&mut self, page: u64, at: f64) -> Option<Evicted> {
            if let Some(i) = self.entries.iter().position(|e| e.0 == page) {
                self.entries[i].2 = self.entries[i].2.min(at);
                return None;
            }
            let mut victim = None;
            if self.entries.len() >= self.cap {
                let (page, dirty, _, _) = self.entries.remove(0);
                self.evictions += 1;
                victim = Some(Evicted { page, dirty });
            }
            let preserved = if self.policy == Replacement::Fifo {
                self.removed.remove(&page)
            } else {
                None
            };
            let stamp = preserved.unwrap_or_else(|| {
                let s = self.next_stamp;
                self.next_stamp += 1;
                s
            });
            let pos = if self.policy == Replacement::Fifo {
                self.entries.iter().position(|e| e.3 > stamp).unwrap_or(self.entries.len())
            } else {
                self.entries.len()
            };
            self.entries.insert(pos, (page, false, at, stamp));
            victim
        }

        fn remove(&mut self, page: u64) -> Option<Evicted> {
            let i = self.entries.iter().position(|e| e.0 == page)?;
            let (page, dirty, _, stamp) = self.entries.remove(i);
            if self.policy == Replacement::Fifo {
                self.removed.insert(page, stamp);
            }
            Some(Evicted { page, dirty })
        }
    }

    #[test]
    fn matches_naive_reference_model_property() {
        // The LRU/FIFO equivalence pin: over random access/install/remove
        // streams, every observable of the intrusive-list implementation
        // (return values, victims, counters, residency) must match the
        // naive model step for step.
        crate::util::proptest::check(0x10CA3, 40, |rng| {
            let cap = 1 + rng.index(6);
            let policy = if rng.chance(0.5) {
                Replacement::Lru
            } else {
                Replacement::Fifo
            };
            let mut fast = LocalMemory::new(cap, policy);
            let mut slow = NaiveLocal::new(cap, policy);
            for t in 0..400u64 {
                let page = rng.below(20);
                let now = t as f64;
                match rng.below(10) {
                    0 => assert_eq!(fast.remove(page), slow.remove(page), "remove {page} @ {t}"),
                    1..=4 => {
                        // Arrival times sometimes in the future to exercise
                        // the installed_at <= now gating.
                        let at = now + if rng.chance(0.3) { 5.0 } else { 0.0 };
                        assert_eq!(
                            fast.install(page, at),
                            slow.install(page, at),
                            "install {page} @ {t}"
                        );
                    }
                    5 => {
                        fast.mark_dirty(page);
                        if let Some(i) = slow.entries.iter().position(|e| e.0 == page) {
                            slow.entries[i].1 = true;
                        }
                    }
                    _ => {
                        let write = rng.chance(0.3);
                        assert_eq!(
                            fast.access(page, write, now),
                            slow.access(page, write, now),
                            "access {page} @ {t}"
                        );
                    }
                }
                assert_eq!(fast.len(), slow.entries.len(), "len @ {t}");
                assert_eq!(
                    (fast.hits, fast.misses, fast.evictions),
                    (slow.hits, slow.misses, slow.evictions),
                    "counters @ {t}"
                );
            }
            // Drain: eviction order of the survivors must agree too.
            for t in 1000..1000 + cap as u64 {
                assert_eq!(
                    fast.install(1_000_000 + t, t as f64),
                    slow.install(1_000_000 + t, t as f64),
                    "drain install @ {t}"
                );
            }
        });
    }
}
