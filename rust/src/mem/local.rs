//! Local memory of a compute component (§2.1).
//!
//! A page-granularity store sized to ~20% of the working set, treated as an
//! inclusive cache of remote memory with a local virtual→physical mapping
//! (MIND-style, the paper's assumed option).  Supports approximate-LRU and
//! FIFO replacement (Fig. 16), dirty bits, and "installed_at" times so a
//! page scheduled by DaeMon only serves requests after it arrives.

use crate::config::Replacement;
use std::collections::{HashMap, VecDeque};

#[derive(Clone, Copy, Debug)]
struct Entry {
    stamp: u64,
    dirty: bool,
    /// Simulation time at which the page's data is resident.
    installed_at: f64,
}

pub struct LocalMemory {
    capacity_pages: usize,
    entries: HashMap<u64, Entry>,
    /// Lazy-deleted recency queue: (stamp, page).
    queue: VecDeque<(u64, u64)>,
    policy: Replacement,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Result of an eviction: the victim page and whether it was dirty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Evicted {
    pub page: u64,
    pub dirty: bool,
}

impl LocalMemory {
    pub fn new(capacity_pages: usize, policy: Replacement) -> Self {
        Self {
            capacity_pages: capacity_pages.max(1),
            entries: HashMap::new(),
            queue: VecDeque::new(),
            policy,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_pages
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Is `page` resident (data arrived) at time `now`?
    pub fn present(&self, page: u64, now: f64) -> bool {
        self.entries
            .get(&page)
            .map(|e| e.installed_at <= now)
            .unwrap_or(false)
    }

    /// Access `page` at `now`; returns true on hit.  Touches recency under
    /// LRU (FIFO order is insertion-only).
    pub fn access(&mut self, page: u64, write: bool, now: f64) -> bool {
        self.tick += 1;
        let tick = self.tick;
        let policy = self.policy;
        if let Some(e) = self.entries.get_mut(&page) {
            if e.installed_at <= now {
                e.dirty |= write;
                if policy == Replacement::Lru {
                    e.stamp = tick;
                    self.queue.push_back((tick, page));
                }
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Install `page` arriving at time `installed_at`.  Returns the evicted
    /// victim if capacity was exceeded.  Installing an already-present page
    /// refreshes its arrival time only if earlier data was still in flight.
    pub fn install(&mut self, page: u64, installed_at: f64) -> Option<Evicted> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.entries.get_mut(&page) {
            e.installed_at = e.installed_at.min(installed_at);
            return None;
        }
        let mut victim = None;
        if self.entries.len() >= self.capacity_pages {
            victim = self.evict();
        }
        self.entries.insert(
            page,
            Entry { stamp: tick, dirty: false, installed_at },
        );
        self.queue.push_back((tick, page));
        victim
    }

    /// Mark a page dirty (e.g. dirty-line flush from the DaeMon dirty
    /// buffer after the page arrives).
    pub fn mark_dirty(&mut self, page: u64) {
        if let Some(e) = self.entries.get_mut(&page) {
            e.dirty = true;
        }
    }

    /// Remove a specific page (invalidate).
    pub fn remove(&mut self, page: u64) -> Option<Evicted> {
        self.entries
            .remove(&page)
            .map(|e| Evicted { page, dirty: e.dirty })
    }

    fn evict(&mut self) -> Option<Evicted> {
        // Pop lazily-deleted queue entries until one matches live state.
        while let Some((stamp, page)) = self.queue.pop_front() {
            if let Some(e) = self.entries.get(&page) {
                let current = match self.policy {
                    Replacement::Lru => e.stamp == stamp,
                    // FIFO: evict on first (oldest) queue entry for a live
                    // page — insertion order.
                    Replacement::Fifo => true,
                };
                if current {
                    let e = self.entries.remove(&page).unwrap();
                    self.evictions += 1;
                    return Some(Evicted { page, dirty: e.dirty });
                }
            }
        }
        None
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_after_install() {
        let mut m = LocalMemory::new(4, Replacement::Lru);
        assert!(!m.access(1, false, 0.0));
        m.install(1, 10.0);
        assert!(!m.present(1, 5.0), "not arrived yet");
        assert!(m.present(1, 10.0));
        assert!(m.access(1, false, 11.0));
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut m = LocalMemory::new(2, Replacement::Lru);
        m.install(1, 0.0);
        m.install(2, 0.0);
        m.access(1, false, 1.0); // 1 is now MRU
        let ev = m.install(3, 2.0).unwrap();
        assert_eq!(ev.page, 2);
        assert!(m.present(1, 2.0) && m.present(3, 2.0));
    }

    #[test]
    fn fifo_evicts_first_installed_regardless_of_touches() {
        let mut m = LocalMemory::new(2, Replacement::Fifo);
        m.install(1, 0.0);
        m.install(2, 0.0);
        m.access(1, false, 1.0); // touching must not save page 1 under FIFO
        let ev = m.install(3, 2.0).unwrap();
        assert_eq!(ev.page, 1);
    }

    #[test]
    fn dirty_propagates_to_eviction() {
        let mut m = LocalMemory::new(1, Replacement::Lru);
        m.install(1, 0.0);
        m.access(1, true, 1.0);
        let ev = m.install(2, 2.0).unwrap();
        assert_eq!(ev, Evicted { page: 1, dirty: true });
    }

    #[test]
    fn mark_dirty_externally() {
        let mut m = LocalMemory::new(1, Replacement::Lru);
        m.install(1, 0.0);
        m.mark_dirty(1);
        let ev = m.install(2, 1.0).unwrap();
        assert!(ev.dirty);
    }

    #[test]
    fn reinstall_keeps_earliest_arrival() {
        let mut m = LocalMemory::new(2, Replacement::Lru);
        m.install(1, 10.0);
        m.install(1, 5.0);
        assert!(m.present(1, 6.0));
    }

    #[test]
    fn capacity_never_exceeded_property() {
        crate::util::proptest::check(0x10CA1, 30, |rng| {
            let cap = 1 + rng.index(8);
            let policy = if rng.chance(0.5) {
                Replacement::Lru
            } else {
                Replacement::Fifo
            };
            let mut m = LocalMemory::new(cap, policy);
            for t in 0..300u64 {
                let page = rng.below(32);
                if rng.chance(0.5) {
                    m.access(page, rng.chance(0.3), t as f64);
                } else {
                    m.install(page, t as f64);
                }
                assert!(m.len() <= cap, "len {} > cap {cap}", m.len());
            }
        });
    }

    #[test]
    fn eviction_victims_were_resident_property() {
        crate::util::proptest::check(0x10CA2, 20, |rng| {
            let mut m = LocalMemory::new(4, Replacement::Lru);
            let mut resident: std::collections::HashSet<u64> =
                std::collections::HashSet::new();
            for t in 0..200u64 {
                let page = rng.below(16);
                if let Some(ev) = m.install(page, t as f64) {
                    assert!(resident.remove(&ev.page), "phantom victim {}", ev.page);
                }
                resident.insert(page);
            }
        });
    }
}
