//! Memory substrate: on-chip cache hierarchy, local memory page store, and
//! DRAM bus models for local and remote memory components.

pub mod cache;
pub mod dram;
pub mod local;

pub use cache::{Access, Cache};
pub use dram::DramBus;
pub use local::{Evicted, LocalMemory};
