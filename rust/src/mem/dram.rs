//! DRAM bus model (Table 2: DDR4-2400, 17 GB/s, 15 ns processing).
//!
//! Both local memory and each remote memory component own one bus.  The
//! remote bus can be §4.1-partitioned by the DaeMon memory engine (the
//! paper partitions "both in the network … and when accessing data from
//! remote memory modules").  Remote accesses additionally pay one DRAM
//! access of hardware address translation (Clio-style, §5).

use crate::net::link::{Class, Link};

pub struct DramBus {
    link: Link,
    /// Fixed processing latency per access, cycles.
    pub latency_cycles: f64,
}

impl DramBus {
    /// Unpartitioned bus.
    pub fn shared(bytes_per_cycle: f64, latency_cycles: f64, interval: f64) -> Self {
        Self { link: Link::shared(0.0, bytes_per_cycle, interval), latency_cycles }
    }

    /// Partitioned bus (DaeMon memory engine).
    pub fn partitioned(
        bytes_per_cycle: f64,
        latency_cycles: f64,
        ratio: f64,
        interval: f64,
    ) -> Self {
        Self {
            link: Link::partitioned(0.0, bytes_per_cycle, ratio, interval),
            latency_cycles,
        }
    }

    /// Read/write `bytes` at `now`; returns completion time.
    pub fn access(&mut self, now: f64, bytes: u64, class: Class) -> f64 {
        self.link.send(now, bytes, class) + self.latency_cycles
    }

    /// Queue backlog for `class` at `now` (cycles).
    pub fn backlog(&self, now: f64, class: Class) -> f64 {
        self.link.backlog(now, class)
    }

    /// Whether the `class` queue has nothing queued or in service at
    /// `now` (the work-conserving borrow test).
    pub fn idle(&self, now: f64, class: Class) -> bool {
        self.link.idle(now, class)
    }

    pub fn is_partitioned(&self) -> bool {
        self.link.is_partitioned()
    }

    /// Service rate of the `class` sub-channel, bytes/cycle.
    pub fn rate(&self, class: Class) -> f64 {
        self.link.rate(class)
    }

    /// One-lookup hardware address translation (a dependent DRAM access).
    pub fn translate(&mut self, now: f64, class: Class) -> f64 {
        self.access(now, 8, class)
    }

    pub fn utilization(&self, horizon: f64) -> f64 {
        self.link.utilization(horizon)
    }

    pub fn bytes_moved(&self) -> u64 {
        self.link.bytes_moved()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_pays_latency_and_serialization() {
        let mut d = DramBus::shared(4.0, 54.0, 1000.0);
        let t = d.access(0.0, 64, Class::Line);
        assert!((t - (16.0 + 54.0)).abs() < 1e-9);
    }

    #[test]
    fn consecutive_accesses_queue() {
        let mut d = DramBus::shared(1.0, 10.0, 1000.0);
        let a = d.access(0.0, 100, Class::Line);
        let b = d.access(0.0, 100, Class::Line);
        assert!((b - a - 100.0).abs() < 1e-9);
    }

    #[test]
    fn partitioned_bus_isolates_page_floods() {
        let mut d = DramBus::partitioned(4.0, 0.0, 0.25, 1000.0);
        d.access(0.0, 30_000, Class::Page);
        let line = d.access(0.0, 64, Class::Line);
        assert!(line < 100.0, "line delayed by page flood: {line}");
    }

    #[test]
    fn translate_is_small_access() {
        let mut d = DramBus::shared(4.0, 54.0, 1000.0);
        let t = d.translate(0.0, Class::Line);
        assert!((t - (2.0 + 54.0)).abs() < 1e-9);
    }
}
