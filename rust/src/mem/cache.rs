//! Set-associative cache model (L1D / L2 / LLC of Table 2).
//!
//! Functional contents with LRU stamps + dirty bits; latency is charged by
//! the core model.  Ways are scanned linearly (8–16 ways ⇒ cheap).

use crate::config::CacheParams;

#[derive(Clone, Copy, Debug, Default)]
struct Way {
    tag: u64,
    valid: bool,
    dirty: bool,
    stamp: u64,
}

/// Result of a cache access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; the evicted victim, if dirty, is carried out for writeback.
    Miss { dirty_victim: Option<u64> },
}

pub struct Cache {
    /// Flat `sets * ways` array (single allocation — the nested
    /// Vec-of-Vecs layout cost one pointer chase per L1 access; see
    /// EXPERIMENTS.md §Perf).
    ways_flat: Vec<Way>,
    set_count: usize,
    set_mask: u64,
    line_shift: u32,
    ways: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(params: &CacheParams, line_bytes: u64) -> Self {
        let lines = params.size_bytes / line_bytes;
        let sets = (lines as usize / params.ways).max(1);
        assert!(sets.is_power_of_two(), "sets must be a power of two: {sets}");
        Self {
            ways_flat: vec![Way::default(); sets * params.ways],
            set_count: sets,
            set_mask: sets as u64 - 1,
            line_shift: line_bytes.trailing_zeros(),
            ways: params.ways,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    fn index(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.line_shift;
        ((line & self.set_mask) as usize, line >> self.set_mask.count_ones())
    }

    /// Access `addr`; on a miss the line is installed and the LRU victim's
    /// full line address is returned if it was dirty.
    pub fn access(&mut self, addr: u64, write: bool) -> Access {
        self.tick += 1;
        let (set_idx, tag) = self.index(addr);
        let shift = self.line_shift;
        let bits = self.set_mask.count_ones();
        let base = set_idx * self.ways;
        let set = &mut self.ways_flat[base..base + self.ways];
        for w in set.iter_mut() {
            if w.valid && w.tag == tag {
                w.stamp = self.tick;
                w.dirty |= write;
                self.hits += 1;
                return Access::Hit;
            }
        }
        self.misses += 1;
        // Victim: invalid way first, else LRU.
        let victim = (0..self.ways)
            .find(|&i| !set[i].valid)
            .unwrap_or_else(|| {
                (0..self.ways)
                    .min_by_key(|&i| set[i].stamp)
                    .unwrap()
            });
        let dirty_victim = if set[victim].valid && set[victim].dirty {
            let line = (set[victim].tag << bits) | set_idx as u64;
            Some(line << shift)
        } else {
            None
        };
        set[victim] = Way { tag, valid: true, dirty: write, stamp: self.tick };
        Access::Miss { dirty_victim }
    }

    /// Probe without updating state.
    pub fn contains(&self, addr: u64) -> bool {
        let (set_idx, tag) = self.index(addr);
        let base = set_idx * self.ways;
        self.ways_flat[base..base + self.ways]
            .iter()
            .any(|w| w.valid && w.tag == tag)
    }

    /// Install a line without it being demanded (e.g. critical line pushed
    /// straight into the LLC by the DaeMon engine).  Returns dirty victim.
    pub fn install(&mut self, addr: u64) -> Option<u64> {
        match self.access(addr, false) {
            Access::Hit => None,
            Access::Miss { dirty_victim } => dirty_victim,
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheParams;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B = 512B.
        Cache::new(
            &CacheParams { size_bytes: 512, ways: 2, latency_cycles: 1.0, mshrs: 4 },
            64,
        )
    }

    #[test]
    fn hit_after_miss() {
        let mut c = tiny();
        assert!(matches!(c.access(0x1000, false), Access::Miss { .. }));
        assert_eq!(c.access(0x1000, false), Access::Hit);
        assert_eq!(c.access(0x1038, false), Access::Hit); // same line
        assert!(matches!(c.access(0x1040, false), Access::Miss { .. })); // next line
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = tiny();
        // Three lines mapping to the same set (stride = sets*line = 256B).
        c.access(0x0000, false);
        c.access(0x0100, false);
        c.access(0x0000, false); // refresh 0x0000
        c.access(0x0200, false); // evicts 0x0100
        assert!(c.contains(0x0000));
        assert!(!c.contains(0x0100));
        assert!(c.contains(0x0200));
    }

    #[test]
    fn dirty_victim_writeback() {
        let mut c = tiny();
        c.access(0x0000, true);
        c.access(0x0100, false);
        let r = c.access(0x0200, false); // evicts dirty 0x0000
        assert_eq!(r, Access::Miss { dirty_victim: Some(0x0000) });
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = tiny();
        c.access(0x0000, false);
        c.access(0x0000, true); // now dirty via hit
        c.access(0x0100, false);
        let r = c.access(0x0200, false);
        assert_eq!(r, Access::Miss { dirty_victim: Some(0x0000) });
    }

    #[test]
    fn hit_rate_counting() {
        let mut c = tiny();
        c.access(0x0000, false);
        c.access(0x0000, false);
        c.access(0x0000, false);
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn table2_geometry() {
        let llc = Cache::new(
            &CacheParams { size_bytes: 4 << 20, ways: 16, latency_cycles: 30.0, mshrs: 128 },
            64,
        );
        assert_eq!(llc.set_count, 4096);
    }

    #[test]
    fn victim_address_reconstruction_property() {
        crate::util::proptest::check(0xCAC4E, 30, |rng| {
            let mut c = tiny();
            let mut resident: crate::util::hash::FxHashSet<u64> =
                crate::util::hash::FxHashSet::default();
            for _ in 0..200 {
                let addr = (rng.below(64) * 64) & !63;
                match c.access(addr, rng.chance(0.5)) {
                    Access::Hit => assert!(resident.contains(&(addr & !63))),
                    Access::Miss { dirty_victim } => {
                        if let Some(v) = dirty_victim {
                            assert!(
                                resident.contains(&v),
                                "victim {v:#x} never inserted"
                            );
                            resident.remove(&v);
                        }
                        resident.insert(addr & !63);
                    }
                }
            }
        });
    }
}
