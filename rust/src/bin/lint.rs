//! `daemon-lint` — the repo's zero-dependency determinism and
//! invariant static-analysis gate.
//!
//! Scans `rust/src`, `rust/tests`, and `benches` and enforces the
//! DESIGN.md determinism rules (R1 hashing, R2 entropy, R3 iteration
//! order) plus the drift invariants (R4 registry/lifecycle docs, R5
//! shard wire format, R6 policy-registry docs).  CI runs this as a
//! required check; run it locally with `cargo run --bin daemon-lint`.
//!
//! Usage:
//!   daemon-lint [--root DIR]    scan a tree (default: current dir)
//!   daemon-lint --list          print rule ids and summaries
//!   daemon-lint --explain RULE  print a rule's DESIGN.md rationale
//!
//! Exit codes: 0 clean, 1 diagnostics found, 2 usage/setup error.

use daemon_sim::util::lint::{all_rules, canonical_rule, run, Repo, Rule};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    root: PathBuf,
    list: bool,
    explain: Option<String>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { root: PathBuf::from("."), list: false, explain: None };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let v = it.next().ok_or("--root needs a directory argument")?;
                args.root = PathBuf::from(v);
            }
            "--list" => args.list = true,
            "--explain" => {
                let v = it.next().ok_or("--explain needs a rule id argument")?;
                args.explain = Some(v);
            }
            "--help" | "-h" => return Err("help".to_string()),
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

const USAGE: &str = "usage: daemon-lint [--root DIR] [--list] [--explain RULE]";

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) if msg == "help" => {
            println!("{USAGE}");
            return ExitCode::SUCCESS;
        }
        Err(msg) => {
            eprintln!("daemon-lint: {msg}");
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };

    if args.list {
        for rule in all_rules() {
            println!("{:<18} {}", rule.id(), rule.summary());
        }
        return ExitCode::SUCCESS;
    }

    if let Some(name) = args.explain {
        let Some(id) = canonical_rule(&name) else {
            eprintln!("daemon-lint: unknown rule `{name}` (try --list)");
            return ExitCode::from(2);
        };
        for rule in all_rules() {
            if rule.id() == id {
                println!("{} — {}\n\n{}", rule.id(), rule.summary(), rule.explain());
            }
        }
        return ExitCode::SUCCESS;
    }

    if !args.root.join("Cargo.toml").is_file() || !args.root.join("rust/src").is_dir() {
        eprintln!(
            "daemon-lint: `{}` does not look like the repo root (want Cargo.toml and \
             rust/src); pass --root",
            args.root.display()
        );
        return ExitCode::from(2);
    }

    let repo = match Repo::load(&args.root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("daemon-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let diags = run(&repo);
    if diags.is_empty() {
        eprintln!("daemon-lint: clean ({} files scanned)", repo.files.len());
        ExitCode::SUCCESS
    } else {
        for d in &diags {
            println!("{d}");
        }
        eprintln!("daemon-lint: {} violation(s)", diags.len());
        ExitCode::from(1)
    }
}
