//! `trace-check` — validate a `--trace-out` Chrome-trace JSON document.
//!
//! CI smoke gate for the observability exporter: parses the file with
//! the in-repo JSON parser and checks the trace-event invariants
//! Perfetto relies on — a non-empty `traceEvents` array whose entries
//! all carry `name`/`ph`/`pid`/`ts`, spans (`ph: "X"`) a non-negative
//! `dur`, plus the exporter's own `otherData.clock = "sim-cycles"` tag.
//!
//! Usage: trace-check <trace.json>
//! Exit codes: 0 valid, 1 invalid, 2 usage/IO error.

use daemon_sim::util::json::Json;
use std::process::ExitCode;

fn validate(doc: &Json) -> Result<usize, String> {
    let events = doc
        .get_arr("traceEvents")
        .ok_or("top-level `traceEvents` array is missing")?;
    if events.is_empty() {
        return Err("`traceEvents` is empty — the run produced no events".into());
    }
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get_str("name")
            .ok_or_else(|| format!("event {i}: missing `name`"))?;
        let ph = ev
            .get_str("ph")
            .ok_or_else(|| format!("event {i} ({name}): missing `ph`"))?;
        if ev.get_f64("pid").is_none() {
            return Err(format!("event {i} ({name}): missing numeric `pid`"));
        }
        match ph {
            "M" => continue, // metadata events carry no timestamp
            "X" => {
                let dur = ev
                    .get_f64("dur")
                    .ok_or_else(|| format!("event {i} ({name}): span without `dur`"))?;
                if dur < 0.0 {
                    return Err(format!("event {i} ({name}): negative `dur` {dur}"));
                }
            }
            "i" => {}
            other => return Err(format!("event {i} ({name}): unexpected `ph` '{other}'")),
        }
        let ts = ev
            .get_f64("ts")
            .ok_or_else(|| format!("event {i} ({name}): missing numeric `ts`"))?;
        if ts < 0.0 {
            return Err(format!("event {i} ({name}): negative `ts` {ts}"));
        }
        if ev.get_f64("tid").is_none() {
            return Err(format!("event {i} ({name}): missing numeric `tid`"));
        }
    }
    match doc.get("otherData").and_then(|o| o.get_str("clock")) {
        Some("sim-cycles") => {}
        other => {
            return Err(format!(
                "`otherData.clock` should be \"sim-cycles\", got {other:?}"
            ))
        }
    }
    Ok(events.len())
}

fn main() -> ExitCode {
    let mut paths: Vec<String> = std::env::args().skip(1).collect();
    if paths.len() != 1 || paths[0].starts_with("--") {
        eprintln!("usage: trace-check <trace.json>");
        return ExitCode::from(2);
    }
    let path = paths.remove(0);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trace-check: {path}: {e}");
            return ExitCode::from(2);
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("trace-check: {path}: bad JSON: {e}");
            return ExitCode::from(1);
        }
    };
    match validate(&doc) {
        Ok(n) => {
            eprintln!("trace-check: {path}: valid ({n} trace events)");
            ExitCode::SUCCESS
        }
        Err(msg) => {
            eprintln!("trace-check: {path}: {msg}");
            ExitCode::from(1)
        }
    }
}
